"""The serving plane must never hand back a torn read.

Three guarantees pinned here, per store layout (device + sharded
S in {1, 2, 4}):

* **Swap atomicity** — queries racing a background ``prepare_compact`` /
  ``prepare_rebalance`` are bit-identical to the pure pre-swap snapshot
  while the shadow builds, and every query racing the publish itself
  matches either the pre- or the post-swap snapshot exactly (the flip is
  one pointer assignment; no query observes a mixture).
* **Chunked-fold parity** — the incremental shadow build
  (``swap_chunk_rows`` small) produces a store bit-identical to the
  monolithic one-program fold (``swap_chunk_rows=None``): same segment
  arrays, same query answers.
* **Scheduler contract** — micro-batch coalescing returns exactly the
  rows a direct ``query_arrays`` batch would; sampling requests never
  coalesce and replay by seed; errors resolve futures instead of wedging
  the lane; tenant quotas reject with ``QuotaExceeded`` and count it.
"""

import threading

import numpy as np
import pytest

import grids
from repro.serving.lsh_service import LSHService
from repro.serving.scheduler import (QuotaExceeded, ServingScheduler,
                                     TenantQuota)

TOPK = 5
N_CORPUS = 67          # coprime to every shard count: padded last shard
N_QUERIES = 6
N_INS = 13

LAYOUTS = (None,) + grids.SHARD_COUNTS    # device + sharded S in {1,2,4}


def _service(shards, **kw):
    corpus, queries = grids.corpus_and_queries(N_CORPUS, N_QUERIES)
    kw.setdefault("bucket_cap", 16)
    kw.setdefault("max_deltas", 64)       # no auto-compact under the races
    svc = LSHService(grids.grid_family("cp-e2lsh"), metric="euclidean",
                     shards=shards, **kw).build(corpus)
    return svc, corpus, queries


def _mutate(svc, corpus):
    """One delta slab + tombstones in both base and delta, so the fold
    has real compaction work (not a no-op flip)."""
    svc.insert(np.asarray(corpus[:N_INS]) + 0.5)
    svc.delete([3, 10, 25, N_CORPUS + 2])


def _answers(svc, queries):
    return svc.query_arrays(queries, topk=TOPK)


def _matches(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def _assert_same(a, b):
    for name, x, y in zip(("ids", "scores", "n_cand"), a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=name)


class TestSwapInterleaving:
    """Satellite: seeded interleaving — queries racing a background swap
    build/publish are bit-identical to a pure pre- or post-swap answer."""

    @pytest.mark.parametrize("shards", LAYOUTS)
    def test_queries_racing_compact_swap_are_never_torn(self, shards):
        svc, corpus, queries = _service(shards)
        _mutate(svc, corpus)
        pre = _answers(svc, queries)

        results = []
        done = threading.Event()

        def serve():
            while not done.is_set():
                results.append(_answers(svc, queries))

        thread = threading.Thread(target=serve)
        thread.start()
        try:
            pending = svc.prepare_compact()
            assert pending is not None      # the mutations gave it work
            # every query that raced the build saw the untouched live store
            mid = list(results)
            svc.apply_swap(pending)
        finally:
            done.set()
            thread.join()
        for r in mid:
            _assert_same(r, pre)
        post = _answers(svc, queries)
        # queries that raced the publish saw exactly one of the two stores
        for r in results:
            assert _matches(r, pre) or _matches(r, post), \
                "a query racing the swap returned a torn mixture"
        assert not svc.index.store.mutated

    @pytest.mark.parametrize("shards", grids.SHARD_COUNTS)
    def test_queries_racing_rebalance_swap_are_never_torn(self, shards):
        svc, corpus, queries = _service(shards)
        _mutate(svc, corpus)
        pre = _answers(svc, queries)

        results = []
        done = threading.Event()

        def serve():
            while not done.is_set():
                results.append(_answers(svc, queries))

        thread = threading.Thread(target=serve)
        thread.start()
        try:
            pending = svc.prepare_rebalance()
            mid = list(results)
            svc.apply_swap(pending)
        finally:
            done.set()
            thread.join()
        for r in mid:
            _assert_same(r, pre)
        post = _answers(svc, queries)
        for r in results:
            assert _matches(r, pre) or _matches(r, post)

    @pytest.mark.parametrize("shards", (None, 2))
    def test_stale_swap_rejected_after_interleaved_mutation(self, shards):
        """A mutation between prepare and apply invalidates the shadow —
        publishing it would silently drop the mutation."""
        svc, corpus, queries = _service(shards)
        _mutate(svc, corpus)
        pending = svc.prepare_compact()
        svc.insert(np.asarray(corpus[:2]) + 1.0)
        with pytest.raises(RuntimeError, match="mutated"):
            svc.apply_swap(pending)
        # the live store still serves; a fresh prepare/apply succeeds
        svc.apply_swap(svc.prepare_compact())
        assert not svc.index.store.mutated
        _answers(svc, queries)


class TestChunkedFoldParity:
    """The incremental (chunked, throttled) shadow build is an
    implementation detail: its store must be bit-identical to the
    monolithic fold's, down to every segment array."""

    @pytest.mark.parametrize("shards", LAYOUTS)
    def test_chunked_store_bit_identical_to_monolithic(self, shards):
        svc_mono, corpus, queries = _service(shards)
        svc_chunk, _, _ = _service(shards)
        svc_mono.index.swap_chunk_rows = None
        svc_chunk.index.swap_chunk_rows = 16   # many chunks over 67 items
        for svc in (svc_mono, svc_chunk):
            _mutate(svc, corpus)
            svc.apply_swap(svc.prepare_compact())
        a, b = svc_mono.index.store.base, svc_chunk.index.store.base
        assert a.cap == b.cap
        np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
        np.testing.assert_array_equal(np.asarray(a.sorted_keys),
                                      np.asarray(b.sorted_keys))
        np.testing.assert_array_equal(np.asarray(a.perm), np.asarray(b.perm))
        import jax
        for la, lb in zip(jax.tree.leaves(a.corpus), jax.tree.leaves(b.corpus)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        _assert_same(_answers(svc_mono, queries), _answers(svc_chunk, queries))


class TestSchedulerCoalescing:
    def test_coalesced_rows_match_direct_batch(self):
        svc, _, queries = _service(None)
        direct = svc.query_arrays(queries, topk=TOPK)
        with ServingScheduler(svc, max_batch=4, deadline_ms=100.0) as sched:
            futs = [sched.query(q, topk=TOPK) for q in queries]
            got = [f.result(timeout=30) for f in futs]
        for i, (ids, scores, n_cand) in enumerate(got):
            np.testing.assert_array_equal(ids, direct[0][i])
            np.testing.assert_array_equal(scores, direct[1][i])
            assert n_cand == int(direct[2][i])
        assert sched.stats.requests == N_QUERIES
        assert sched.stats.batches >= 1
        # pad rows never inflate the tenant's counters
        assert svc.stats.queries == 2 * N_QUERIES   # direct + scheduled

    def test_sampling_requests_replay_by_seed_and_never_coalesce(self):
        svc, _, queries = _service(None)
        with ServingScheduler(svc, max_batch=8, deadline_ms=50.0) as sched:
            futs = [sched.query(queries[0], topk=TOPK, mode="uniform", seed=9)
                    for _ in range(4)]
            got = [f.result(timeout=30) for f in futs]
            sched.flush(timeout=30)
            # one program batch per sampling request: the draw is a
            # per-request seeded event, never amortized across requests
            assert sched.stats.batches == 4
        for r in got[1:]:
            _assert_same(r, got[0])
        direct = svc.query_arrays(queries[:1], topk=TOPK, mode="uniform",
                                  seed=9)
        _assert_same(got[0], (direct[0][0], direct[1][0], int(direct[2][0])))

    def test_errors_resolve_futures_without_wedging_the_lane(self):
        svc, _, queries = _service(None)
        with ServingScheduler(svc, max_batch=4, deadline_ms=5.0) as sched:
            with pytest.raises(ValueError, match="probes must be >= 1"):
                sched.query(queries[0], probes=0).result(timeout=30)
            with pytest.raises(ValueError, match="seed"):
                sched.query(queries[0], mode="uniform").result(timeout=30)
            ids, _, _ = sched.query(queries[0], topk=TOPK).result(timeout=30)
            assert ids.shape == (TOPK,)

    def test_ingest_lane_orders_mutations_and_swaps(self):
        svc, corpus, queries = _service(2)
        direct = LSHService(grids.grid_family("cp-e2lsh"), metric="euclidean",
                            shards=2, bucket_cap=16, max_deltas=64,
                            ).build(corpus)
        _mutate(direct, corpus)
        direct.apply_swap(direct.prepare_compact())
        with ServingScheduler(svc, max_batch=4, deadline_ms=5.0) as sched:
            sched.insert(np.asarray(corpus[:N_INS]) + 0.5)
            sched.delete([3, 10, 25, N_CORPUS + 2])
            assert sched.compact().result(timeout=60) is svc
            fut = sched.query(queries[0], topk=TOPK)
            _assert_same(fut.result(timeout=30),
                         tuple(r[0] if getattr(r, "ndim", 0) else r
                               for r in _answers(direct, queries[:1])))
        assert not svc.index.store.mutated
        assert svc.stats.compactions == 1

    def test_flush_and_close_contract(self):
        svc, _, queries = _service(None)
        sched = ServingScheduler(svc, max_batch=4, deadline_ms=5.0)
        futs = [sched.query(q, topk=TOPK) for q in queries]
        sched.flush(timeout=30)
        assert all(f.done() for f in futs)
        sched.close()
        sched.close()                      # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            sched.query(queries[0])


class TestNamespaces:
    def _two_tenant(self):
        svc_a, corpus, queries = _service(None)
        svc_b, _, _ = _service(2)
        sched = ServingScheduler(
            {"a": svc_a, "b": svc_b},
            max_batch=4, deadline_ms=5.0,
            quotas={"a": TenantQuota(max_items=N_CORPUS + 4)})
        return sched, svc_a, svc_b, corpus, queries

    def test_tenants_route_to_their_own_index(self):
        sched, svc_a, svc_b, corpus, queries = self._two_tenant()
        with sched:
            assert sorted(sched.namespaces()) == ["a", "b"]
            assert sched.service("a") is svc_a
            ra = sched.query(queries[0], tenant="a", topk=TOPK).result(30)
            rb = sched.query(queries[0], tenant="b", topk=TOPK).result(30)
            da = svc_a.query_arrays(queries[:1], topk=TOPK)
            db = svc_b.query_arrays(queries[:1], topk=TOPK)
            np.testing.assert_array_equal(ra[0], da[0][0])
            np.testing.assert_array_equal(rb[0], db[0][0])
            # per-tenant counters stay per-tenant (1 scheduled + 1 direct)
            assert sched.tenant_stats("a").queries == 2
            assert sched.tenant_stats("b").queries == 2
            with pytest.raises(KeyError, match="unknown namespace"):
                sched.query(queries[0], tenant="nope")
            with pytest.raises(ValueError, match="already registered"):
                sched.add_namespace("a", svc_a)

    def test_max_items_quota_rejects_oversize_insert(self):
        sched, svc_a, _, corpus, _ = self._two_tenant()
        with sched:
            sched.insert(np.asarray(corpus[:4]), tenant="a").result(30)
            with pytest.raises(QuotaExceeded, match="max_items"):
                sched.insert(np.asarray(corpus[:1]), tenant="a")
            assert svc_a.stats.rejected == 1
            # tenant "b" has no quota: same insert admits fine
            sched.insert(np.asarray(corpus[:1]), tenant="b").result(30)

    def test_max_pending_quota_sheds_load(self):
        svc, _, queries = _service(None)
        sched = ServingScheduler(
            svc, max_batch=4, deadline_ms=5.0,
            quotas={"default": TenantQuota(max_pending=0)})
        with sched:
            with pytest.raises(QuotaExceeded, match="max_pending"):
                sched.query(queries[0])
            assert svc.stats.rejected == 1
