"""Streaming mutations vs fresh rebuild: after any interleaving of
``insert`` / ``delete`` batches, ``query_batch`` must equal an index
freshly built over the same effective corpus — while delta segments and
tombstones are outstanding, after the (shard-local) ``compact()``, and
after ``rebalance()`` — for every hash family kind, both metrics, the
device and the sharded layout, and S in {1, 2, 4} shards. On the sharded
layout the mutation plane is shard-native: every insert batch becomes one
sharded delta slab routed least-loaded-first, and ``compact()`` folds each
shard locally. Tombstoned items must never surface in any top-k.

Equality granularity: ids, candidate counts, and candidate sets are
bit-identical in every cell. Scores are bit-identical whenever the stored
arrays coincide with a fresh build's — after the device index's
``compact()`` and after the sharded index's ``rebalance()`` (both rebuild
the exact fresh-build layout) — and reproduce to float-reassociation noise
(asserted at <= 16 ulp) while deltas are outstanding or while a
shard-locally compacted base partitions shards differently from the
contiguous fresh build: the programs then rank at different candidate
widths and XLA may re-vectorize the score reductions per shape (the same
cross-program wobble tests/test_index_sharded.py documents for the vmap
fallback, here three orders of magnitude tighter).

Shard-native coverage must fail loudly: every sharded cell asserts
``ShardedLSHIndex.query_path`` — on a multi-device platform (the CI
4-device leg runs this whole file in-process) a silent fallback from
shard_map to the vmapped program is an assertion error, not a quiet loss
of coverage. A subprocess leg forces the 4-device host platform so the
shard_map path of the mutated store is exercised in every tier-1 run.
"""

import os
import subprocess
import sys
import textwrap

import grids
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from grids import ALL_KINDS, DIMS, SHARD_COUNTS
from repro.core import (CPTensor, DeviceLSHIndex, HostLSHIndex,
                        ShardedLSHIndex, ShardedSegment, cp_random_data,
                        make_family)
from repro.core.segments import route_balanced
from repro.serving.lsh_service import LSHService

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N_CORPUS, N_QUERIES, TOPK = 48, 4, 5
# two insert batches and two delete batches, interleaved; delete ids are
# effective ids at the time of the call and span base + delta segments
N_INS1, N_INS2 = 12, 9
DEL1 = np.array([3, 40, 50, 59])   # valid in [0, 60): base + first delta
DEL2 = np.array([0, 33, 64])       # valid in [0, 65): post-DEL1 numbering


def _data(seed=0):
    return grids.corpus_and_queries(N_CORPUS, N_QUERIES, seed=seed)


def _inserts(seed=100):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (N_INS1,) + DIMS),
            jax.random.normal(k2, (N_INS2,) + DIMS))


def _family(kind):
    return grids.grid_family(kind)


def _mutate(index, corpus):
    """Apply the fixed insert/delete interleaving; return the effective
    corpus (numpy) a fresh rebuild must be bit-identical to."""
    ins1, ins2 = _inserts()
    eff = np.asarray(corpus)
    index.insert(ins1)
    eff = np.concatenate([eff, np.asarray(ins1)])
    index.delete(DEL1)
    eff = np.delete(eff, DEL1, axis=0)
    index.insert(ins2)
    eff = np.concatenate([eff, np.asarray(ins2)])
    index.delete(DEL2)
    eff = np.delete(eff, DEL2, axis=0)
    return eff


def _assert_bit_identical(got, want, msg=None, scores_exact=True):
    g_ids, g_sc, g_nc = (np.asarray(a) for a in got)
    w_ids, w_sc, w_nc = (np.asarray(a) for a in want)
    np.testing.assert_array_equal(g_ids, w_ids, err_msg=msg)
    np.testing.assert_array_equal(g_nc, w_nc, err_msg=msg)
    if scores_exact:
        np.testing.assert_array_equal(g_sc, w_sc, err_msg=msg)
    else:
        fin = np.isfinite(w_sc)
        np.testing.assert_array_equal(np.isfinite(g_sc), fin, err_msg=msg)
        np.testing.assert_array_equal(g_sc[~fin], w_sc[~fin], err_msg=msg)
        np.testing.assert_array_max_ulp(g_sc[fin], w_sc[fin], maxulp=16)


# shared with the other layout suites (tests/grids.py)
_assert_query_path = grids.assert_query_path


@pytest.mark.parametrize("kind,metric", grids.cell_params())
class TestStreamingParityDevice:
    def test_mutated_equals_fresh_rebuild(self, kind, metric):
        corpus, queries = _data()
        fam = _family(kind)
        mutated = DeviceLSHIndex(fam, metric=metric, max_deltas=8).build(
            corpus)
        eff = _mutate(mutated, corpus)
        assert mutated.size == eff.shape[0]
        assert len(mutated.store.deltas) == 2 and mutated.store.mutated
        fresh = DeviceLSHIndex(fam, metric=metric).build(jnp.asarray(eff))
        for batch in (1, N_QUERIES):
            want = fresh.query_batch(queries[:batch], topk=TOPK)
            _assert_bit_identical(
                mutated.query_batch(queries[:batch], topk=TOPK), want,
                (kind, metric, batch, "uncompacted"), scores_exact=False)
        mutated.compact()
        assert not mutated.store.mutated and not mutated.store.deltas
        for batch in (1, N_QUERIES):
            want = fresh.query_batch(queries[:batch], topk=TOPK)
            _assert_bit_identical(
                mutated.query_batch(queries[:batch], topk=TOPK), want,
                (kind, metric, batch, "compacted"))


@pytest.mark.parametrize("shards", SHARD_COUNTS)
@pytest.mark.parametrize("kind,metric", grids.cell_params())
class TestStreamingParitySharded:
    """The acceptance matrix: 6 kinds x 2 metrics x S in {1, 2, 4} x
    {uncompacted, shard-locally compacted, rebalanced}. Ids, counts, and
    candidate sets are bit-identical to a fresh rebuild in every cell;
    scores are <= 16 ulp while the shard partition differs from the
    contiguous fresh build and bit-identical after ``rebalance()``."""

    def test_sharded_mutated_equals_fresh_rebuild(self, kind, metric,
                                                  shards):
        corpus, queries = _data()
        fam = _family(kind)
        mutated = ShardedLSHIndex(fam, metric=metric, shards=shards,
                                  max_deltas=8).build(corpus)
        _assert_query_path(mutated)
        eff = _mutate(mutated, corpus)
        assert all(isinstance(d, ShardedSegment)
                   for d in mutated.store.deltas)
        fresh = ShardedLSHIndex(fam, metric=metric, shards=shards).build(
            jnp.asarray(eff))
        want = fresh.query_batch(queries, topk=TOPK)
        _assert_bit_identical(mutated.query_batch(queries, topk=TOPK),
                              want, (kind, metric, shards, "uncompacted"),
                              scores_exact=False)
        # candidate sets (effective ids) also match the fresh rebuild
        for i in range(N_QUERIES):
            np.testing.assert_array_equal(mutated.candidates(queries[i]),
                                          fresh.candidates(queries[i]))
        mutated.compact()
        assert not mutated.store.mutated and not mutated.store.deltas
        _assert_query_path(mutated)
        _assert_bit_identical(mutated.query_batch(queries, topk=TOPK),
                              want, (kind, metric, shards, "compacted"),
                              scores_exact=False)
        mutated.rebalance()
        _assert_query_path(mutated)
        _assert_bit_identical(mutated.query_batch(queries, topk=TOPK),
                              want, (kind, metric, shards, "rebalanced"))


class TestShardOccupancy:
    """Invariants of the routed mutation plane: occupancy always sums to
    the live count, the balance policy fills least-loaded shards first,
    and rebalance restores the contiguous even split."""

    def test_route_balanced_waterfill(self):
        alloc, offsets = route_balanced(10, np.array([5, 0, 3, 9]))
        assert alloc.sum() == 10
        # shard 1 (emptiest) gets the first, largest slab
        assert offsets[1] == 0 and alloc[1] == alloc.max()
        after = np.array([5, 0, 3, 9]) + alloc
        assert after[:3].max() - after[:3].min() <= 1  # filled shards level
        assert alloc[3] == 0                           # heaviest untouched
        # contiguous slabs tile the batch exactly
        spans = sorted((int(o), int(o + a)) for o, a in zip(offsets, alloc))
        covered = [s for s in spans if s[0] != s[1]]
        assert covered[0][0] == 0 and covered[-1][1] == 10
        for (_, e), (b, _) in zip(covered, covered[1:]):
            assert e == b
        # deterministic
        alloc2, offsets2 = route_balanced(10, np.array([5, 0, 3, 9]))
        np.testing.assert_array_equal(alloc, alloc2)
        np.testing.assert_array_equal(offsets, offsets2)

    def test_occupancy_tracks_mutations(self):
        corpus, _ = _data(12)
        idx = ShardedLSHIndex(_family("cp-e2lsh"), metric="euclidean",
                              shards=4).build(corpus)
        assert idx.occupancy().sum() == idx.size == N_CORPUS
        eff = _mutate(idx, corpus)
        occ = idx.occupancy()
        assert occ.sum() == idx.size == eff.shape[0]
        # routed inserts keep shards within a few items of even
        assert occ.max() - occ.min() <= DEL1.size + DEL2.size
        idx.compact()                      # shard-local: occupancy unchanged
        np.testing.assert_array_equal(idx.occupancy(), occ)
        assert idx.store.base.counts == tuple(int(c) for c in occ)
        idx.rebalance()                    # contiguous even split restored
        occ2 = idx.occupancy()
        assert occ2.sum() == eff.shape[0]
        assert occ2.max() - occ2.min() <= 4  # ceil split of n over 4 shards
        n_s = -(-eff.shape[0] // 4)
        assert idx.store.base.counts == tuple(
            int(np.clip(eff.shape[0] - s * n_s, 0, n_s)) for s in range(4))

    def test_sharded_delta_is_sharded_segment(self):
        """Inserts land as routed slabs — one ShardedSegment per batch,
        luts carrying the leading shard dim — never replicated flats."""
        corpus, _ = _data(13)
        idx = ShardedLSHIndex(_family("tt-srp"), metric="cosine",
                              shards=2).build(corpus)
        ins1, _ = _inserts()
        idx.insert(ins1)
        (delta,) = idx.store.deltas
        assert isinstance(delta, ShardedSegment)
        assert delta.shards == 2 and sum(delta.counts) == N_INS1
        live, eff = idx.store._luts[1]
        assert live.shape == (2, delta.shard_size + 1)
        assert eff.shape == (2, delta.shard_size)
        assert not np.asarray(live[:, -1]).any()   # pad sentinel column
        # slab effective ids continue the sequence numbering in batch order
        got = np.sort(np.asarray(eff)[np.asarray(live[:, :-1])])
        np.testing.assert_array_equal(
            got, np.arange(N_CORPUS, N_CORPUS + N_INS1))


class TestCappedLiveWindow:
    """The PR 3 wart, fixed: explicit bucket_cap probe windows prefer live
    slots, so tombstones stop consuming truncation-window space."""

    def test_delete_heavy_capped_equals_fresh_capped_rebuild(self):
        """After heavy deletes, a capped device index returns exactly what
        a fresh capped build over the live corpus returns (same window
        membership — under the old dense windows, buckets whose first
        ``cap`` slots were tombstoned went empty until compaction)."""
        corpus, queries = _data(5)
        cap = 3
        fam = make_family(jax.random.PRNGKey(11), "srp", DIMS, num_codes=1,
                          num_tables=2, rank=2)   # 1-bit keys: huge buckets
        idx = DeviceLSHIndex(fam, metric="cosine", bucket_cap=cap).build(
            corpus)
        dead = np.arange(0, 40, 2)                # 20 of 48 items die
        idx.delete(dead)
        eff = np.delete(np.asarray(corpus), dead, axis=0)
        fresh = DeviceLSHIndex(fam, metric="cosine", bucket_cap=cap).build(
            jnp.asarray(eff))
        want = fresh.query_batch(queries, topk=TOPK)
        _assert_bit_identical(idx.query_batch(queries, topk=TOPK), want,
                              "capped live window", scores_exact=False)
        for i in range(N_QUERIES):
            np.testing.assert_array_equal(idx.candidates(queries[i]),
                                          fresh.candidates(queries[i]))
        # deletes must not starve the window: every probe still fills its
        # cap from live items while live bucket members remain
        n_cand = np.asarray(idx.query_batch(queries, topk=TOPK)[2])
        assert (n_cand > 0).all()

    def test_sharded_capped_live_window(self):
        """Same fix on the sharded layout (S=1 pins the fresh-build
        window equality; the live-window lookups carry the shard dim)."""
        corpus, queries = _data(5)
        fam = make_family(jax.random.PRNGKey(11), "srp", DIMS, num_codes=1,
                          num_tables=2, rank=2)
        idx = ShardedLSHIndex(fam, metric="cosine", bucket_cap=3,
                              shards=1).build(corpus)
        assert idx.store._wins[0] is not None
        assert idx.store._wins[0][0].shape[0] == 1   # leading shard dim
        dead = np.arange(0, 40, 2)
        idx.delete(dead)
        eff = np.delete(np.asarray(corpus), dead, axis=0)
        fresh = ShardedLSHIndex(fam, metric="cosine", bucket_cap=3,
                                shards=1).build(jnp.asarray(eff))
        _assert_bit_identical(idx.query_batch(queries, topk=TOPK),
                              fresh.query_batch(queries, topk=TOPK),
                              "sharded capped live window",
                              scores_exact=False)

    def test_default_cap_keeps_no_window_luts(self):
        corpus, _ = _data(6)
        idx = DeviceLSHIndex(_family("cp-e2lsh"),
                             metric="euclidean").build(corpus)
        assert idx.store._wins == [None]
        idx.delete([1])
        assert idx.store._wins == [None]


class TestStreamingParityShardCounts:
    def test_cp_format_corpus_mutations(self):
        """Pytree (CP factor) corpora stream through insert/delete/compact
        leaf-wise, like the build path — on both layouts."""
        n = 30
        keys = jax.random.split(jax.random.PRNGKey(7), n + 8)
        stack = lambda ks: CPTensor(
            factors=tuple(
                jnp.stack([cp_random_data(k, DIMS, 3).factors[m]
                           for k in ks]) for m in range(3)), scale=1.0)
        corpus, batch = stack(keys[:n]), stack(keys[n:])
        fam = _family("cp-e2lsh")
        queries = jax.tree.map(lambda a: a[:3], corpus)
        eff_ids = np.delete(np.arange(n + 8), [5, n + 2])
        eff = jax.tree.map(lambda *xs: jnp.concatenate(xs)[eff_ids],
                           corpus, batch)
        for make, compact_exact in (
                (lambda: DeviceLSHIndex(fam, metric="euclidean"), True),
                (lambda: ShardedLSHIndex(fam, metric="euclidean",
                                         shards=2), False)):
            mutated = make().build(corpus)
            mutated.insert(batch)
            mutated.delete([5, n + 2])
            fresh = make().build(eff)
            _assert_bit_identical(mutated.query_batch(queries, topk=TOPK),
                                  fresh.query_batch(queries, topk=TOPK),
                                  scores_exact=False)
            mutated.compact()
            # the flat compact rebuilds the exact fresh-build arrays ->
            # scores bit-equal; the shard-local fold keeps routing's
            # partition -> <= 16 ulp until rebalance()
            _assert_bit_identical(mutated.query_batch(queries, topk=TOPK),
                                  fresh.query_batch(queries, topk=TOPK),
                                  scores_exact=compact_exact)


class TestTombstones:
    def test_deleted_item_never_surfaces(self):
        """An exact-member query stops returning its item the moment the
        item is tombstoned, even with the full corpus as topk."""
        corpus, _ = _data(2)
        fam = _family("cp-e2lsh")
        idx = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        ids, scores, _ = idx.query(corpus[11], topk=1)
        assert ids[0] == 11 and scores[0] < 1e-3
        idx.delete([11])
        ids, scores, n_cand = idx.query(corpus[11], topk=N_CORPUS)
        assert n_cand <= N_CORPUS - 1
        assert not (scores < 1e-3).any()   # the deleted vector is gone
        corpus_eff = np.asarray(idx.effective_corpus())
        for i, s in zip(ids, scores):      # returned ids index the live set
            np.testing.assert_allclose(
                np.linalg.norm(corpus_eff[i].ravel()
                               - np.asarray(corpus[11]).ravel()),
                s, rtol=1e-4, atol=1e-5)

    def test_tombstones_lower_candidate_counts(self):
        corpus, queries = _data(3)
        fam = _family("tt-srp")
        idx = DeviceLSHIndex(fam, metric="cosine").build(corpus)
        before = np.asarray(idx.query_batch(queries, topk=TOPK)[2])
        cand = idx.candidates(queries[0])
        assert cand.size > 0
        idx.delete(cand)                   # kill query 0's whole bucket set
        after_cand = idx.candidates(
            jax.tree.map(lambda a: a, queries[0]))
        assert after_cand.size == 0 or not np.intersect1d(
            after_cand, cand).size
        after = np.asarray(idx.query_batch(queries, topk=TOPK)[2])
        assert (after <= before).all()
        assert int(np.asarray(idx.query_batch(queries[:1], TOPK)[2])[0]) == 0

    def test_delete_out_of_range_raises(self):
        corpus, _ = _data(4)
        idx = DeviceLSHIndex(_family("srp"), metric="cosine").build(corpus)
        with pytest.raises(IndexError):
            idx.delete([N_CORPUS])
        with pytest.raises(IndexError):
            idx.delete([-1])
        idx.delete([0, 0, 1])              # duplicates collapse
        assert idx.size == N_CORPUS - 2


class TestMutationContract:
    def test_insert_past_max_deltas_auto_compacts(self):
        corpus, queries = _data(5)
        fam = _family("cp-e2lsh")
        idx = DeviceLSHIndex(fam, metric="euclidean", max_deltas=1).build(
            corpus)
        ins1, ins2 = _inserts()
        idx.insert(ins1)
        assert len(idx.store.deltas) == 1 and idx.compactions == 0
        idx.insert(ins2)                   # 2 > max_deltas -> auto-compact
        assert len(idx.store.deltas) == 0 and idx.compactions == 1
        full = jnp.concatenate([corpus, ins1, ins2])
        fresh = DeviceLSHIndex(fam, metric="euclidean").build(full)
        _assert_bit_identical(idx.query_batch(queries, topk=TOPK),
                              fresh.query_batch(queries, topk=TOPK))

    def test_sharded_auto_compact_is_shard_local(self):
        corpus, queries = _data(5)
        fam = _family("cp-e2lsh")
        idx = ShardedLSHIndex(fam, metric="euclidean", shards=2,
                              max_deltas=1).build(corpus)
        ins1, ins2 = _inserts()
        occ_before = idx.insert(ins1).occupancy()
        idx.insert(ins2)                   # 2 > max_deltas -> auto-compact
        assert len(idx.store.deltas) == 0 and idx.compactions == 1
        assert idx.rebalances == 0         # compaction never moved items
        full = jnp.concatenate([corpus, ins1, ins2])
        fresh = ShardedLSHIndex(fam, metric="euclidean", shards=2).build(
            full)
        _assert_bit_identical(idx.query_batch(queries, topk=TOPK),
                              fresh.query_batch(queries, topk=TOPK),
                              scores_exact=False)
        assert idx.occupancy().sum() == occ_before.sum() + N_INS2

    def test_compact_pristine_is_noop(self):
        corpus, _ = _data(6)
        idx = DeviceLSHIndex(_family("e2lsh"), metric="euclidean").build(
            corpus)
        store = idx.store
        idx.compact()
        assert idx.store is store and idx.compactions == 0

    def test_compact_empty_raises(self):
        corpus, _ = _data(7)
        idx = DeviceLSHIndex(_family("srp"), metric="cosine").build(corpus)
        idx.delete(np.arange(N_CORPUS))
        assert idx.size == 0
        with pytest.raises(ValueError):
            idx.compact()

    def test_effective_corpus_tracks_mutations(self):
        corpus, _ = _data(8)
        idx = DeviceLSHIndex(_family("cp-srp"), metric="cosine").build(corpus)
        eff = _mutate(idx, corpus)
        np.testing.assert_array_equal(np.asarray(idx.effective_corpus()), eff)
        np.testing.assert_array_equal(np.asarray(idx.corpus), eff)
        idx.compact()
        np.testing.assert_array_equal(np.asarray(idx.effective_corpus()), eff)

    def test_sharded_corpus_tracks_mutations(self):
        """ShardedLSHIndex.corpus follows the live corpus after mutations
        (in effective-id order even though routed slabs and shard-local
        compaction interleave shards), same contract as
        DeviceLSHIndex.corpus."""
        corpus, _ = _data(8)
        idx = ShardedLSHIndex(_family("cp-srp"), metric="cosine",
                              shards=2).build(corpus)
        np.testing.assert_array_equal(np.asarray(idx.corpus),
                                      np.asarray(corpus))
        eff = _mutate(idx, corpus)
        np.testing.assert_array_equal(np.asarray(idx.corpus), eff)
        idx.compact()
        np.testing.assert_array_equal(np.asarray(idx.corpus), eff)
        idx.rebalance()
        np.testing.assert_array_equal(np.asarray(idx.corpus), eff)

    def test_insert_empty_batch_is_noop(self):
        corpus, queries = _data(6)
        for idx in (DeviceLSHIndex(_family("e2lsh"),
                                   metric="euclidean").build(corpus),
                    ShardedLSHIndex(_family("e2lsh"), metric="euclidean",
                                    shards=2).build(corpus)):
            before = idx.query_batch(queries, topk=TOPK)
            idx.insert(jnp.zeros((0,) + DIMS))
            assert len(idx.store.deltas) == 0 and idx.size == N_CORPUS
            _assert_bit_identical(idx.query_batch(queries, topk=TOPK),
                                  before)

    def test_rebalance_empty_raises(self):
        corpus, _ = _data(7)
        idx = ShardedLSHIndex(_family("srp"), metric="cosine",
                              shards=2).build(corpus)
        idx.delete(np.arange(N_CORPUS))
        with pytest.raises(ValueError):
            idx.rebalance()


class TestServiceMutations:
    def test_endpoints_and_counters(self):
        corpus, queries = _data(9)
        fam = _family("cp-e2lsh")
        svc = LSHService(fam, metric="euclidean", shards=2).build(corpus)
        ins1, ins2 = _inserts()
        svc.insert(ins1)
        assert svc.delete(DEL1) == DEL1.size
        svc.insert(ins2)
        st = svc.stats
        assert st.inserted == N_INS1 + N_INS2 and st.insert_batches == 2
        assert st.deleted == DEL1.size and st.delete_batches == 1
        assert st.insert_ms > 0 and st.insert_items_per_s > 0
        assert len(st.shard_occupancy) == 2
        assert sum(st.shard_occupancy) == svc.index.size
        assert st.occupancy_skew >= 1.0
        out = svc.query_batch(queries, topk=TOPK)
        assert len(out) == N_QUERIES
        svc.compact()
        assert st.compactions == 1 and st.compact_ms > 0
        assert st.rebalances == 0
        assert not svc.index.store.mutated
        fresh = ShardedLSHIndex(fam, metric="euclidean", shards=2).build(
            svc.index.effective_corpus())
        _assert_bit_identical(svc.index.query_batch(queries, topk=TOPK),
                              fresh.query_batch(queries, topk=TOPK),
                              scores_exact=False)
        svc.rebalance()
        assert st.rebalances == 1 and st.rebalance_ms > 0
        assert sum(st.shard_occupancy) == svc.index.size
        # after the explicit re-partition the layout IS the fresh build's
        _assert_bit_identical(svc.index.query_batch(queries, topk=TOPK),
                              fresh.query_batch(queries, topk=TOPK))

    def test_auto_compact_counters_split_from_explicit(self):
        """max_deltas-triggered folds land in ``auto_compactions`` /
        ``auto_compact_ms`` and never inflate ``insert_ms`` — the ingest
        throughput stat measures ingest, not fold cost."""
        import time
        corpus, _ = _data(9)
        svc = LSHService(_family("cp-e2lsh"), metric="euclidean", shards=2,
                         max_deltas=1).build(corpus)
        ins1, ins2 = _inserts()
        t0 = time.perf_counter()
        svc.insert(ins1)
        svc.insert(ins2)                   # 2 > max_deltas -> auto-compact
        wall_ms = (time.perf_counter() - t0) * 1e3
        st = svc.stats
        assert st.auto_compactions == 1 and st.auto_compact_ms > 0
        assert st.compactions == 0         # no *explicit* fold happened
        # the split is exact: the two timers partition the insert wall
        assert st.insert_ms + st.auto_compact_ms <= wall_ms * 1.05
        svc.insert(ins1)
        svc.compact()
        assert st.compactions == 1 and st.auto_compactions == 1

    def test_rebuild_resets_mutation_counters_and_occupancy(self):
        """``build()`` on a live service describes the new corpus from
        scratch: stale mutation counters and the previous corpus's
        ``shard_occupancy`` must not leak through — not even via the next
        ``_sync_mutation_stats`` (the index's own counters reset too)."""
        corpus, _ = _data(9)
        svc = LSHService(_family("cp-e2lsh"), metric="euclidean", shards=2,
                         max_deltas=1).build(corpus)
        ins1, ins2 = _inserts()
        svc.insert(ins1)
        svc.insert(ins2)                   # auto-compact
        svc.delete(DEL1)
        svc.compact()
        corpus2, _ = grids.corpus_and_queries(N_CORPUS + 5, N_QUERIES,
                                              seed=12)
        svc.build(corpus2)
        st = svc.stats
        assert st.inserted == st.insert_batches == 0
        assert st.deleted == st.delete_batches == 0
        assert st.compactions == st.auto_compactions == st.rebalances == 0
        assert st.insert_ms == st.compact_ms == st.auto_compact_ms == 0.0
        assert sum(st.shard_occupancy) == svc.index.size == N_CORPUS + 5
        # post-rebuild history starts from zero: one insert, no ghosts
        svc.insert(ins1)
        assert st.inserted == N_INS1 and st.insert_batches == 1
        assert st.compactions == 0 and st.auto_compactions == 0
        assert sum(st.shard_occupancy) == N_CORPUS + 5 + N_INS1

    def test_host_service_is_rebuild_only(self):
        corpus, _ = _data(10)
        svc = LSHService(_family("srp"), metric="cosine",
                         device=False).build(corpus)
        ins1, _ = _inserts()
        with pytest.raises(TypeError):
            svc.insert(ins1)
        with pytest.raises(TypeError):
            svc.delete([0])
        with pytest.raises(TypeError):
            svc.compact()
        with pytest.raises(TypeError):
            svc.rebalance()

    def test_device_service_rejects_rebalance(self):
        corpus, _ = _data(10)
        svc = LSHService(_family("srp"), metric="cosine").build(corpus)
        with pytest.raises(TypeError):
            svc.rebalance()

    def test_recall_against_effective_corpus(self):
        from repro.core import recall_at_k
        corpus, queries = _data(11)
        idx = DeviceLSHIndex(_family("cp-e2lsh"),
                             metric="euclidean").build(corpus)
        _mutate(idx, corpus)
        stats = recall_at_k(idx, queries, topk=TOPK)
        assert 0.0 <= stats["recall"] <= 1.0
        assert stats["corpus_size"] == idx.size


@pytest.mark.slow
class TestShardMapStreamingMultiDevice:
    """Force a 4-device host platform in a subprocess so the shard_map path
    of the shard-native mutated store runs in every tier-1 invocation (the
    flag must be set before jax initialises — same pattern as
    test_index_sharded.py). The CI 4-device leg runs this whole file
    in-process, where ``_assert_query_path`` makes any silent vmap
    fallback a loud failure."""

    def test_shard_map_mutation_parity_bit_identical(self):
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import DeviceLSHIndex, ShardedLSHIndex, make_family
        assert len(jax.devices()) == 4
        DIMS = (4, 4, 4)
        kc, kq, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 4)
        corpus = jax.random.normal(kc, (67,) + DIMS)
        queries = corpus[:4] + 0.1 * jax.random.normal(kq, (4,) + DIMS)
        ins1 = jax.random.normal(k1, (12,) + DIMS)
        ins2 = jax.random.normal(k2, (9,) + DIMS)
        dels1, dels2 = [3, 40, 66, 75], [0, 33, 70]
        eff = np.concatenate([np.asarray(corpus), np.asarray(ins1)])
        eff = np.delete(eff, dels1, axis=0)
        eff = np.concatenate([eff, np.asarray(ins2)])
        eff = np.delete(eff, dels2, axis=0)

        def check(g, w, msg, scores_exact):
            np.testing.assert_array_equal(np.asarray(g[0]), np.asarray(w[0]),
                                          err_msg=msg)
            np.testing.assert_array_equal(np.asarray(g[2]), np.asarray(w[2]),
                                          err_msg=msg)
            gs, ws = np.asarray(g[1]), np.asarray(w[1])
            if scores_exact:
                np.testing.assert_array_equal(gs, ws, err_msg=msg)
            else:
                fin = np.isfinite(ws)
                np.testing.assert_array_equal(np.isfinite(gs), fin)
                np.testing.assert_array_max_ulp(gs[fin], ws[fin], maxulp=16)

        for kind, metric in (("cp-e2lsh", "euclidean"), ("tt-srp", "cosine")):
            k, w = (3, 6.0) if "e2lsh" in kind else (6, 0.0)
            fam = make_family(jax.random.PRNGKey(42), kind, DIMS,
                              num_codes=k, num_tables=4, rank=2,
                              bucket_width=max(w, 1.0))
            single = DeviceLSHIndex(fam, metric=metric).build(corpus)
            single.insert(ins1); single.delete(dels1)
            single.insert(ins2); single.delete(dels2)
            d = single.query_batch(queries, topk=5)
            for s in (2, 4):
                sharded = ShardedLSHIndex(fam, metric=metric,
                                          shards=s).build(corpus)
                assert sharded.mesh is not None, (kind, s)
                assert sharded.query_path == "shard_map", (kind, s)
                sharded.insert(ins1); sharded.delete(dels1)
                sharded.insert(ins2); sharded.delete(dels2)
                # routed slabs live on the mesh, exactly like the base
                for seg in [sharded.store.base] + sharded.store.deltas:
                    assert seg.sorted_keys.sharding.spec[0] == "shard"
                fresh = ShardedLSHIndex(fam, metric=metric,
                                        shards=s).build(jnp.asarray(eff))
                g = sharded.query_batch(queries, topk=5)
                f = fresh.query_batch(queries, topk=5)
                check(g, f, (kind, metric, s, "uncompacted"), False)
                check(g, d, (kind, metric, s, "vs-device"), False)
                sharded.compact()          # shard-local fold
                assert sharded.query_path == "shard_map"
                g = sharded.query_batch(queries, topk=5)
                check(g, f, (kind, metric, s, "compacted"), False)
                sharded.rebalance()        # contiguous split: bit-exact
                g = sharded.query_batch(queries, topk=5)
                check(g, f, (kind, metric, s, "rebalanced"), True)
        print("shard_map streaming parity ok")
        """
        assert "shard_map streaming parity ok" in _run_sub(code)


def _run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
