"""Integration tests: the multi-table LSH index end-to-end (build/query/recall)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LSHIndex, brute_force, make_family, recall_at_k,
                        CPTensor, cp_random_data)

DIMS = (6, 6, 6)


def _corpus_with_planted_neighbors(key, n=400, n_queries=10, noise=0.05):
    """Dense corpus where query i's true NN is corpus item i (planted)."""
    kc, kq = jax.random.split(key)
    corpus = jax.random.normal(kc, (n,) + DIMS)
    queries = corpus[:n_queries] + noise * jax.random.normal(kq, (n_queries,) + DIMS)
    return corpus, queries


class TestIndexDense:
    def test_planted_neighbor_found_euclidean(self):
        corpus, queries = _corpus_with_planted_neighbors(jax.random.PRNGKey(0))
        fam = make_family(jax.random.PRNGKey(1), "cp-e2lsh", DIMS,
                          num_codes=6, num_tables=8, rank=2, bucket_width=6.0)
        idx = LSHIndex(fam, metric="euclidean").build(corpus)
        found = 0
        for i in range(queries.shape[0]):
            ids, _, _ = idx.query(queries[i], topk=1)
            found += int(ids.size and ids[0] == i)
        assert found >= 8  # >= 80% of planted NNs

    def test_planted_neighbor_found_cosine(self):
        corpus, queries = _corpus_with_planted_neighbors(jax.random.PRNGKey(2))
        fam = make_family(jax.random.PRNGKey(3), "cp-srp", DIMS,
                          num_codes=10, num_tables=8, rank=2)
        idx = LSHIndex(fam, metric="cosine").build(corpus)
        found = 0
        for i in range(queries.shape[0]):
            ids, _, _ = idx.query(queries[i], topk=1)
            found += int(ids.size and ids[0] == i)
        assert found >= 8

    def test_candidates_shrink_vs_corpus(self):
        """LSH must prune: mean candidate set far below corpus size."""
        corpus, queries = _corpus_with_planted_neighbors(jax.random.PRNGKey(4))
        fam = make_family(jax.random.PRNGKey(5), "tt-srp", DIMS,
                          num_codes=12, num_tables=4, rank=2)
        idx = LSHIndex(fam, metric="cosine").build(corpus)
        # Only the planted NN is genuinely close; the rest of any top-k are
        # near-orthogonal and correctly pruned -> measure recall@1.
        stats = recall_at_k(idx, queries, topk=1)
        assert stats["mean_candidates"] < 0.5 * idx.size
        assert stats["recall"] >= 0.8

    def test_brute_force_is_exact(self):
        corpus, queries = _corpus_with_planted_neighbors(jax.random.PRNGKey(6))
        ids, scores = brute_force("euclidean", queries[0], corpus, topk=3)
        d = np.linalg.norm(np.asarray(corpus).reshape(corpus.shape[0], -1)
                           - np.asarray(queries[0]).reshape(1, -1), axis=1)
        np.testing.assert_array_equal(ids, np.argsort(d)[:3])


class TestIndexCPFormat:
    def test_cp_corpus_roundtrip(self):
        """Corpus held in CP format end-to-end (the paper's efficient regime)."""
        n = 200
        key = jax.random.PRNGKey(7)
        keys = jax.random.split(key, n)
        factors = [jnp.stack([cp_random_data(k, DIMS, 3).factors[m] for k in keys])
                   for m in range(3)]
        corpus = CPTensor(factors=tuple(factors), scale=1.0)
        fam = make_family(jax.random.PRNGKey(8), "cp-e2lsh", DIMS,
                          num_codes=4, num_tables=6, rank=2, bucket_width=8.0)
        idx = LSHIndex(fam, metric="euclidean").build(corpus)
        q = jax.tree.map(lambda a: a[17], corpus)  # exact member -> must find itself
        ids, scores, _ = idx.query(q, topk=1)
        assert ids.size >= 1 and ids[0] == 17
        assert scores[0] < 1e-3
