"""Mesh-sharded index vs the single-device DeviceLSHIndex: candidate sets
and top-k results must be identical for every family kind, both metrics,
S in {1, 2, 4} shard counts, and batch sizes 1 and >1 (shard-count
invariance). Both indexes are segment stores — the sharded one holds a
``ShardedSegment`` base laid over the mesh axis (streaming-mutation
coverage lives in tests/test_index_mutation.py). The corpus size is coprime
to the shard counts so the padded last shard is always exercised.

On a multi-device host platform (the CI leg runs this file with
XLA_FLAGS=--xla_force_host_platform_device_count=4) every shard count takes
the shard_map path and results — scores included — are bit-identical to the
single-device program. On one device the S>1 cells fall back to the
vmapped program: ids / candidate sets / counts are still exactly equal,
but scores carry cross-program float-reduction wobble (amplified by the
||x||^2+||y||^2-2<x,y> cancellation, ~1e-4 relative) and are compared with
a tight tolerance. A subprocess test forces the 4-device platform so the
shard_map path runs in every tier-1 invocation (same pattern as
test_distributed.py — the flag must not leak into this process).
"""

import os
import subprocess
import sys
import textwrap

import grids
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from grids import ALL_KINDS, DIMS, SHARD_COUNTS
from repro.core import (CPTensor, DeviceLSHIndex, ShardedLSHIndex,
                        ShardedSegment, cp_random_data, make_family)
from repro.serving.lsh_service import LSHService, build_service

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N_CORPUS, N_QUERIES, TOPK = 67, 4, 5   # 67: coprime to 2 and 4 -> padding


def _data(seed=0):
    return grids.corpus_and_queries(N_CORPUS, N_QUERIES, seed=seed)


def _family(kind):
    return grids.grid_family(kind)


def _assert_parity(single, sharded, queries, topk=TOPK):
    """query_batch of both indexes must agree; scores bit-equal on the
    shard_map path, tight-tolerance on the vmapped fallback."""
    d_ids, d_sc, d_nc = (np.asarray(a)
                         for a in single.query_batch(queries, topk=topk))
    s_ids, s_sc, s_nc = (np.asarray(a)
                         for a in sharded.query_batch(queries, topk=topk))
    np.testing.assert_array_equal(d_ids, s_ids)
    np.testing.assert_array_equal(d_nc, s_nc)
    if sharded.mesh is not None:
        np.testing.assert_array_equal(d_sc, s_sc)
    else:
        np.testing.assert_allclose(d_sc, s_sc, rtol=3e-4, atol=1e-4)


@pytest.mark.parametrize("kind,metric", grids.cell_params())
class TestShardCountInvariance:
    def test_topk_and_candidates_match_device(self, kind, metric):
        corpus, queries = _data()
        fam = _family(kind)
        single = DeviceLSHIndex(fam, metric=metric).build(corpus)
        for s in SHARD_COUNTS:
            sharded = ShardedLSHIndex(fam, metric=metric,
                                      shards=s).build(corpus)
            for batch in (1, N_QUERIES):
                _assert_parity(single, sharded, queries[:batch])
            for i in range(N_QUERIES):
                np.testing.assert_array_equal(
                    single.candidates(queries[i]),
                    sharded.candidates(queries[i]), err_msg=(kind, metric, s))


class TestShardedIndexContract:
    def test_more_shards_than_corpus_items(self):
        """n < S leaves whole shards as padding; results still match."""
        corpus, queries = _data(1)
        fam = _family("cp-e2lsh")
        tiny = corpus[:3]
        single = DeviceLSHIndex(fam, metric="euclidean").build(tiny)
        sharded = ShardedLSHIndex(fam, metric="euclidean",
                                  shards=4).build(tiny)
        _assert_parity(single, sharded, queries)

    def test_cp_format_corpus(self):
        """Pytree (CP factor) corpora shard leaf-wise like dense ones."""
        n = 40
        keys = jax.random.split(jax.random.PRNGKey(7), n)
        factors = [jnp.stack([cp_random_data(k, DIMS, 3).factors[m]
                              for k in keys]) for m in range(3)]
        corpus = CPTensor(factors=tuple(factors), scale=1.0)
        fam = _family("cp-e2lsh")
        single = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        sharded = ShardedLSHIndex(fam, metric="euclidean",
                                  shards=3).build(corpus)
        queries = jax.tree.map(lambda a: a[:4], corpus)
        _assert_parity(single, sharded, queries)
        ids, scores, _ = sharded.query(jax.tree.map(lambda a: a[17], corpus),
                                       topk=1)
        assert ids.size == 1 and ids[0] == 17
        assert scores[0] < 1e-3

    def test_empty_candidate_rows_fill(self):
        """A query hitting no bucket in any shard -> -1 / +inf fill."""
        corpus, _ = _data(2)
        fam = make_family(jax.random.PRNGKey(42), "cp-e2lsh", DIMS,
                          num_codes=3, num_tables=4, rank=2, bucket_width=1.0)
        sharded = ShardedLSHIndex(fam, metric="euclidean",
                                  shards=2).build(corpus)
        far = 1e3 * jnp.ones(DIMS)
        assert sharded.candidates(far).size == 0, "fixture must be empty"
        ids, scores, n_cand = sharded.query_batch(far[None], topk=TOPK)
        assert int(n_cand[0]) == 0
        assert (np.asarray(ids[0]) == -1).all()
        assert np.isinf(np.asarray(scores[0])).all()
        got, _, n = sharded.query(far, topk=TOPK)
        assert got.size == 0 and n == 0

    def test_shards_must_be_positive(self):
        with pytest.raises(ValueError):
            ShardedLSHIndex(_family("srp"), metric="cosine", shards=0)

    def test_fresh_build_store_shape(self):
        """A fresh sharded build is a pristine store whose base is one
        ShardedSegment; pad slots are born dead (never queryable)."""
        corpus, _ = _data(6)
        sharded = ShardedLSHIndex(_family("cp-e2lsh"), metric="euclidean",
                                  shards=4).build(corpus)
        store = sharded.store
        assert isinstance(store.base, ShardedSegment)
        assert not store.deltas and not store.mutated
        n_s = -(-N_CORPUS // 4)
        assert store.base.shard_size == n_s
        assert store.base.slots == 4 * n_s > N_CORPUS      # padded
        assert store.n_live == N_CORPUS == sharded.size
        assert not store.live_host[N_CORPUS:].any()        # pads dead
        live, eff = store._luts[0]
        assert live.shape == (4, n_s + 1)
        assert not np.asarray(live[:, -1]).any()           # sentinel column
        np.testing.assert_array_equal(
            np.asarray(eff).reshape(-1)[:N_CORPUS], np.arange(N_CORPUS))

    def test_coarse_family_warning_both_layouts(self):
        """The cap*L > n warning fires from the shared segment-build path
        for the device AND the sharded layout (the sharded build used to
        skip it)."""
        corpus, _ = _data(7)
        fam = make_family(jax.random.PRNGKey(3), "srp", DIMS, num_codes=1,
                          num_tables=6, rank=2)   # 1-bit keys: huge buckets
        with pytest.warns(UserWarning, match="DeviceLSHIndex"):
            DeviceLSHIndex(fam, metric="cosine").build(corpus)
        with pytest.warns(UserWarning, match="ShardedLSHIndex"):
            ShardedLSHIndex(fam, metric="cosine", shards=2).build(corpus)

    def test_keep_corpus_false_still_serves_queries(self):
        """Queries re-rank against the sharded slices only; the unsharded
        copy is a reference-API convenience that can be dropped."""
        corpus, queries = _data(5)
        fam = _family("cp-e2lsh")
        single = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        sharded = ShardedLSHIndex(fam, metric="euclidean", shards=2,
                                  keep_corpus=False).build(corpus)
        assert sharded.corpus is None
        _assert_parity(single, sharded, queries)


class TestShardedService:
    def test_service_shards_knob_matches_device_service(self):
        corpus, queries = _data(3)
        fam = _family("tt-e2lsh")
        plain = LSHService(fam, metric="euclidean").build(corpus)
        sharded = LSHService(fam, metric="euclidean", shards=2).build(corpus)
        assert isinstance(sharded.index, ShardedLSHIndex)
        p_ids, _, p_nc = plain.query_arrays(queries, topk=TOPK)
        s_ids, _, s_nc = sharded.query_arrays(queries, topk=TOPK)
        np.testing.assert_array_equal(p_ids, s_ids)   # ids are corpus-global
        np.testing.assert_array_equal(p_nc, s_nc)
        assert sharded.stats.queries == N_QUERIES

    def test_build_service_passthrough_and_host_rejects_shards(self):
        corpus, queries = _data(4)
        svc = build_service(jax.random.PRNGKey(0), "cp-srp", DIMS, corpus,
                            num_codes=6, num_tables=4, rank=2, shards=2)
        assert isinstance(svc.index, ShardedLSHIndex)
        assert svc.index.shards == 2
        out = svc.query_batch(queries, topk=3)
        assert len(out) == N_QUERIES
        with pytest.raises(ValueError):
            LSHService(_family("srp"), device=False, shards=2)


@pytest.mark.slow
class TestShardMapPathMultiDevice:
    """Force a 4-device host platform in a subprocess (the flag must be set
    before jax initialises, so it cannot run in this process).

    ``slow``: each test pays a fresh-interpreter jax import + compile (the
    three together are the longest single items in the suite), and the
    dedicated 4-device CI leg covers the same shard_map path in-process on
    every push — the fast leg skips only this subprocess duplicate, the
    full leg still runs it so a plain local ``make test`` keeps the
    coverage with no CI dependency."""

    def test_shard_map_parity_bit_identical(self):
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import DeviceLSHIndex, ShardedLSHIndex, make_family
        assert len(jax.devices()) == 4
        DIMS = (4, 4, 4)
        kc, kq = jax.random.split(jax.random.PRNGKey(0))
        corpus = jax.random.normal(kc, (67,) + DIMS)
        queries = corpus[:4] + 0.1 * jax.random.normal(kq, (4,) + DIMS)
        for kind, metric in (("cp-e2lsh", "euclidean"), ("tt-srp", "cosine")):
            k, w = (3, 6.0) if "e2lsh" in kind else (6, 0.0)
            fam = make_family(jax.random.PRNGKey(42), kind, DIMS,
                              num_codes=k, num_tables=4, rank=2,
                              bucket_width=max(w, 1.0))
            single = DeviceLSHIndex(fam, metric=metric).build(corpus)
            for s in (2, 4):
                sharded = ShardedLSHIndex(fam, metric=metric,
                                          shards=s).build(corpus)
                assert sharded.mesh is not None, (kind, s)
                assert sharded.sorted_keys.sharding.spec[0] == "shard"
                for batch in (1, 4):
                    d = single.query_batch(queries[:batch], topk=5)
                    g = sharded.query_batch(queries[:batch], topk=5)
                    for a, b in zip(d, g):   # ids, scores, n_cand: bit-equal
                        np.testing.assert_array_equal(
                            np.asarray(a), np.asarray(b),
                            err_msg=(kind, metric, s, batch))
        print("shard_map parity ok")
        """
        assert "shard_map parity ok" in _run_sub(code)

    def test_rule_context_places_index_on_data_axis(self):
        """Inside axis_rules the lsh_shard rule resolves through the same
        machinery as the model dims: the index lands on the data axis."""
        code = """
        import jax, numpy as np
        from repro.core import DeviceLSHIndex, ShardedLSHIndex, make_family
        from repro.distributed.sharding import axis_rules
        from repro.launch.mesh import make_local_mesh
        DIMS = (4, 4, 4)
        corpus = jax.random.normal(jax.random.PRNGKey(0), (66,) + DIMS)
        fam = make_family(jax.random.PRNGKey(1), "cp-e2lsh", DIMS,
                          num_codes=3, num_tables=4, rank=2, bucket_width=6.0)
        single = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        mesh = make_local_mesh(2, 2)
        with axis_rules(mesh):
            sharded = ShardedLSHIndex(fam, metric="euclidean",
                                      shards=2).build(corpus)
            assert sharded.mesh_axis == "data", sharded.mesh_axis
            d = single.query_batch(corpus[:3], topk=5)
            g = sharded.query_batch(corpus[:3], topk=5)
        for a, b in zip(d, g):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("rule context ok")
        """
        assert "rule context ok" in _run_sub(code)

    def test_dryrun_lsh_index_cell_small_mesh(self):
        """The dry-run cost-accounting cell for the sharded index compiles
        on a shrunk production mesh, reports sane numbers, and its record
        flows through the roofline/report consumers (analyse + both
        tables), which glob every experiments/dryrun/*.json."""
        code = """
        import os
        os.environ.setdefault("XLA_FLAGS", "")
        import json, tempfile
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_lib
        from repro.launch import report, roofline
        mesh_lib.make_production_mesh = lambda multi_pod=False: mesh_lib._mesh(
            (2, 2, 2) if multi_pod else (2, 4),
            ("pod", "data", "model") if multi_pod else ("data", "model"))
        dr.make_production_mesh = mesh_lib.make_production_mesh
        for mp in (False, True):
            rec = dr.lower_lsh_index_cell(mp, corpus_n=1 << 12, batch=64)
            assert rec["status"] == "ok", rec
            assert rec["shards"] == 2 and rec["shard_axis"] == "data"
            assert rec["n_chips"] == 8  # (2,2,2) and (2,4) shrunk meshes
            assert rec["cost"]["flops_per_device"] > 0
            assert rec["memory"]["peak_per_device_bytes"] > 0
            hp = rec["hash_program"]   # the fused hash profiled alongside
            # dense corpus -> the XLA path executes even when the pallas
            # backend is forced (e.g. the REPRO_HASH_BACKEND=pallas CI leg)
            assert hp["backend"] == "xla"
            assert hp["batch"] == 64
            assert hp["cost"]["flops_per_device"] > 0
            # the T-wide multi-probe query profiled alongside: it prices
            # the key expansion + T probe windows per table, so it must
            # read strictly more probe bytes than the single-probe cell
            mp_rec = rec["multiprobe_program"]
            assert mp_rec["probes"] == 8
            assert (mp_rec["cost"]["flops_per_device"]
                    > rec["cost"]["flops_per_device"])
            # the shard-local mutation programs profiled alongside: the
            # routed slab insert (hash included) and the per-shard compact
            # fold — and neither may schedule a collective (shard-local
            # by construction)
            ip, cp = rec["insert_program"], rec["compact_program"]
            assert ip["slab_size"] == rec["insert_program"]["insert_n"] // 2
            assert ip["cost"]["flops_per_device"] > 0
            assert cp["folded_slots_per_shard"] > 0
            assert all(v["count"] == 0
                       for v in cp["collectives"].values()), cp["collectives"]
            # the swap's shadow build (prepare_rebalance): the global
            # sequence-order gather + re-partition + re-sort — the one
            # mutation program allowed to carry cross-shard traffic
            sw = rec["swap_build_program"]
            assert sw["live_n"] == rec["corpus_n"] + ip["insert_n"]
            assert sw["new_shard_size"] > 0
            assert sw["cost"]["bytes_accessed_per_device"] > 0
            row = roofline.analyse(rec)
            assert row["bottleneck"] in ("compute", "memory", "collective")
            assert row["roofline_mfu"] is None  # no model-flops notion
            # every sub-program expands to its own analysable record
            subs = roofline.expand(rec)
            # the fused query-to-candidates program profiled alongside:
            # end-to-end hash -> probe -> re-rank -> top-k over base +
            # delta at T probes, so it must price at least the T-wide
            # base-only query
            fq = rec["fused_query_program"]
            assert fq["probes"] == 8 and fq["batch"] == 64
            assert fq["probe_backend"] in ("xla", "pallas")
            assert (fq["cost"]["flops_per_device"]
                    >= mp_rec["cost"]["flops_per_device"])
            assert [r["arch"] for r in subs[1:]] == [
                "lsh-index:delta_probe", "lsh-index:multiprobe_program",
                "lsh-index:fused_query_program",
                "lsh-index:hash_program", "lsh-index:insert_program",
                "lsh-index:compact_program",
                "lsh-index:swap_build_program"]
            for r in subs[1:]:
                assert roofline.analyse(r)["roofline_mfu"] is None
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "lsh_index__16x16.json"), "w") as f:
                json.dump(rec | {"mesh": "16x16"}, f)
            assert "lsh-index" in roofline.table(d)
            assert "lsh-index:insert_program" in roofline.table(d)
            assert "lsh-index:compact_program" in report.dryrun_table(d)
            assert "fewer probe bytes" in report.roofline_table(d)
            assert "fewer mutation" in report.roofline_table(d) or \
                "shard-local" in report.roofline_table(d)
        print("lsh dryrun ok")
        """
        assert "lsh dryrun ok" in _run_sub(code, devices=8)


def _run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
