"""The shared parametrization grid of the layout/parity suites.

Every cross-layout test file sweeps the same 6 family kinds x 2 metrics
over the same small canonical families and clustered corpus/query fixtures.
That grid used to be copy-pasted per file (test_index_device,
test_index_sharded, test_index_mutation, test_hash_backends) and drifted
one constant at a time; it lives here once so a new layout suite (e.g.
tests/test_multiprobe.py) states *which* cells it covers, not how to
build them.

Not a test module — pytest prepends this directory to sys.path, so suites
just ``import grids``.
"""

import jax
import pytest

from repro.core import make_family
from repro.core.lsh import ALL_KINDS, E2LSH_KINDS, SRP_KINDS  # noqa: F401
                                      # (re-exported: the grid axes)

METRICS = ("euclidean", "cosine")
DIMS = (4, 4, 4)
SHARD_COUNTS = (1, 2, 4)   # corpus sizes are kept coprime to these so the
                           # padded last shard is always exercised


def metric_for(kind: str) -> str:
    """The metric the kind's collision guarantees target (SRP hashes
    angles -> cosine; E2LSH hashes offsets -> euclidean)."""
    return "cosine" if kind.endswith("srp") else "euclidean"


def cell_params(kinds=ALL_KINDS, metrics=METRICS):
    """The kind x metric grid as parametrize cells, with every
    *non-canonical* metric pairing marked ``slow``.

    The canonical-metric half (SRP kinds -> cosine, E2LSH kinds ->
    euclidean) already drives both scoring paths across the kind axis, so
    the cross-metric half re-checks metric handling the fast leg has
    covered with a different hash family in front of it — real coverage,
    but redundant per-push. ``make test`` / the full CI leg still sweeps
    the whole grid; ``make test-fast`` / the fast leg runs the canonical
    half. Use as ``@pytest.mark.parametrize("kind,metric", cell_params())``
    in place of stacking a kind and a metric decorator.
    """
    return [pytest.param(kind, metric,
                         marks=() if metric == metric_for(kind)
                         else (pytest.mark.slow,))
            for kind in kinds for metric in metrics]


def grid_family(kind: str, dims=DIMS, num_tables: int = 4, rank: int = 2,
                seed: int = 42, hash_backend: str = "auto"):
    """The canonical small test family of the parity suites.

    (num_codes, bucket_width) are tuned per hash type so every kind lands
    a useful bucket structure on the ~50-70 item fixtures: K=3 wide-bucket
    E2LSH, K=6 SRP. Keep in sync with nothing — this IS the definition the
    suites share.
    """
    k, w = (3, 6.0) if "e2lsh" in kind else (6, 0.0)
    return make_family(jax.random.PRNGKey(seed), kind, dims, num_codes=k,
                       num_tables=num_tables, rank=rank,
                       bucket_width=max(w, 1.0), hash_backend=hash_backend)


def corpus_and_queries(n_corpus: int, n_queries: int, dims=DIMS,
                       seed: int = 0, noise: float = 0.1):
    """Gaussian corpus + queries perturbed off its first rows, so every
    query has a planted near neighbour (the fixture all parity suites
    share)."""
    kc, kq = jax.random.split(jax.random.PRNGKey(seed))
    corpus = jax.random.normal(kc, (n_corpus,) + dims)
    queries = corpus[:n_queries] + noise * jax.random.normal(
        kq, (n_queries,) + dims)
    return corpus, queries


def assert_query_path(index) -> None:
    """Shard-native coverage must fail loudly: whenever the platform has
    enough devices for every shard, the shard_map program MUST be the one
    that executes — a silent vmap fallback is a bug, not a degradation."""
    want = "shard_map" if len(jax.devices()) >= index.shards else "vmap"
    assert index.query_path == want, (
        f"expected the {want} query path on {len(jax.devices())} devices "
        f"with S={index.shards}, got {index.query_path}")
