"""Property tests for model components: SSD chunked==recurrent, chunked
flash attention == naive softmax, MoE dispatch == dense reference, LSH
attention retrieval quality."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as params_lib
from repro.models.attention import chunked_attention
from repro.models.lsh_attention import lsh_attention_prefill, srp_bucket_codes
from repro.models.moe import moe_block, moe_block_dense_reference
from repro.models.ssm import ssd_chunked, ssd_decode_step


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 64])
    def test_chunked_equals_recurrent(self, chunk):
        """The SSD chunked algorithm must equal the naive recurrence."""
        key = jax.random.PRNGKey(0)
        b, s, h, p, n = 2, 33, 3, 4, 5  # deliberately not chunk-aligned
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        bm = jax.random.normal(ks[3], (b, s, h, n))
        cm = jax.random.normal(ks[4], (b, s, h, n))

        y_chunk, final = ssd_chunked(x, dt, a, bm, cm, chunk)

        state = jnp.zeros((b, h, p, n))
        ys = []
        for t in range(s):
            y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], a,
                                         bm[:, t], cm[:, t])
            ys.append(y_t)
        y_ref = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(y_chunk, y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(final, state, rtol=2e-4, atol=2e-4)


class TestChunkedAttention:
    def _naive(self, q, k, v, causal, window):
        b, s, h, hd = q.shape
        kvh = k.shape[2]
        g = h // kvh
        qg = q.reshape(b, s, kvh, g, hd)
        sc = jnp.einsum("bskgh,btkh->bskgt", qg, k) / math.sqrt(hd)
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        mask = jnp.ones((s, s), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bskgt,btkh->bskgh", p, v).reshape(b, s, h, hd)

    @pytest.mark.parametrize("causal,window,kv_chunk", [
        (True, 0, 8), (True, 0, 16), (False, 0, 8), (True, 5, 8),
        (True, 12, 32),
    ])
    def test_vs_naive(self, causal, window, kv_chunk):
        key = jax.random.PRNGKey(2)
        b, s, h, kvh, hd = 2, 29, 4, 2, 8  # ragged vs chunk, GQA group 2
        kq, kk, kv_ = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, s, h, hd))
        k = jax.random.normal(kk, (b, s, kvh, hd))
        v = jax.random.normal(kv_, (b, s, kvh, hd))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        got = chunked_attention(q, k, v, pos, pos, causal=causal,
                                window=window, kv_chunk=kv_chunk)
        want = self._naive(q, k, v, causal, window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestMoE:
    def test_dispatch_equals_dense_reference(self):
        """With ample capacity (no drops) slot dispatch == dense reference."""
        cfg = dataclasses.replace(get_config("mixtral-8x22b", "smoke"),
                                  capacity_factor=8.0)
        key = jax.random.PRNGKey(3)
        params = params_lib.init_params(cfg, key)
        lp = jax.tree.map(lambda a: a[0], params["blocks"])
        x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
        got, aux = moe_block(cfg, lp, x)
        want = moe_block_dense_reference(cfg, lp, x)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
        assert float(aux) > 0.0

    def test_capacity_drops_are_bounded(self):
        """With tight capacity the outputs differ only on dropped tokens."""
        cfg = dataclasses.replace(get_config("mixtral-8x22b", "smoke"),
                                  capacity_factor=1.0)
        key = jax.random.PRNGKey(4)
        params = params_lib.init_params(cfg, key)
        lp = jax.tree.map(lambda a: a[0], params["blocks"])
        x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
        got, _ = moe_block(cfg, lp, x)
        want = moe_block_dense_reference(cfg, lp, x)
        frac_equal = float(jnp.mean(
            (jnp.abs(got - want) < 1e-4).all(axis=-1).astype(jnp.float32)))
        assert frac_equal > 0.5  # most tokens still routed identically

    def test_shared_expert_path(self):
        cfg = get_config("llama4-maverick-400b-a17b", "smoke")
        key = jax.random.PRNGKey(5)
        params = params_lib.init_params(cfg, key)
        lp = jax.tree.map(lambda a: a[0], params["blocks"])
        x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
        got, _ = moe_block(cfg, lp, x)
        assert got.shape == x.shape and bool(jnp.isfinite(got).all())


class TestLSHAttention:
    def test_bucket_codes_match_core_srp(self):
        """srp_bucket_codes must be the paper's CP-SRP (Definition 12):
        sign of the CP-Rademacher projection of the matricized vector."""
        from repro.core import CPTensor, project, CPProjection
        key = jax.random.PRNGKey(6)
        k1, k2, kx = jax.random.split(key, 3)
        K, m1, m2, r = 5, 4, 8, 3
        f1 = jax.random.normal(k1, (K, m1, r))
        f2 = jax.random.normal(k2, (K, m2, r))
        x = jax.random.normal(kx, (m1 * m2,))
        codes = srp_bucket_codes(x, f1, f2)
        proj = CPProjection(factors=(jnp.sign(f1), jnp.sign(f2)),
                            scale=1.0 / math.sqrt(r))
        vals = project(proj, x.reshape(m1, m2))
        bits = (np.asarray(vals) > 0).astype(np.int32)
        want = int((bits * (1 << np.arange(K))).sum())
        assert int(codes) == want

    def test_same_vector_same_bucket(self):
        key = jax.random.PRNGKey(7)
        f1 = jax.random.normal(key, (8, 4, 2))
        f2 = jax.random.normal(jax.random.PRNGKey(8), (8, 8, 2))
        x = jax.random.normal(jax.random.PRNGKey(9), (10, 32))
        c1 = srp_bucket_codes(x, f1, f2)
        c2 = srp_bucket_codes(x * 3.7, f1, f2)  # scale-invariant (sign)
        np.testing.assert_array_equal(c1, c2)

    def test_prefill_recovers_strong_matches(self):
        """Planted high-similarity q/k pairs must dominate LSH attention
        output: compare to exact attention on those rows."""
        cfg = get_config("phi3-mini-3.8b", "smoke")
        key = jax.random.PRNGKey(10)
        b, s, h, hd = 1, 64, cfg.n_heads, cfg.hd
        kq, kk, kv_, kp1, kp2 = jax.random.split(key, 5)
        k = jax.random.normal(kk, (b, s, h, hd))
        v = jax.random.normal(kv_, (b, s, h, hd))
        # queries strongly aligned with the key 8 positions earlier
        q = jnp.roll(k, 8, axis=1) * 4.0 + 0.1 * jax.random.normal(kq, (b, s, h, hd))
        proj = {"f1": jax.random.normal(kp1, (cfg.lsh_num_hashes, 4, cfg.lsh_rank)),
                "f2": jax.random.normal(kp2, (cfg.lsh_num_hashes, 4, cfg.lsh_rank))}
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out = lsh_attention_prefill(cfg, proj, q, k, v, pos)
        exact = chunked_attention(q, k, v, pos, pos, causal=True)
        # rows late enough to have their planted match in-context
        err = jnp.abs(out[:, 16:] - exact[:, 16:]).mean()
        base = jnp.abs(exact[:, 16:]).mean()
        assert float(err) < 0.35 * float(base), (float(err), float(base))
