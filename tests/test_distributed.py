"""Distribution tests.

Multi-device tests run in SUBPROCESSES with XLA_FLAGS (host-platform device
count) so the main test process keeps its single real device — the dry-run
flag must never leak into conftest/pyproject (see the system contract in
launch/dryrun.py)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed import sharding as sh
from repro.launch.mesh import make_local_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def test_rules_noop_without_context(self):
        x = jax.numpy.ones((4, 4))
        assert sh.shard(x, "batch", "embed") is x

    def test_resolution_on_trivial_mesh(self):
        # On a 1x1 mesh every size divides -> axes resolve (equivalent to
        # replication); unknown names resolve to None. The real divisibility
        # fallback is exercised on an 8-device mesh in test_axis_used_once.
        mesh = make_local_mesh(1, 1)
        with sh.axis_rules(mesh) as ctx:
            spec = sh.resolve_spec(("batch", "mlp"), (3, 5))
            assert spec == jax.sharding.PartitionSpec("data", "model")
            assert sh.resolve_spec(("nonexistent",), (7,)) == \
                jax.sharding.PartitionSpec(None)
            assert not ctx.fallbacks

    def test_axis_used_once_per_spec(self):
        code = """
        import os
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import axis_rules, resolve_spec
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(2, 4)
        with axis_rules(mesh):
            # both "mlp" and "heads" map to "model": second one must drop
            spec = resolve_spec(("mlp", "heads"), (8, 8))
            assert spec == P("model", None), spec
            # divisibility fallback: 6 % 4 != 0 -> replicated
            spec = resolve_spec(("batch", "mlp"), (4, 6))
            assert spec == P("data", None), spec
        print("ok")
        """
        assert "ok" in run_sub(code)


class TestDistributedTrainStep:
    def test_sharded_train_step_matches_single_device(self):
        """Same seed/batch: a (2,4)-mesh pjit train step must match the
        unsharded step numerically (moe arch exercises expert sharding)."""
        code = """
        import os
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data.synthetic import DataConfig, batch_at
        from repro.distributed.sharding import axis_rules, tree_shardings
        from repro.training import optimizer as opt_lib
        from repro.training.train_loop import (TrainConfig, init_state,
                                               make_train_step, state_axes)
        cfg = get_config("mixtral-8x22b", "smoke")
        tc = TrainConfig(adamw=opt_lib.AdamWConfig(peak_lr=1e-3,
                                                   warmup_steps=2,
                                                   decay_steps=50))
        dc = DataConfig(batch_size=4, seq_len=32, seed=1)
        batch = batch_at(dc, cfg, 0)

        # single device reference
        state0, _ = init_state(cfg, tc, jax.random.PRNGKey(0))
        ref_state, ref_metrics = make_train_step(cfg, tc)(state0, batch)

        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(2, 4)
        with axis_rules(mesh):
            state1, _ = init_state(cfg, tc, jax.random.PRNGKey(0))
            sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                              x.dtype), state1)
            sh = tree_shardings(state_axes(cfg), sds)
            state1 = jax.tree.map(jax.device_put, state1, sh)
            step = jax.jit(make_train_step(cfg, tc))
            new_state, metrics = step(state1, batch)
        np.testing.assert_allclose(float(ref_metrics["loss"]),
                                   float(metrics["loss"]), rtol=1e-4)
        a = jax.tree.leaves(ref_state.params)[0]
        b = jax.tree.leaves(new_state.params)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)
        print("match ok")
        """
        assert "match ok" in run_sub(code)

    def test_dryrun_cell_small_mesh(self):
        """The dry-run machinery end-to-end on an 8-device host mesh."""
        code = """
        import os
        os.environ.setdefault("XLA_FLAGS", "")
        import jax, json
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_lib
        # shrink the production mesh for the in-test run
        mesh_lib.make_production_mesh = lambda multi_pod=False: mesh_lib._mesh(
            (2, 2, 2) if multi_pod else (2, 4),
            ("pod", "data", "model") if multi_pod else ("data", "model"))
        dr.make_production_mesh = mesh_lib.make_production_mesh
        from repro.configs import get_config
        import dataclasses
        cfg = dataclasses.replace(get_config("stablelm-3b", "smoke"))
        for mp in (False, True):
            rec = dr.lower_cell("stablelm-3b", "train_4k", mp,
                                config_variant=dataclasses.replace(
                                    cfg, n_layers=2))
            assert rec["status"] == "ok", rec
            assert rec["cost"]["flops_per_device"] > 0
        print("dryrun ok")
        """
        assert "dryrun ok" in run_sub(code)


class TestElastic:
    def test_reshard_across_meshes(self):
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.elastic import reshard
        from repro.models import params as P
        cfg = get_config("stablelm-3b", "smoke")
        params = P.init_params(cfg, jax.random.PRNGKey(0))
        axes = P.param_axes(cfg)
        from repro.launch.mesh import make_local_mesh
        m1 = make_local_mesh(2, 4)
        m2 = make_local_mesh(4, 2)
        p1 = reshard(params, axes, m1)
        p2 = reshard(p1, axes, m2)   # elastic move 2x4 -> 4x2
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params, p2)
        print("elastic ok")
        """
        assert "elastic ok" in run_sub(code)
