"""Unit + property tests: tensor formats and in-format contractions vs dense
oracles. Property-style coverage uses seeded np.random draws of shapes/ranks
(plain parametrized pytest, no extra testing dependencies)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPTensor, TTTensor, cp_rademacher, tt_rademacher,
                        cp_random_data, tt_random_data, cp_to_dense,
                        tt_to_dense, dense_to_tt, khatri_rao)
from repro.core import contractions as C

jax.config.update("jax_enable_x64", False)

PROPERTY_SEEDS = list(range(12))


def _draw_dims_ranks(seed, max_rank=4):
    """Seeded random (dims, rx, ry) draw: 2-4 modes, each dim in [2, 6]."""
    rng = np.random.default_rng(seed)
    dims = [int(d) for d in rng.integers(2, 7, size=rng.integers(2, 5))]
    rx, ry = (int(r) for r in rng.integers(1, max_rank + 1, size=2))
    return dims, rx, ry


def _key(seed):
    return jax.random.PRNGKey(seed)


class TestFormats:
    def test_cp_to_dense_matches_outer_products(self):
        key = _key(0)
        x = cp_random_data(key, (3, 4, 5), rank=2)
        dense = cp_to_dense(x)
        # manual: sum_r a_r o b_r o c_r
        a, b, c = x.factors
        want = jnp.einsum("ir,jr,kr->ijk", a, b, c)
        np.testing.assert_allclose(dense, want, rtol=1e-5)

    def test_tt_to_dense_elementwise(self):
        key = _key(1)
        x = tt_random_data(key, (3, 4, 5), rank=3)
        dense = tt_to_dense(x)
        g1, g2, g3 = x.cores
        for idx in [(0, 0, 0), (2, 3, 4), (1, 2, 3)]:
            i, j, k = idx
            want = (g1[:, i, :] @ g2[:, j, :] @ g3[:, k, :]).reshape(())
            np.testing.assert_allclose(dense[idx], want * x.scale, rtol=1e-4)

    def test_rademacher_entries_are_pm1(self):
        x = cp_rademacher(_key(2), (4, 5), rank=3)
        for f in x.factors:
            assert set(np.unique(np.asarray(f))) <= {-1.0, 1.0}
        t = tt_rademacher(_key(3), (4, 5, 6), rank=2)
        for c in t.cores:
            assert set(np.unique(np.asarray(c))) <= {-1.0, 1.0}

    def test_scales_match_definitions(self):
        # Def. 6: 1/sqrt(R); Def. 7: 1/sqrt(R^{N-1})
        assert cp_rademacher(_key(0), (4, 4, 4), rank=9).scale == pytest.approx(1 / 3)
        assert tt_rademacher(_key(0), (4, 4, 4), rank=4).scale == pytest.approx(1 / 4)

    def test_storage_sizes(self):
        # paper Tables 1-2: CP O(NdR), TT O(NdR^2)
        n, d, r = 4, 6, 3
        cp = cp_rademacher(_key(0), (d,) * n, rank=r)
        assert cp.storage_size() == n * d * r
        tt = tt_rademacher(_key(0), (d,) * n, rank=r)
        assert tt.storage_size() == 2 * d * r + (n - 2) * d * r * r

    def test_tt_svd_roundtrip(self):
        key = _key(4)
        x = jax.random.normal(key, (4, 5, 6))
        tt = dense_to_tt(x, max_rank=30)  # full rank -> exact
        np.testing.assert_allclose(tt_to_dense(tt), x, atol=1e-4)

    def test_tt_svd_truncation_monotone(self):
        x = jax.random.normal(_key(5), (5, 6, 7))
        errs = []
        for r in (1, 3, 8, 30):
            tt = dense_to_tt(x, max_rank=r)
            errs.append(float(jnp.linalg.norm(tt_to_dense(tt) - x)))
        assert errs == sorted(errs, reverse=True)
        assert errs[-1] < 1e-3

    def test_khatri_rao_shape_and_values(self):
        a = jnp.arange(6.0).reshape(3, 2)
        b = jnp.arange(8.0).reshape(4, 2)
        kr = khatri_rao([a, b])
        assert kr.shape == (12, 2)
        np.testing.assert_allclose(kr[:, 0], jnp.kron(a[:, 0], b[:, 0]))


class TestContractionsVsDense:
    """Every in-format inner product must equal the dense oracle."""

    @pytest.mark.parametrize("seed", PROPERTY_SEEDS)
    def test_cp_cp(self, seed):
        dims, rx, ry = _draw_dims_ranks(seed)
        k1, k2 = jax.random.split(_key(seed))
        x = cp_random_data(k1, dims, rx)
        y = cp_random_data(k2, dims, ry)
        want = jnp.vdot(cp_to_dense(x), cp_to_dense(y))
        np.testing.assert_allclose(C.inner_cp_cp(x, y), want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("seed", PROPERTY_SEEDS)
    def test_tt_tt(self, seed):
        dims, rx, ry = _draw_dims_ranks(seed)
        k1, k2 = jax.random.split(_key(seed))
        x = tt_random_data(k1, dims, rx)
        y = tt_random_data(k2, dims, ry)
        want = jnp.vdot(tt_to_dense(x), tt_to_dense(y))
        np.testing.assert_allclose(C.inner_tt_tt(x, y), want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("seed", PROPERTY_SEEDS)
    def test_cp_tt(self, seed):
        dims, rx, ry = _draw_dims_ranks(seed)
        k1, k2 = jax.random.split(_key(seed))
        x = cp_random_data(k1, dims, rx)
        y = tt_random_data(k2, dims, ry)
        want = jnp.vdot(cp_to_dense(x), tt_to_dense(y))
        np.testing.assert_allclose(C.inner_cp_tt(x, y), want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("seed", PROPERTY_SEEDS)
    def test_dense_cp(self, seed):
        dims, r, _ = _draw_dims_ranks(seed)
        k1, k2 = jax.random.split(_key(seed))
        x = jax.random.normal(k1, tuple(dims))
        y = cp_random_data(k2, dims, r)
        want = jnp.vdot(x, cp_to_dense(y))
        np.testing.assert_allclose(C.inner_dense_cp(x, y), want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("seed", PROPERTY_SEEDS)
    def test_dense_tt(self, seed):
        dims, r, _ = _draw_dims_ranks(seed)
        k1, k2 = jax.random.split(_key(seed))
        x = jax.random.normal(k1, tuple(dims))
        y = tt_random_data(k2, dims, r)
        want = jnp.vdot(x, tt_to_dense(y))
        np.testing.assert_allclose(C.inner_dense_tt(x, y), want, rtol=2e-4, atol=2e-5)

    def test_polymorphic_inner_consistency(self):
        dims = (3, 4, 5)
        kd, kc, kt = jax.random.split(_key(7), 3)
        xd = jax.random.normal(kd, dims)
        xc = cp_random_data(kc, dims, 3)
        xt = tt_random_data(kt, dims, 2)
        objs = {"dense": xd, "cp": xc, "tt": xt}
        dense = {"dense": xd, "cp": cp_to_dense(xc), "tt": tt_to_dense(xt)}
        for na, a in objs.items():
            for nb, b in objs.items():
                want = jnp.vdot(dense[na], dense[nb])
                np.testing.assert_allclose(C.inner(a, b), want, rtol=3e-4, atol=3e-5,
                                           err_msg=f"{na} x {nb}")

    def test_norm_distance_cosine(self):
        dims = (4, 4, 4)
        k1, k2 = jax.random.split(_key(8))
        x = cp_random_data(k1, dims, 3)
        y = tt_random_data(k2, dims, 2)
        xd, yd = cp_to_dense(x), tt_to_dense(y)
        np.testing.assert_allclose(C.norm(x), jnp.linalg.norm(xd), rtol=1e-4)
        np.testing.assert_allclose(C.distance(x, y), jnp.linalg.norm(xd - yd), rtol=1e-3)
        cs = jnp.vdot(xd, yd) / (jnp.linalg.norm(xd) * jnp.linalg.norm(yd))
        np.testing.assert_allclose(C.cosine_similarity(x, y), cs, rtol=1e-3)

    def test_jit_compatible(self):
        dims = (3, 4, 5)
        x = cp_random_data(_key(0), dims, 2)
        y = tt_random_data(_key(1), dims, 2)
        f = jax.jit(C.inner)
        np.testing.assert_allclose(f(x, y), C.inner(x, y), rtol=1e-5)
