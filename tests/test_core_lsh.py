"""Tests for projections + the four LSH families: statistics vs paper theory.

Validates the paper's claims directly:
 - E[<P,X>] = 0, Var(<P,X>) = ||X||_F^2    (Theorems 3, 5)
 - collision prob of CP/TT-E2LSH matches p(r) (Theorems 4, 6 / Eq. 4.17)
 - collision prob of CP/TT-SRP matches 1 - theta/pi (Theorems 8, 10)
 - format-invariance: hashing the SAME tensor given densely / in CP / in TT
   yields identical codes under one projection family
 - space complexities of Tables 1-2
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (make_family, naive_storage_size, pack_bits, unpack_bits,
                        project, sample_cp_projection, sample_tt_projection,
                        sample_dense_projection, cp_random_data, tt_random_data,
                        cp_to_dense, tt_to_dense, dense_to_tt, theory)
from repro.core import contractions as C
from repro.core.index import _combine_codes, _make_mults

DIMS = (8, 8, 8)
PROJECTION_SEEDS = list(range(10))


def _key(seed):
    return jax.random.PRNGKey(seed)


class TestProjectionPaths:
    """All projection paths must agree with densified oracles."""

    @pytest.mark.parametrize("seed", PROJECTION_SEEDS)
    def test_cp_projection_all_input_formats(self, seed):
        rng = np.random.default_rng(seed)
        rank, k = int(rng.integers(1, 5)), int(rng.integers(1, 7))
        kp, kx = jax.random.split(_key(seed))
        dims = (4, 5, 6)
        p = sample_cp_projection(kp, k, dims, rank)
        x_cp = cp_random_data(kx, dims, 3)
        x_dense = cp_to_dense(x_cp)
        x_tt = dense_to_tt(x_dense, max_rank=20)  # exact
        want = jnp.stack([jnp.vdot(cp_to_dense(p.single(i)), x_dense)
                          for i in range(k)])
        for x in (x_cp, x_dense, x_tt):
            got = project(p, x)
            np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-4)

    @pytest.mark.parametrize("seed", PROJECTION_SEEDS)
    def test_tt_projection_all_input_formats(self, seed):
        rng = np.random.default_rng(seed)
        rank, k = int(rng.integers(1, 4)), int(rng.integers(1, 7))
        kp, kx = jax.random.split(_key(seed))
        dims = (4, 5, 6)
        p = sample_tt_projection(kp, k, dims, rank)
        x_cp = cp_random_data(kx, dims, 3)
        x_dense = cp_to_dense(x_cp)
        x_tt = dense_to_tt(x_dense, max_rank=20)
        want = jnp.stack([jnp.vdot(tt_to_dense(p.single(i)), x_dense)
                          for i in range(k)])
        for x in (x_cp, x_dense, x_tt):
            got = project(p, x)
            np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-4)

    def test_dense_projection_is_matmul(self):
        kp, kx = jax.random.split(_key(0))
        p = sample_dense_projection(kp, 7, DIMS)
        x = jax.random.normal(kx, DIMS)
        np.testing.assert_allclose(project(p, x), p.matrix @ x.reshape(-1),
                                   rtol=1e-5)

    def test_projection_linearity(self):
        kp, k1, k2 = jax.random.split(_key(1), 3)
        p = sample_cp_projection(kp, 5, DIMS, 3)
        a = jax.random.normal(k1, DIMS)
        b = jax.random.normal(k2, DIMS)
        lhs = project(p, 2.5 * a - 1.5 * b)
        rhs = 2.5 * project(p, a) - 1.5 * project(p, b)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


class TestMomentTheorems:
    """Theorems 3 & 5: <P,X> has mean 0 and variance ||X||_F^2."""

    @pytest.mark.parametrize("kind", ["cp", "tt"])
    def test_projection_moments(self, kind):
        n_samples = 4000
        kx, kp = jax.random.split(_key(42))
        x = jax.random.normal(kx, DIMS)
        sampler = sample_cp_projection if kind == "cp" else sample_tt_projection
        p = sampler(kp, n_samples, DIMS, rank=2)
        vals = np.asarray(project(p, x))
        fro2 = float(jnp.vdot(x, x))
        # mean: se = sigma/sqrt(n)
        se = math.sqrt(fro2 / n_samples)
        assert abs(vals.mean()) < 4 * se
        # variance of the variance estimate ~ 2 sigma^4 / n for normal-ish
        var = vals.var()
        se_var = math.sqrt(2.0 / n_samples) * fro2
        assert abs(var - fro2) < 6 * se_var

    def test_gaussian_variant_moments(self):
        kx, kp = jax.random.split(_key(43))
        x = jax.random.normal(kx, DIMS)
        p = sample_cp_projection(kp, 4000, DIMS, rank=2, dist="gaussian")
        vals = np.asarray(project(p, x))
        fro2 = float(jnp.vdot(x, x))
        # CP-Gaussian has heavier tails (product of normals); loose bound
        assert abs(vals.mean()) < 5 * math.sqrt(fro2 / 4000)
        assert 0.5 * fro2 < vals.var() < 2.0 * fro2


class TestCollisionProbabilities:
    """Empirical collision rates vs the paper's closed forms."""

    @pytest.mark.parametrize("kind", ["cp-e2lsh", "tt-e2lsh", "e2lsh"])
    def test_e2lsh_collision_matches_theory(self, kind):
        w, m = 4.0, 3000
        kx, kn, kf = jax.random.split(_key(7), 3)
        x = jax.random.normal(kx, DIMS)
        for r_target in (1.0, 3.0, 6.0):
            noise = jax.random.normal(kn, DIMS)
            y = x + noise * (r_target / jnp.linalg.norm(noise))
            fam = make_family(kf, kind, DIMS, num_codes=m, num_tables=1,
                              rank=2, bucket_width=w)
            cx = np.asarray(fam.hash(x)).ravel()
            cy = np.asarray(fam.hash(y)).ravel()
            emp = (cx == cy).mean()
            want = float(theory.e2lsh_collision_prob(r_target, w))
            se = math.sqrt(want * (1 - want) / m)
            assert abs(emp - want) < 5 * se + 0.015, (kind, r_target, emp, want)

    @pytest.mark.parametrize("kind", ["cp-srp", "tt-srp", "srp"])
    def test_srp_collision_matches_theory(self, kind):
        m = 3000
        kx, kn, kf = jax.random.split(_key(9), 3)
        x = jax.random.normal(kx, DIMS)
        for mix in (0.1, 0.5, 1.5):
            y = x + mix * jax.random.normal(kn, DIMS)
            cos = float(jnp.vdot(x, y) / (jnp.linalg.norm(x) * jnp.linalg.norm(y)))
            fam = make_family(kf, kind, DIMS, num_codes=m, num_tables=1, rank=2)
            cx = np.asarray(fam.hash(x)).ravel()
            cy = np.asarray(fam.hash(y)).ravel()
            emp = (cx == cy).mean()
            want = float(theory.srp_collision_prob(cos))
            se = math.sqrt(max(want * (1 - want), 1e-4) / m)
            assert abs(emp - want) < 5 * se + 0.015, (kind, mix, emp, want)

    def test_e2lsh_collision_monotone_in_distance(self):
        """Definition 1: closer pairs must collide more (LSH validity)."""
        m = 2000
        kx, kf = jax.random.split(_key(11))
        x = jax.random.normal(kx, DIMS)
        fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=m, rank=2,
                          bucket_width=4.0)
        cx = np.asarray(fam.hash(x)).ravel()
        rates = []
        for r in (0.5, 2.0, 8.0):
            noise = jax.random.normal(jax.random.PRNGKey(int(r * 10)), DIMS)
            y = x + noise * (r / jnp.linalg.norm(noise))
            cy = np.asarray(fam.hash(y)).ravel()
            rates.append((cx == cy).mean())
        assert rates[0] > rates[1] > rates[2]


class TestHashingMechanics:
    def test_format_invariance(self):
        """Same tensor, three formats, one family -> identical codes."""
        kf, kx = jax.random.split(_key(3))
        dims = (4, 5, 6)
        x_cp = cp_random_data(kx, dims, 3)
        x_dense = cp_to_dense(x_cp)
        x_tt = dense_to_tt(x_dense, max_rank=20)
        for kind in ("cp-e2lsh", "tt-e2lsh", "cp-srp", "tt-srp"):
            fam = make_family(kf, kind, dims, num_codes=16, num_tables=2, rank=3)
            h_dense = np.asarray(fam.hash(x_dense))
            h_cp = np.asarray(fam.hash(x_cp))
            h_tt = np.asarray(fam.hash(x_tt))
            assert (h_dense == h_cp).mean() > 0.95, kind  # float-assoc tolerance
            assert (h_dense == h_tt).mean() > 0.95, kind

    def test_hash_shapes_and_dtype(self):
        fam = make_family(_key(0), "cp-e2lsh", DIMS, num_codes=8, num_tables=3,
                          rank=2)
        x = jax.random.normal(_key(1), DIMS)
        h = fam.hash(x)
        assert h.shape == (3, 8) and h.dtype == jnp.int32
        xs = jax.random.normal(_key(2), (5,) + DIMS)
        hb = fam.hash_batch(xs)
        assert hb.shape == (5, 3, 8)

    @pytest.mark.parametrize("k", [1, 7, 31, 32, 33, 40, 63, 64, 65, 96, 100])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_bit_pack_roundtrip(self, k, seed):
        """Roundtrip exactness, including K not a multiple of 32."""
        bits = np.random.default_rng(seed * 1000 + k).integers(
            0, 2, size=(3, k)).astype(np.int32)
        packed = pack_bits(jnp.asarray(bits))
        assert packed.shape == (3, (k + 31) // 32)
        assert packed.dtype == jnp.uint32
        np.testing.assert_array_equal(unpack_bits(packed, k), bits)

    @pytest.mark.parametrize("k", [5, 33, 70])
    def test_bit_pack_padding_is_zero(self, k):
        """Bits beyond K never leak into the packed words: all-ones input
        packs to exactly (2^K - 1) split across words."""
        bits = jnp.ones((1, k), jnp.int32)
        packed = np.asarray(pack_bits(bits))[0].astype(np.uint64)
        total = 0
        for w_i, word in enumerate(packed):
            total += int(word) << (32 * w_i)
        assert total == (1 << k) - 1

    def test_srp_packed_equals_unpacked(self):
        fam = make_family(_key(5), "cp-srp", DIMS, num_codes=40, num_tables=2,
                          rank=2)
        x = jax.random.normal(_key(6), DIMS)
        np.testing.assert_array_equal(
            unpack_bits(fam.hash_packed(x), 40), np.asarray(fam.hash(x)))

    def test_e2lsh_shift_property(self):
        """floor((v+b)/w) must shift by exactly 1 when v shifts by w."""
        fam = make_family(_key(12), "cp-e2lsh", DIMS, num_codes=32, rank=2,
                          bucket_width=2.0)
        x = jax.random.normal(_key(13), DIMS)
        v = fam.raw_projections(x)
        c1 = np.asarray(jnp.floor((v + fam.offsets) / fam.bucket_width))
        c2 = np.asarray(jnp.floor((v + fam.bucket_width + fam.offsets)
                                  / fam.bucket_width))
        np.testing.assert_array_equal(c2, c1 + 1)


class TestCombineCodes:
    """The universal bucket-key hash behind both LSH indexes."""

    def test_permutation_sensitivity(self):
        """Distinct per-position multipliers: permuting the K codes within a
        table must (generically) change the bucket key."""
        mults = _make_mults(0, 8)
        rng = np.random.default_rng(1)
        codes = rng.integers(-50, 50, size=(4, 8)).astype(np.int32)
        base = _combine_codes(codes, mults)
        changed = 0
        for _ in range(20):
            p = rng.permutation(8)
            if np.array_equal(p, np.arange(8)):
                continue
            perm_keys = _combine_codes(codes[:, p], mults)
            changed += int(not np.array_equal(perm_keys, base))
        assert changed >= 18  # collisions are possible but must be rare

    def test_order_matters_two_codes(self):
        mults = _make_mults(3, 2)
        a = _combine_codes(np.array([[1, 2]], np.int32), mults)
        b = _combine_codes(np.array([[2, 1]], np.int32), mults)
        assert a[0] != b[0]

    @pytest.mark.parametrize("codes", [
        np.array([[2**31 - 1, -2**31, 2**31 - 1]], np.int32),
        np.array([[-1, -2, -3]], np.int32),
        np.array([[0, 2**30, -2**30]], np.int32),
    ])
    def test_int32_overflow_stability(self, codes):
        """Overflow-prone int32 codes wrap mod 2^32 deterministically —
        no errors, uint32 output, and repeated evaluation agrees."""
        mults = _make_mults(7, codes.shape[-1])
        k1 = _combine_codes(codes, mults)
        k2 = _combine_codes(codes.copy(), mults)
        assert k1.dtype == np.uint32
        np.testing.assert_array_equal(k1, k2)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_host_device_keys_identical(self, seed):
        """numpy (host tables) and jnp (device tables) produce bit-identical
        bucket keys, including for negative / extreme codes."""
        rng = np.random.default_rng(seed)
        mults = _make_mults(seed, 6)
        codes = rng.integers(-2**31, 2**31, size=(5, 3, 6)).astype(np.int32)
        host = _combine_codes(codes, mults)
        device = np.asarray(_combine_codes(jnp.asarray(codes), mults))
        assert host.dtype == np.uint32 and device.dtype == np.uint32
        np.testing.assert_array_equal(host, device)

    def test_mults_are_odd_and_seeded(self):
        m1, m2 = _make_mults(5, 16), _make_mults(5, 16)
        np.testing.assert_array_equal(m1, m2)
        assert (m1 % 2 == 1).all()
        assert not np.array_equal(m1, _make_mults(6, 16))


class TestSpaceComplexity:
    """Tables 1-2: storage of each family vs the naive method."""

    def test_table_1_and_2_storage(self):
        n, d, r, k = 4, 10, 3, 16
        dims = (d,) * n
        cp_e2 = make_family(_key(0), "cp-e2lsh", dims, num_codes=k, rank=r)
        tt_e2 = make_family(_key(0), "tt-e2lsh", dims, num_codes=k, rank=r)
        naive = make_family(_key(0), "e2lsh", dims, num_codes=k)
        assert cp_e2.storage_size() == k * n * d * r                    # O(KNdR)
        assert tt_e2.storage_size() == k * (2 * d * r + (n - 2) * d * r * r)  # O(KNdR^2)
        assert naive.storage_size() == k * d ** n                       # O(Kd^N)
        assert naive_storage_size(dims, k, 1) == k * d ** n
        # exponential vs linear separation
        assert cp_e2.storage_size() < tt_e2.storage_size() < naive.storage_size()
