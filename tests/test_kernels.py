"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref.py oracles,
swept over shapes and dtypes (including non-aligned shapes that exercise the
ops.py padding paths), plus agreement with the core-library paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cp_random_data, tt_random_data, sample_cp_projection,
                        sample_tt_projection, project)
from repro.kernels import (cp_inner_products, tt_inner_products, srp_pack,
                           e2lsh_quantize)
from repro.kernels import ref
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.tt_inner import tt_inner_pallas
from repro.kernels.srp_pack import srp_pack_pallas
from repro.kernels.e2lsh_quant import e2lsh_quant_pallas
from repro.core.lsh import pack_bits, e2lsh_discretize


def _key(seed):
    return jax.random.PRNGKey(seed)


SHAPE_SWEEP = [
    # (n_modes, d, rx, rp, k)
    (2, 8, 1, 1, 8),
    (2, 16, 4, 8, 8),
    (3, 8, 2, 4, 16),
    (3, 24, 8, 8, 8),
    (4, 8, 4, 2, 24),
    (4, 16, 3, 5, 8),
    (5, 8, 2, 2, 8),
]


class TestCPGramKernel:
    @pytest.mark.parametrize("n,d,rx,rp,k", SHAPE_SWEEP)
    def test_vs_ref_shape_sweep(self, n, d, rx, rp, k):
        kx, kp = jax.random.split(_key(n * 1000 + d))
        xf = jax.random.normal(kx, (n, d, rx))
        pf = jax.random.normal(kp, (n, k, d, rp))
        got = cp_gram_pallas(xf, pf, block_k=8, interpret=True)
        want = ref.cp_inner_ref(xf, pf)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        kx, kp = jax.random.split(_key(0))
        xf = jax.random.normal(kx, (3, 8, 4)).astype(dtype)
        pf = jax.random.normal(kp, (3, 8, 8, 4)).astype(dtype)
        got = cp_gram_pallas(xf.astype(jnp.float32), pf.astype(jnp.float32),
                             block_k=8, interpret=True)
        want = ref.cp_inner_ref(xf.astype(jnp.float32), pf.astype(jnp.float32))
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    @pytest.mark.parametrize("seed", range(6))
    def test_ops_wrapper_vs_core_projection(self, seed):
        """ops.cp_inner_products == core project() on real CP formats.

        dims=10 (not a multiple of 8) and K=12 (not a multiple of block_k=8)
        exercise the mode-dim and K-block zero-padding paths in ops.py.
        """
        kx, kp = jax.random.split(_key(seed))
        dims = (10, 10, 10)
        x = cp_random_data(kx, dims, 3)
        p = sample_cp_projection(kp, 12, dims, 4)
        got = cp_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("d,k", [(9, 13), (7, 1), (11, 8), (8, 17)])
    def test_padded_nonaligned_shapes_vs_ref(self, d, k):
        """Mode-dim padding (d % 8 != 0) and K-block padding (k % 8 != 0)
        must not change any of the K outputs vs the unpadded oracle."""
        kx, kp = jax.random.split(_key(d * 100 + k))
        dims = (d, d, d)
        x = cp_random_data(kx, dims, 2)
        p = sample_cp_projection(kp, k, dims, 3)
        got = cp_inner_products(x, p, interpret=True)
        assert got.shape == (k,)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestTTInnerKernel:
    @pytest.mark.parametrize("n,d,rx,rp,k", SHAPE_SWEEP)
    def test_vs_ref_shape_sweep(self, n, d, rx, rp, k):
        kx, kp = jax.random.split(_key(n * 999 + d))
        xc = jax.random.normal(kx, (n, rx, d, rx))
        pc = jax.random.normal(kp, (n, k, rp, d, rp))
        got = tt_inner_pallas(xc, pc, block_k=8, interpret=True)
        want = ref.tt_inner_ref(xc, pc)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("seed", range(6))
    def test_ops_wrapper_vs_core_projection(self, seed):
        """dims=9 and K=10 exercise mode-dim + K-block padding for TT."""
        kx, kp = jax.random.split(_key(seed))
        dims = (9, 9, 9)
        x = tt_random_data(kx, dims, 3)
        p = sample_tt_projection(kp, 10, dims, 2)
        got = tt_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("d,k", [(10, 13), (7, 1), (13, 9)])
    def test_padded_nonaligned_shapes_vs_ref(self, d, k):
        """Non-aligned mode dims and K vs the core projection oracle, with
        boundary-rank zero-padding in the same run."""
        kx, kp = jax.random.split(_key(d * 37 + k))
        dims = (d, d, d)
        x = tt_random_data(kx, dims, 2)
        p = sample_tt_projection(kp, k, dims, 3)
        got = tt_inner_products(x, p, interpret=True)
        assert got.shape == (k,)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_boundary_rank_padding_exact(self):
        """Zero-padded boundary cores + e_00 start must be exact, not approx."""
        kx, kp = jax.random.split(_key(7))
        x = tt_random_data(kx, (6, 6), 4)  # N=2: both cores are boundary cores
        p = sample_tt_projection(kp, 8, (6, 6), 3)
        got = tt_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestSRPPackKernel:
    @pytest.mark.parametrize("b,k", [(8, 32), (16, 64), (8, 128), (24, 96)])
    def test_vs_ref(self, b, k):
        v = jax.random.normal(_key(b * k), (b, k))
        got = srp_pack_pallas(v, block_b=8, interpret=True)
        np.testing.assert_array_equal(got, ref.srp_pack_ref(v))

    @pytest.mark.parametrize("b,k", [(1, 1), (3, 31), (5, 33), (7, 40),
                                     (8, 70), (13, 64), (20, 5), (9, 96)])
    def test_ops_wrapper_ragged(self, b, k):
        """K -> multiple-of-32 padding with -1 fill (sign bit 0) and batch
        padding must reproduce the unpadded reference exactly."""
        v = jax.random.normal(_key(b * 1000 + k), (b, k))
        got = srp_pack(v, interpret=True)
        want = ref.srp_pack_ref(v)
        np.testing.assert_array_equal(got, want)

    def test_matches_core_pack_bits(self):
        v = jax.random.normal(_key(3), (5, 40))
        got = srp_pack(v, interpret=True)
        want = pack_bits((v > 0).astype(jnp.int32))
        np.testing.assert_array_equal(got, want)

    def test_zero_is_bit_zero(self):
        """sign(0) = 0 per Definition 2 (1 iff v > 0)."""
        v = jnp.zeros((8, 32))
        got = srp_pack_pallas(v, interpret=True)
        np.testing.assert_array_equal(got, jnp.zeros((8, 1), jnp.uint32))


class TestE2LSHQuantKernel:
    @pytest.mark.parametrize("b,k,w", [(8, 16, 4.0), (16, 8, 1.0), (8, 64, 0.5)])
    def test_vs_ref(self, b, k, w):
        kv, kb = jax.random.split(_key(int(b * k * w)))
        v = 10.0 * jax.random.normal(kv, (b, k))
        offs = jax.random.uniform(kb, (k,), minval=0.0, maxval=w)
        got = e2lsh_quant_pallas(v, offs, w, block_b=8, interpret=True)
        np.testing.assert_array_equal(got, ref.e2lsh_quant_ref(v, offs, w))

    @pytest.mark.parametrize("b", [1, 2, 5, 8, 9, 13, 20])
    def test_ops_wrapper_ragged_vs_core(self, b):
        """Batch padding to block_b must leave the B live rows unchanged."""
        kv, kb = jax.random.split(_key(b))
        v = 5.0 * jax.random.normal(kv, (b, 12))
        offs = jax.random.uniform(kb, (12,), minval=0.0, maxval=2.0)
        got = e2lsh_quantize(v, offs, 2.0, interpret=True)
        want = e2lsh_discretize(v, offs, 2.0)
        np.testing.assert_array_equal(got, want)

    def test_floor_boundary_values(self):
        """Exact multiples of w land in the upper bucket (floor semantics)."""
        v = jnp.array([[0.0, 2.0, -2.0, 3.999999, -0.000001]] * 8)
        offs = jnp.zeros((5,))
        got = e2lsh_quant_pallas(v, offs, 2.0, block_b=8, interpret=True)
        np.testing.assert_array_equal(got[0], jnp.array([0, 1, -1, 1, -1]))
