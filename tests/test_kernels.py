"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref.py oracles,
swept over batched shapes and dtypes (including non-aligned shapes that
exercise the ops.py padding paths), fused-epilogue correctness (discretize /
combine / pack vs composed oracles), plus agreement with the core-library
projection paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cp_random_data, tt_random_data, sample_cp_projection,
                        sample_tt_projection, project)
from repro.core.lsh import (e2lsh_discretize, make_mults, pack_bits,
                            _combine_codes)
from repro.kernels import (cp_inner_products, tt_inner_products, srp_pack,
                           e2lsh_quantize)
from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.tt_inner import tt_inner_pallas
from repro.kernels.srp_pack import srp_pack_pallas
from repro.kernels.e2lsh_quant import e2lsh_quant_pallas


def _key(seed):
    return jax.random.PRNGKey(seed)


SHAPE_SWEEP = [
    # (batch, n_modes, d, rx, rp, l_tables, k_codes)
    (8, 2, 8, 1, 1, 1, 8),
    (8, 2, 16, 4, 8, 2, 4),
    (16, 3, 8, 2, 4, 1, 16),
    (8, 3, 24, 8, 8, 4, 2),
    (24, 4, 8, 4, 2, 3, 8),
    (8, 4, 16, 3, 5, 1, 7),
    (8, 5, 8, 2, 2, 2, 3),
]


class TestCPGramKernel:
    @pytest.mark.parametrize("b,n,d,rx,rp,l,k", SHAPE_SWEEP)
    def test_vs_ref_shape_sweep(self, b, n, d, rx, rp, l, k):
        kx, kp = jax.random.split(_key(n * 1000 + d + b))
        xf = jax.random.normal(kx, (b, n, d, rx))
        pf = jax.random.normal(kp, (n, l, k, d, rp))
        got = cp_gram_pallas(xf, pf, epilogue="raw", interpret=True)
        want = ref.cp_inner_ref(xf, pf.reshape(n, l * k, d, rp))
        np.testing.assert_allclose(got, want.reshape(b, l, k),
                                   rtol=1e-4, atol=1e-4)

    def test_batch_blocking_matches_unblocked(self):
        """The B x table grid must tile without changing any output."""
        kx, kp = jax.random.split(_key(0))
        xf = jax.random.normal(kx, (32, 3, 8, 4))
        pf = jax.random.normal(kp, (3, 4, 6, 8, 4))
        a = cp_gram_pallas(xf, pf, epilogue="raw", block_b=8, block_l=2,
                           interpret=True)
        c = cp_gram_pallas(xf, pf, epilogue="raw", block_b=32, block_l=4,
                           interpret=True)
        np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        kx, kp = jax.random.split(_key(0))
        xf = jax.random.normal(kx, (8, 3, 8, 4)).astype(dtype)
        pf = jax.random.normal(kp, (3, 1, 8, 8, 4)).astype(dtype)
        got = cp_gram_pallas(xf.astype(jnp.float32), pf.astype(jnp.float32),
                             epilogue="raw", interpret=True)
        want = ref.cp_inner_ref(xf.astype(jnp.float32),
                                pf.astype(jnp.float32).reshape(3, 8, 8, 4))
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(got[:, 0], want, rtol=tol, atol=tol)

    @pytest.mark.parametrize("seed", range(6))
    def test_ops_wrapper_vs_core_projection(self, seed):
        """ops.cp_inner_products == core project() on real CP formats.

        dims=10 (not a multiple of 8) exercises the mode-dim zero-padding
        and the B=1 -> block_b batch padding in ops.py.
        """
        kx, kp = jax.random.split(_key(seed))
        dims = (10, 10, 10)
        x = cp_random_data(kx, dims, 3)
        p = sample_cp_projection(kp, 12, dims, 4)
        got = cp_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("d,k", [(9, 13), (7, 1), (11, 8), (8, 17)])
    def test_padded_nonaligned_shapes_vs_ref(self, d, k):
        """Mode-dim padding (d % 8 != 0) and odd K must not change any of
        the K outputs vs the unpadded oracle."""
        kx, kp = jax.random.split(_key(d * 100 + k))
        dims = (d, d, d)
        x = cp_random_data(kx, dims, 2)
        p = sample_cp_projection(kp, k, dims, 3)
        got = cp_inner_products(x, p, interpret=True)
        assert got.shape == (k,)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestTTInnerKernel:
    @pytest.mark.parametrize("b,n,d,rx,rp,l,k", SHAPE_SWEEP)
    def test_vs_ref_shape_sweep(self, b, n, d, rx, rp, l, k):
        kx, kp = jax.random.split(_key(n * 999 + d + b))
        xc = jax.random.normal(kx, (b, n, rx, d, rx))
        pc = jax.random.normal(kp, (n, l, k, rp, d, rp))
        got = tt_inner_pallas(xc, pc, epilogue="raw", interpret=True)
        want = ref.tt_inner_ref(xc, pc.reshape(n, l * k, rp, d, rp))
        np.testing.assert_allclose(got, want.reshape(b, l, k),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("seed", range(6))
    def test_ops_wrapper_vs_core_projection(self, seed):
        """dims=9 exercises mode-dim + batch padding for TT."""
        kx, kp = jax.random.split(_key(seed))
        dims = (9, 9, 9)
        x = tt_random_data(kx, dims, 3)
        p = sample_tt_projection(kp, 10, dims, 2)
        got = tt_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("d,k", [(10, 13), (7, 1), (13, 9)])
    def test_padded_nonaligned_shapes_vs_ref(self, d, k):
        """Non-aligned mode dims and K vs the core projection oracle, with
        boundary-rank zero-padding in the same run."""
        kx, kp = jax.random.split(_key(d * 37 + k))
        dims = (d, d, d)
        x = tt_random_data(kx, dims, 2)
        p = sample_tt_projection(kp, k, dims, 3)
        got = tt_inner_products(x, p, interpret=True)
        assert got.shape == (k,)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_boundary_rank_padding_exact(self):
        """Zero-padded boundary cores + e_00 start must be exact, not approx."""
        kx, kp = jax.random.split(_key(7))
        x = tt_random_data(kx, (6, 6), 4)  # N=2: both cores are boundary cores
        p = sample_tt_projection(kp, 8, (6, 6), 3)
        got = tt_inner_products(x, p, interpret=True)
        want = project(p, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestStackTTCores:
    """Direct unit coverage of the boundary-rank core stacking in ops.py."""

    def test_boundary_and_interior_ranks(self):
        rank = 4
        cores = (jnp.arange(1 * 5 * 4, dtype=jnp.float32).reshape(1, 5, 4),
                 jnp.ones((4, 5, 4), jnp.float32),
                 jnp.full((4, 5, 1), 2.0))
        out = ops._stack_tt_cores(cores, rank)
        assert out.shape == (3, rank, 5, rank)
        # real entries land in the leading rows/cols, the rest is exactly 0
        np.testing.assert_array_equal(out[0, :1], cores[0])
        np.testing.assert_array_equal(out[0, 1:], 0.0)
        np.testing.assert_array_equal(out[1], cores[1])
        np.testing.assert_array_equal(out[2, :, :, :1], cores[2])
        np.testing.assert_array_equal(out[2, :, :, 1:], 0.0)

    def test_truncated_interior_rank(self):
        """Cores with rank strictly between 1 and the chain max (e.g. from
        a truncated TT-SVD) pad to exactly the chain max, not a multiple."""
        rank = 5
        core = jnp.ones((3, 4, 2), jnp.float32)
        out = ops._stack_tt_cores((core,), rank)
        assert out.shape == (1, rank, 4, rank)
        np.testing.assert_array_equal(out[0, :3, :, :2], core)
        assert float(jnp.abs(out).sum()) == float(jnp.abs(core).sum())


class TestFusedEpilogues:
    """The in-kernel discretize / combine / pack epilogues vs composed
    oracles, through the real ops.fused_hash padding path."""

    DIMS = (7, 7, 7)

    def _family(self, kind, k=5, l=3, seed=2):
        from repro.core import make_family
        return make_family(_key(seed), kind, self.DIMS, num_codes=k,
                           num_tables=l, rank=3, bucket_width=4.0,
                           hash_backend="xla")

    def _batch(self, kind, b=11, seed=4):
        maker = cp_random_data if kind.startswith("cp") else tt_random_data
        return jax.vmap(lambda kk: maker(kk, self.DIMS, 2))(
            jax.random.split(_key(seed), b))

    @pytest.mark.parametrize("kind", ["cp-e2lsh", "tt-e2lsh"])
    def test_e2lsh_codes_and_keys(self, kind):
        fam = self._family(kind)
        xs = self._batch(kind)
        want = fam.hash_batch(xs)  # xla oracle: batched einsum + discretize
        got = ops.fused_hash(xs, fam.projection, epilogue="codes",
                             kind=kind, num_tables=3, num_codes=5,
                             offsets=fam.offsets, w=fam.bucket_width,
                             interpret=True)
        np.testing.assert_array_equal(got, want)
        mults = make_mults(0, 5)
        got_keys = ops.fused_hash(xs, fam.projection, epilogue="keys",
                                  kind=kind, num_tables=3, num_codes=5,
                                  offsets=fam.offsets, w=fam.bucket_width,
                                  mults=mults, interpret=True)
        np.testing.assert_array_equal(got_keys,
                                      _combine_codes(np.asarray(want), mults))

    @pytest.mark.parametrize("kind", ["cp-srp", "tt-srp"])
    def test_srp_codes_keys_packed(self, kind):
        fam = self._family(kind)
        xs = self._batch(kind)
        want = fam.hash_batch(xs)
        got = ops.fused_hash(xs, fam.projection, epilogue="codes",
                             kind=kind, num_tables=3, num_codes=5,
                             interpret=True)
        np.testing.assert_array_equal(got, want)
        mults = make_mults(1, 5)
        got_keys = ops.fused_hash(xs, fam.projection, epilogue="keys",
                                  kind=kind, num_tables=3, num_codes=5,
                                  mults=mults, interpret=True)
        np.testing.assert_array_equal(got_keys,
                                      _combine_codes(np.asarray(want), mults))
        got_packed = ops.fused_hash(xs, fam.projection, epilogue="packed",
                                    kind=kind, num_tables=3, num_codes=5,
                                    interpret=True)
        np.testing.assert_array_equal(got_packed, pack_bits(want))


class TestSRPPackKernel:
    @pytest.mark.parametrize("b,k", [(8, 32), (16, 64), (8, 128), (24, 96)])
    def test_vs_ref(self, b, k):
        v = jax.random.normal(_key(b * k), (b, k))
        got = srp_pack_pallas(v, block_b=8, interpret=True)
        np.testing.assert_array_equal(got, ref.srp_pack_ref(v))

    @pytest.mark.parametrize("b,k", [(1, 1), (3, 31), (5, 33), (7, 40),
                                     (8, 70), (13, 64), (20, 5), (9, 96)])
    def test_ops_wrapper_ragged(self, b, k):
        """K -> multiple-of-32 padding with -1 fill (sign bit 0) and batch
        padding must reproduce the unpadded reference exactly."""
        v = jax.random.normal(_key(b * 1000 + k), (b, k))
        got = srp_pack(v, interpret=True)
        want = ref.srp_pack_ref(v)
        np.testing.assert_array_equal(got, want)

    def test_matches_core_pack_bits(self):
        v = jax.random.normal(_key(3), (5, 40))
        got = srp_pack(v, interpret=True)
        want = pack_bits((v > 0).astype(jnp.int32))
        np.testing.assert_array_equal(got, want)

    def test_zero_is_bit_zero(self):
        """sign(0) = 0 per Definition 2 (1 iff v > 0)."""
        v = jnp.zeros((8, 32))
        got = srp_pack_pallas(v, interpret=True)
        np.testing.assert_array_equal(got, jnp.zeros((8, 1), jnp.uint32))


class TestE2LSHQuantKernel:
    @pytest.mark.parametrize("b,k,w", [(8, 16, 4.0), (16, 8, 1.0), (8, 64, 0.5)])
    def test_vs_ref(self, b, k, w):
        kv, kb = jax.random.split(_key(int(b * k * w)))
        v = 10.0 * jax.random.normal(kv, (b, k))
        offs = jax.random.uniform(kb, (k,), minval=0.0, maxval=w)
        got = e2lsh_quant_pallas(v, offs, w, block_b=8, interpret=True)
        np.testing.assert_array_equal(got, ref.e2lsh_quant_ref(v, offs, w))

    @pytest.mark.parametrize("b", [1, 2, 5, 8, 9, 13, 20])
    def test_ops_wrapper_ragged_vs_core(self, b):
        """Batch padding to block_b must leave the B live rows unchanged."""
        kv, kb = jax.random.split(_key(b))
        v = 5.0 * jax.random.normal(kv, (b, 12))
        offs = jax.random.uniform(kb, (12,), minval=0.0, maxval=2.0)
        got = e2lsh_quantize(v, offs, 2.0, interpret=True)
        want = e2lsh_discretize(v, offs, 2.0)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("k", [1, 5, 12, 127, 129, 200])
    def test_ops_wrapper_pads_k_axis(self, k):
        """Regression: K not a multiple of the f32 lane width (128) must be
        padded on BOTH values and offsets and sliced back — every live
        column bit-equal to the unpadded oracle, output exactly (B, K)."""
        kv, kb = jax.random.split(_key(k * 7))
        v = 5.0 * jax.random.normal(kv, (6, k))
        offs = jax.random.uniform(kb, (k,), minval=0.0, maxval=2.0)
        got = e2lsh_quantize(v, offs, 2.0, interpret=True)
        assert got.shape == (6, k)
        np.testing.assert_array_equal(got, e2lsh_discretize(v, offs, 2.0))

    def test_floor_boundary_values(self):
        """Exact multiples of w land in the upper bucket (floor semantics)."""
        v = jnp.array([[0.0, 2.0, -2.0, 3.999999, -0.000001]] * 8)
        offs = jnp.zeros((5,))
        got = e2lsh_quant_pallas(v, offs, 2.0, block_b=8, interpret=True)
        np.testing.assert_array_equal(got[0], jnp.array([0, 1, -1, 1, -1]))
