"""Bit-identity matrix of the fused query-to-candidates path.

Pins both probe backends — ``xla`` (the restructured segment-major
schedule in ``core.segments``) and ``pallas`` (the fused query kernel in
``kernels.fused_query``, interpret mode on CPU) — against the reference
planner, bitwise: candidate ids equal, scores equal as int32 bit
patterns, candidate counts equal. The grid is the shared layout suite
(tests/grids.py): 6 kinds x 2 metrics (non-canonical pairings marked
slow) x T in {1, 8} x {device, sharded S in {1, 2, 4}} x {fresh,
mutated}.

Reference pairing doctrine (mirrors the seed's own programs): XLA's CPU
backend picks reduction lowerings per program *structure*, so two
correct programs with different batching structures can round last bits
differently — the seed's vmapped no-mesh fallback and its unbatched
shard_map body already diverge this way (test_index_sharded tolerates it
with an rtol on the vmap path). Each backend therefore pins against the
reference sharing its batching structure:

- device (unbatched schedule)          -> ``segmented_query_reference``
- sharded + mesh (shard_map, xla)      -> ``shard_map_query_reference``
- sharded no-mesh (vmapped, xla)       -> ``sharded_query_vmap_reference``
- sharded pallas (per-shard unbatched) -> per-shard reference loop
                                          + ``merge_topk``

Cross-structure equality is NOT asserted anywhere in the repo and is not
a regression when absent.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import grids
from repro.core import DeviceLSHIndex, ShardedLSHIndex
from repro.core import segments as seg
from repro.distributed import index_sharding
from repro.serving.lsh_service import build_service

N, B, TOPK = 53, 6, 5
BACKENDS = ("xla", "pallas")


def _assert_bitwise(tag, got, ref):
    gi, gs, gn = got
    ri, rs, rn = ref
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri),
                                  err_msg=f"{tag}: candidate ids differ")
    np.testing.assert_array_equal(
        np.asarray(gs).view(np.int32), np.asarray(rs).view(np.int32),
        err_msg=f"{tag}: scores differ in bit pattern")
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(rn),
                                  err_msg=f"{tag}: candidate counts differ")


def _mutate(idx, corpus):
    idx.delete(jnp.arange(0, 12, 3))
    idx.insert(corpus[:7] * 1.01)


def _per_shard_reference(fam, idx, queries, metric, probes):
    """The pallas-structure reference: each shard queried as its own
    unbatched program (the shard_map body), merged once — matching the
    fused kernel's one-flat-launch-over-(shard, segment) schedule."""
    view = idx.store.view
    keys = seg.query_keys(fam, jnp.asarray(idx._mults), queries, probes)
    base = view.seg_arrays(0)
    deltas = view.delta_arrays
    s = jax.tree.leaves(base)[0].shape[0]
    outs = []
    for i in range(s):
        base_i = jax.tree.map(lambda a, i=i: a[i], base)
        deltas_i = tuple(jax.tree.map(lambda a, i=i: a[i], d)
                         for d in deltas)
        outs.append(seg.shard_topk_with_deltas(
            metric, TOPK, view.base.cap, view.delta_caps, queries,
            base_i, deltas_i, keys))
    if s == 1:
        return outs[0]
    return seg.merge_topk(metric, TOPK,
                          jnp.stack([o[0] for o in outs]),
                          jnp.stack([o[1] for o in outs]),
                          jnp.stack([o[2] for o in outs]))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind,metric", grids.cell_params())
def test_device_bit_identity(kind, metric, backend):
    fam = grids.grid_family(kind)
    corpus, queries = grids.corpus_and_queries(N, B)
    idx = DeviceLSHIndex(fam, metric=metric, bucket_cap=4).build(corpus)
    for state in ("fresh", "mutated"):
        if state == "mutated":
            _mutate(idx, corpus)
        view = idx.store.view
        for probes in (1, 8):
            ref = seg.segmented_query_reference(
                fam, view.all_arrays, jnp.asarray(idx._mults), queries,
                metric=metric, topk=TOPK, caps=view.all_caps,
                probes=probes)
            got = dataclasses.replace(idx, probe_backend=backend) \
                .query_batch(queries, topk=TOPK, probes=probes)
            _assert_bitwise(f"device {state} T={probes} {backend}",
                            got, ref)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shards", grids.SHARD_COUNTS)
@pytest.mark.parametrize("kind,metric", grids.cell_params())
def test_sharded_bit_identity(kind, metric, shards, backend):
    fam = grids.grid_family(kind)
    corpus, queries = grids.corpus_and_queries(N, B, seed=1)
    idx = ShardedLSHIndex(fam, metric=metric, shards=shards,
                          bucket_cap=4).build(corpus)
    for state in ("fresh", "mutated"):
        if state == "mutated":
            _mutate(idx, corpus)
        view = idx.store.view
        for probes in (1, 8):
            if backend == "pallas":
                ref = _per_shard_reference(fam, idx, queries, metric,
                                           probes)
            else:
                args = (fam, view.seg_arrays(0), view.delta_arrays,
                        jnp.asarray(idx._mults), queries)
                kwargs = dict(metric=metric, topk=TOPK,
                              cap=view.base.cap,
                              delta_caps=view.delta_caps, probes=probes)
                if idx.mesh is not None:
                    ref = index_sharding.shard_map_query_reference(
                        *args, mesh=idx.mesh, axis=idx.mesh_axis,
                        **kwargs)
                else:
                    ref = seg.sharded_query_vmap_reference(*args,
                                                           **kwargs)
            got = dataclasses.replace(idx, probe_backend=backend) \
                .query_batch(queries, topk=TOPK, probes=probes)
            _assert_bitwise(
                f"sharded S={shards} {state} T={probes} {backend}",
                got, ref)


def test_resolved_probe_backend(monkeypatch):
    monkeypatch.delenv("REPRO_PROBE_BACKEND", raising=False)
    assert seg.resolved_probe_backend("auto") == (
        "pallas" if jax.default_backend() == "tpu" else "xla")
    # explicit knob wins over everything
    monkeypatch.setenv("REPRO_PROBE_BACKEND", "pallas")
    assert seg.resolved_probe_backend("xla") == "xla"
    # env var steers 'auto' (read at trace time)
    assert seg.resolved_probe_backend("auto") == "pallas"
    with pytest.raises(ValueError):
        seg.resolved_probe_backend("mlir")


def test_probe_backend_threading():
    """The knob flows index -> service and is reported by probe_path."""
    fam = grids.grid_family("cp-e2lsh")
    corpus, queries = grids.corpus_and_queries(N, B)
    on_cpu = jax.default_backend() != "tpu"
    idx = DeviceLSHIndex(fam, metric="euclidean", bucket_cap=4,
                         probe_backend="pallas").build(corpus)
    assert idx.probe_path == "pallas"
    if on_cpu:
        assert DeviceLSHIndex(fam, metric="euclidean").probe_path == "xla"
    svc = build_service(jax.random.PRNGKey(0), "cp-e2lsh", grids.DIMS,
                        corpus, num_codes=3, num_tables=4, rank=2,
                        bucket_width=6.0, bucket_cap=4,
                        probe_backend="pallas")
    assert svc.probe_path == "pallas"
    got = svc.query_arrays(queries, topk=TOPK)
    ref = build_service(jax.random.PRNGKey(0), "cp-e2lsh", grids.DIMS,
                        corpus, num_codes=3, num_tables=4, rank=2,
                        bucket_width=6.0, bucket_cap=4,
                        probe_backend="xla").query_arrays(queries,
                                                          topk=TOPK)
    _assert_bitwise("service pallas vs xla (same unbatched structure)",
                    got, ref)


def test_sharded_query_path_loud():
    """The 4-device CI leg must run the fused program inside shard_map —
    a silent vmap fallback on the xla backend is a failure; the pallas
    backend must report its (deferred-dispatch) single-program path."""
    fam = grids.grid_family("cp-e2lsh")
    corpus, _ = grids.corpus_and_queries(N, B)
    idx = ShardedLSHIndex(fam, metric="euclidean", shards=4,
                          bucket_cap=4, probe_backend="xla").build(corpus)
    grids.assert_query_path(idx)
    assert idx.probe_path == "xla"
    pallas_idx = dataclasses.replace(idx, probe_backend="pallas")
    assert pallas_idx.query_path == "vmap"
    assert pallas_idx.probe_path == "pallas"
