"""Device-resident index vs the host-dict reference: bucket membership and
top-k results must agree for every hash family kind and both metrics.

The device index is a segment store holding one base ``TableSegment``
(sorted keys + permutation + corpus slice) built with the default exact
bucket cap (largest bucket observed at build time), so candidate sets are
identical to the host dict buckets by construction — these tests pin that
contract, plus the segment-store shape of a fresh build. Streaming
mutations are covered in tests/test_index_mutation.py.
"""

import grids
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from grids import ALL_KINDS, DIMS
from repro.core import (DeviceLSHIndex, HostLSHIndex, TableSegment,
                        make_family)
from repro.core.index import _combine_codes, _hash_one, _max_run_length

N_CORPUS, N_QUERIES, TOPK = 64, 4, 5


def _data(seed=0):
    return grids.corpus_and_queries(N_CORPUS, N_QUERIES, seed=seed)


def _build_pair(kind, metric, corpus):
    fam = grids.grid_family(kind)
    host = HostLSHIndex(fam, metric=metric).build(corpus)
    device = DeviceLSHIndex(fam, metric=metric).build(corpus)
    return host, device


@pytest.mark.parametrize("kind,metric", grids.cell_params())
class TestDeviceMatchesHost:
    def test_bucket_membership(self, kind, metric):
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        for i in range(N_QUERIES):
            want = set(host.candidates(queries[i]).tolist())
            got = set(device.candidates(queries[i]).tolist())
            assert got == want, (kind, metric, i)

    def test_topk_single_query(self, kind, metric):
        """Batch size 1 through the batched path == host per-query path."""
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        for i in range(N_QUERIES):
            h_ids, h_scores, h_n = host.query(queries[i], topk=TOPK)
            d_ids, d_scores, d_n = device.query(queries[i], topk=TOPK)
            assert h_n == d_n, (kind, metric, i)
            assert len(h_ids) == len(d_ids)
            assert set(h_ids.tolist()) == set(d_ids.tolist()), (kind, metric)
            np.testing.assert_allclose(np.sort(h_scores), np.sort(d_scores),
                                       rtol=1e-4, atol=1e-5)

    def test_topk_batched(self, kind, metric):
        """Batch size > 1: each row of query_batch == the single-query path."""
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        ids, scores, n_cand = device.query_batch(queries, topk=TOPK)
        assert ids.shape == (N_QUERIES, TOPK)
        assert scores.shape == (N_QUERIES, TOPK)
        for i in range(N_QUERIES):
            h_ids, h_scores, h_n = host.query(queries[i], topk=TOPK)
            row = np.asarray(ids[i])
            mask = row >= 0
            assert int(n_cand[i]) == h_n
            assert set(row[mask].tolist()) == set(h_ids.tolist())
            np.testing.assert_allclose(np.sort(np.asarray(scores[i])[mask]),
                                       np.sort(h_scores), rtol=1e-4, atol=1e-5)


class TestDeviceIndexContract:
    def test_topk_fill_when_few_candidates(self):
        """Rows with < topk candidates are -1/inf-filled, never padded with
        arbitrary corpus ids."""
        corpus, queries = _data(1)
        _, device = _build_pair("cp-e2lsh", "euclidean", corpus)
        ids, scores, n_cand = device.query_batch(queries, topk=N_CORPUS)
        ids, scores = np.asarray(ids), np.asarray(scores)
        for i in range(N_QUERIES):
            nc = int(n_cand[i])
            assert (ids[i, :nc] >= 0).all()
            assert (ids[i, nc:] == -1).all()
            assert np.isinf(scores[i, nc:]).all()

    def test_no_duplicate_ids_in_topk(self):
        """A corpus id found in several tables appears once in the top-k."""
        corpus, queries = _data(2)
        _, device = _build_pair("cp-srp", "cosine", corpus)
        ids, _, _ = device.query_batch(queries, topk=N_CORPUS)
        for row in np.asarray(ids):
            live = row[row >= 0]
            assert len(live) == len(set(live.tolist()))

    def test_explicit_bucket_cap_bounds_candidates(self):
        """A small bucket_cap truncates probes to <= L * cap candidates."""
        corpus, queries = _data(3)
        fam = make_family(jax.random.PRNGKey(7), "srp", DIMS, num_codes=2,
                          num_tables=3, rank=2)
        device = DeviceLSHIndex(fam, metric="cosine", bucket_cap=2).build(corpus)
        assert device.cap == 2
        _, _, n_cand = device.query_batch(queries, topk=TOPK)
        assert (np.asarray(n_cand) <= 3 * 2).all()

    def test_exact_member_query_finds_itself(self):
        corpus, _ = _data(4)
        _, device = _build_pair("tt-e2lsh", "euclidean", corpus)
        ids, scores, _ = device.query(corpus[11], topk=1)
        assert ids.size == 1 and ids[0] == 11
        assert scores[0] < 1e-3


class TestEmptyAndDegenerateQueries:
    """Regression: the -1 fill must hold by construction — not via score
    sentinels — for empty candidate sets and NaN-scored candidates."""

    @pytest.mark.parametrize("kind,metric", [("cp-e2lsh", "euclidean"),
                                             ("tt-e2lsh", "cosine")])
    def test_empty_candidate_set_fills_minus_one(self, kind, metric):
        corpus, _ = _data(1)
        fam = make_family(jax.random.PRNGKey(42), kind, DIMS, num_codes=3,
                          num_tables=4, rank=2, bucket_width=1.0)
        host = HostLSHIndex(fam, metric=metric).build(corpus)
        device = DeviceLSHIndex(fam, metric=metric).build(corpus)
        far = 1e3 * jnp.ones(DIMS)      # lands in a bucket nothing occupies
        assert host.candidates(far).size == 0, "fixture must yield empty set"
        ids, scores, n_cand = device.query_batch(far[None], topk=TOPK)
        assert int(n_cand[0]) == 0
        assert (np.asarray(ids[0]) == -1).all()
        assert np.isinf(np.asarray(scores[0])).all()
        got, got_scores, n = device.query(far, topk=TOPK)
        assert got.size == 0 and got_scores.size == 0 and n == 0

    def test_mixed_batch_keeps_empty_row_masked(self):
        corpus, _ = _data(1)
        fam = make_family(jax.random.PRNGKey(42), "cp-e2lsh", DIMS,
                          num_codes=3, num_tables=4, rank=2, bucket_width=1.0)
        device = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        batch = jnp.stack([1e3 * jnp.ones(DIMS), corpus[5]])
        ids, _, n_cand = device.query_batch(batch, topk=3)
        ids = np.asarray(ids)
        assert int(n_cand[0]) == 0 and (ids[0] == -1).all()
        assert ids[1, 0] == 5

    def test_zero_norm_cosine_query_matches_host(self):
        """NaN similarities must not drop candidates: device returns the
        same ids as the host path (scores NaN), not a spurious -1 fill."""
        corpus, _ = _data(2)
        fam = make_family(jax.random.PRNGKey(42), "cp-srp", DIMS,
                          num_codes=6, num_tables=4, rank=2)
        host = HostLSHIndex(fam, metric="cosine").build(corpus)
        device = DeviceLSHIndex(fam, metric="cosine").build(corpus)
        zero = jnp.zeros(DIMS)
        h_ids, h_scores, h_n = host.query(zero, topk=N_CORPUS)
        d_ids, d_scores, d_n = device.query(zero, topk=N_CORPUS)
        assert h_n == d_n
        assert set(h_ids.tolist()) == set(d_ids.tolist())
        if d_n:
            assert np.isnan(d_scores).all() and np.isnan(h_scores).all()


class TestSegmentStoreStructure:
    """A fresh build is a pristine single-segment store: one base
    TableSegment, no deltas, no tombstones, effective ids == physical."""

    def test_fresh_build_store_shape(self):
        corpus, _ = _data(7)
        _, device = _build_pair("cp-e2lsh", "euclidean", corpus)
        store = device.store
        assert isinstance(store.base, TableSegment)
        assert not store.deltas and not store.mutated
        assert store.n_live == N_CORPUS and store.n_dead == 0
        assert store.base.keys.shape == (N_CORPUS, 4)          # (m, L)
        assert store.base.sorted_keys.shape == (4, N_CORPUS)   # (L, m)
        assert bool(store.live_host.all())
        live, eff = store._luts[0]
        assert live.shape == (N_CORPUS + 1,) and not bool(live[-1])
        np.testing.assert_array_equal(np.asarray(eff), np.arange(N_CORPUS))
        assert device.effective_corpus() is store.base.corpus  # zero-copy

    def test_sorted_keys_are_permuted_build_keys(self):
        """The segment's sorted view is exactly its corpus-order keys run
        through the stored permutation — what compaction relies on."""
        corpus, _ = _data(8)
        _, device = _build_pair("tt-e2lsh", "euclidean", corpus)
        seg = device.store.base
        keys_t = np.asarray(seg.keys).T                        # (L, m)
        np.testing.assert_array_equal(
            np.take_along_axis(keys_t, np.asarray(seg.perm), axis=1),
            np.asarray(seg.sorted_keys))
        assert (np.diff(np.asarray(seg.sorted_keys).astype(np.int64),
                        axis=1) >= 0).all()

    def test_host_query_batch_shares_planner_results(self):
        """HostLSHIndex serves batches through the same segment planner:
        results are bit-identical to the device index (same store arrays)."""
        corpus, queries = _data(9)
        host, device = _build_pair("cp-srp", "cosine", corpus)
        h = host.query_batch(queries, topk=TOPK)
        d = device.query_batch(queries, topk=TOPK)
        for a, b in zip(h, d):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestBuildTimeEdgeCases:
    """_max_run_length and the explicit bucket_cap truncation path."""

    def test_max_run_length_cases(self):
        cases = [
            ([[1, 1, 2, 2, 2, 3]], 3),
            ([[5, 5, 5, 5]], 4),
            ([[7]], 1),
            ([[1, 2, 3, 4]], 1),
            ([[1, 2, 3, 3]], 2),                 # run at the end
            ([[1, 1, 2, 3], [2, 2, 2, 3]], 3),   # max across tables
        ]
        for rows, want in cases:
            got = int(_max_run_length(jnp.asarray(rows, jnp.uint32)))
            assert got == want, (rows, got, want)

    def test_default_cap_is_largest_build_bucket(self):
        corpus, _ = _data(6)
        fam = make_family(jax.random.PRNGKey(5), "srp", DIMS, num_codes=2,
                          num_tables=3, rank=2)
        host = HostLSHIndex(fam, metric="cosine").build(corpus)
        device = DeviceLSHIndex(fam, metric="cosine").build(corpus)
        largest = max(len(b) for t in host._tables for b in t.values())
        assert device.cap == largest

    def test_bucket_cap_truncates_in_corpus_order(self):
        """cap < largest bucket: each probe keeps exactly the first `cap`
        members of the bucket in corpus order (the build sort is stable),
        never an arbitrary subset."""
        corpus, queries = _data(5)
        cap = 3
        fam = make_family(jax.random.PRNGKey(11), "srp", DIMS, num_codes=1,
                          num_tables=2, rank=2)   # 1-bit keys: huge buckets
        host = HostLSHIndex(fam, metric="cosine").build(corpus)
        assert max(len(b) for t in host._tables
                   for b in t.values()) > cap, "fixture must overflow cap"
        device = DeviceLSHIndex(fam, metric="cosine",
                                bucket_cap=cap).build(corpus)
        assert device.cap == cap
        for i in range(N_QUERIES):
            codes = np.asarray(_hash_one(fam, queries[i]))[None]
            keys = _combine_codes(codes, host._mults)[0]
            expected = set()
            for t in range(fam.num_tables):
                # host bucket lists are built in ascending corpus order
                expected.update(host._tables[t].get(int(keys[t]), [])[:cap])
            got = set(device.candidates(queries[i]).tolist())
            assert got == expected, i
