"""Device-resident index vs the host-dict reference: bucket membership and
top-k results must agree for every hash family kind and both metrics.

The device index is built with the default exact bucket cap (largest bucket
observed at build time), so candidate sets are identical by construction —
these tests pin that contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceLSHIndex, HostLSHIndex, make_family
from repro.core.lsh import ALL_KINDS

DIMS = (4, 4, 4)
N_CORPUS, N_QUERIES, TOPK = 64, 4, 5


def _data(seed=0):
    kc, kq = jax.random.split(jax.random.PRNGKey(seed))
    corpus = jax.random.normal(kc, (N_CORPUS,) + DIMS)
    queries = corpus[:N_QUERIES] + 0.1 * jax.random.normal(
        kq, (N_QUERIES,) + DIMS)
    return corpus, queries


def _build_pair(kind, metric, corpus):
    k, w = (3, 6.0) if "e2lsh" in kind else (6, 0.0)
    fam = make_family(jax.random.PRNGKey(42), kind, DIMS, num_codes=k,
                      num_tables=4, rank=2, bucket_width=max(w, 1.0))
    host = HostLSHIndex(fam, metric=metric).build(corpus)
    device = DeviceLSHIndex(fam, metric=metric).build(corpus)
    return host, device


@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
@pytest.mark.parametrize("kind", ALL_KINDS)
class TestDeviceMatchesHost:
    def test_bucket_membership(self, kind, metric):
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        for i in range(N_QUERIES):
            want = set(host.candidates(queries[i]).tolist())
            got = set(device.candidates(queries[i]).tolist())
            assert got == want, (kind, metric, i)

    def test_topk_single_query(self, kind, metric):
        """Batch size 1 through the batched path == host per-query path."""
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        for i in range(N_QUERIES):
            h_ids, h_scores, h_n = host.query(queries[i], topk=TOPK)
            d_ids, d_scores, d_n = device.query(queries[i], topk=TOPK)
            assert h_n == d_n, (kind, metric, i)
            assert len(h_ids) == len(d_ids)
            assert set(h_ids.tolist()) == set(d_ids.tolist()), (kind, metric)
            np.testing.assert_allclose(np.sort(h_scores), np.sort(d_scores),
                                       rtol=1e-4, atol=1e-5)

    def test_topk_batched(self, kind, metric):
        """Batch size > 1: each row of query_batch == the single-query path."""
        corpus, queries = _data()
        host, device = _build_pair(kind, metric, corpus)
        ids, scores, n_cand = device.query_batch(queries, topk=TOPK)
        assert ids.shape == (N_QUERIES, TOPK)
        assert scores.shape == (N_QUERIES, TOPK)
        for i in range(N_QUERIES):
            h_ids, h_scores, h_n = host.query(queries[i], topk=TOPK)
            row = np.asarray(ids[i])
            mask = row >= 0
            assert int(n_cand[i]) == h_n
            assert set(row[mask].tolist()) == set(h_ids.tolist())
            np.testing.assert_allclose(np.sort(np.asarray(scores[i])[mask]),
                                       np.sort(h_scores), rtol=1e-4, atol=1e-5)


class TestDeviceIndexContract:
    def test_topk_fill_when_few_candidates(self):
        """Rows with < topk candidates are -1/inf-filled, never padded with
        arbitrary corpus ids."""
        corpus, queries = _data(1)
        _, device = _build_pair("cp-e2lsh", "euclidean", corpus)
        ids, scores, n_cand = device.query_batch(queries, topk=N_CORPUS)
        ids, scores = np.asarray(ids), np.asarray(scores)
        for i in range(N_QUERIES):
            nc = int(n_cand[i])
            assert (ids[i, :nc] >= 0).all()
            assert (ids[i, nc:] == -1).all()
            assert np.isinf(scores[i, nc:]).all()

    def test_no_duplicate_ids_in_topk(self):
        """A corpus id found in several tables appears once in the top-k."""
        corpus, queries = _data(2)
        _, device = _build_pair("cp-srp", "cosine", corpus)
        ids, _, _ = device.query_batch(queries, topk=N_CORPUS)
        for row in np.asarray(ids):
            live = row[row >= 0]
            assert len(live) == len(set(live.tolist()))

    def test_explicit_bucket_cap_bounds_candidates(self):
        """A small bucket_cap truncates probes to <= L * cap candidates."""
        corpus, queries = _data(3)
        fam = make_family(jax.random.PRNGKey(7), "srp", DIMS, num_codes=2,
                          num_tables=3, rank=2)
        device = DeviceLSHIndex(fam, metric="cosine", bucket_cap=2).build(corpus)
        assert device.cap == 2
        _, _, n_cand = device.query_batch(queries, topk=TOPK)
        assert (np.asarray(n_cand) <= 3 * 2).all()

    def test_exact_member_query_finds_itself(self):
        corpus, _ = _data(4)
        _, device = _build_pair("tt-e2lsh", "euclidean", corpus)
        ids, scores, _ = device.query(corpus[11], topk=1)
        assert ids.size == 1 and ids[0] == 11
        assert scores[0] < 1e-3
