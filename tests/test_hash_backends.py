"""Backend parity matrix for the batch-native fused hashing pipeline.

Pins the tentpole contract: for every family kind, the pallas(interpret)
and xla hash backends produce BIT-IDENTICAL integer codes, bucket keys, and
packed SRP signatures, across batch sizes and deliberately awkward shapes
(odd mode dims, K and rank not multiples of 8 — the ops.py padding paths).
Kinds whose format combination has no kernel (dense projections, and
CP/TT projections over dense inputs) must fall back to the XLA path
inside the pallas backend, trivially but verifiably equal.

Also covers the dispatch knob itself: make_family validation, the
REPRO_HASH_BACKEND env override of 'auto', batched-vs-single consistency,
and index-level build parity (identical sorted bucket keys either way).
"""

import os
import subprocess
import sys

import grids
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeviceLSHIndex, cp_random_data, make_family,
                        tt_random_data)
from repro.core.lsh import (ALL_KINDS, SRP_KINDS, _combine_codes, make_mults,
                            pack_bits)

# odd mode dims, odd K, odd rank, odd L: nothing is a multiple of 8
DIMS = (7, 7, 7)
K, L, RANK = 5, 3, 3
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _key(seed):
    return jax.random.PRNGKey(seed)


def _families(kind, seed=0):
    mk = lambda backend: make_family(_key(seed), kind, DIMS, num_codes=K,
                                     num_tables=L, rank=RANK,
                                     bucket_width=4.0, hash_backend=backend)
    return mk("xla"), mk("pallas")


def _batch(kind, b, fmt, seed=1):
    if fmt == "dense":
        return jax.random.normal(_key(seed), (b,) + DIMS)
    maker = cp_random_data if fmt == "cp" else tt_random_data
    return jax.vmap(lambda k: maker(k, DIMS, 2))(jax.random.split(_key(seed), b))


def _native_fmt(kind):
    """The input format the pallas kernels cover for this kind."""
    if kind.startswith("cp"):
        return "cp"
    if kind.startswith("tt"):
        return "tt"
    return "dense"


class TestBackendParityMatrix:
    """pallas(interpret) vs xla: bit-identical codes for all 6 kinds x
    batch {1, 64} x {kernel-native format, dense fallback}."""

    @pytest.mark.parametrize("batch", [1, 64])
    @pytest.mark.parametrize("kind", ALL_KINDS)
    def test_codes_bit_identical(self, kind, batch):
        fam_x, fam_p = _families(kind)
        xs = _batch(kind, batch, _native_fmt(kind))
        cx = np.asarray(fam_x.hash_batch(xs))
        cp = np.asarray(fam_p.hash_batch(xs))
        assert cx.shape == (batch, L, K) and cx.dtype == np.int32
        np.testing.assert_array_equal(cx, cp, err_msg=(kind, batch))

    @pytest.mark.parametrize("kind", ALL_KINDS)
    def test_keys_bit_identical_and_consistent(self, kind):
        """hash_keys: fused combine equals combine-of-codes, both backends."""
        fam_x, fam_p = _families(kind)
        xs = _batch(kind, 16, _native_fmt(kind))
        mults = make_mults(3, K)
        kx = np.asarray(fam_x.hash_keys(xs, jnp.asarray(mults)))
        kp = np.asarray(fam_p.hash_keys(xs, jnp.asarray(mults)))
        assert kx.shape == (16, L) and kx.dtype == np.uint32
        np.testing.assert_array_equal(kx, kp, err_msg=kind)
        np.testing.assert_array_equal(
            kx, _combine_codes(np.asarray(fam_x.hash_batch(xs)), mults))

    @pytest.mark.parametrize("kind", ["cp-e2lsh", "tt-srp"])
    def test_dense_inputs_fall_back_identically(self, kind):
        """CP/TT projections over dense inputs have no kernel; the pallas
        backend must serve them through XLA with identical codes."""
        fam_x, fam_p = _families(kind)
        xs = _batch(kind, 9, "dense")
        np.testing.assert_array_equal(np.asarray(fam_x.hash_batch(xs)),
                                      np.asarray(fam_p.hash_batch(xs)))

    @pytest.mark.parametrize("kind", SRP_KINDS)
    def test_packed_bit_identical(self, kind):
        fam_x, fam_p = _families(kind)
        xs = _batch(kind, 8, _native_fmt(kind))
        px = np.asarray(fam_x.hash_packed_batch(xs))
        pp = np.asarray(fam_p.hash_packed_batch(xs))
        assert px.shape == (8, L, 1)  # K=5 -> one uint32 word per table
        np.testing.assert_array_equal(px, pp, err_msg=kind)
        np.testing.assert_array_equal(px, pack_bits(fam_x.hash_batch(xs)))

    @pytest.mark.parametrize("kind", ["cp-srp", "tt-e2lsh"])
    def test_single_hash_matches_batch_row(self, kind):
        """hash(x) is the batch-of-1 case on both backends."""
        for fam in _families(kind):
            xs = _batch(kind, 4, _native_fmt(kind))
            hb = np.asarray(fam.hash_batch(xs))
            h0 = np.asarray(fam.hash(jax.tree.map(lambda a: a[0], xs)))
            np.testing.assert_array_equal(h0, hb[0])


class TestIndexLevelParity:
    """The segment build consumes hash_keys: a pallas-backed index must
    produce bit-identical sorted bucket tables and query results."""

    @pytest.mark.parametrize("kind", ["cp-e2lsh", "tt-srp"])
    def test_build_and_query_parity(self, kind):
        fmt = _native_fmt(kind)
        corpus = _batch(kind, 48, fmt, seed=5)
        queries = _batch(kind, 6, fmt, seed=6)
        fam_x, fam_p = _families(kind, seed=7)
        metric = grids.metric_for(kind)
        ix = DeviceLSHIndex(fam_x, metric=metric).build(corpus)
        ip = DeviceLSHIndex(fam_p, metric=metric).build(corpus)
        np.testing.assert_array_equal(np.asarray(ix.sorted_keys),
                                      np.asarray(ip.sorted_keys))
        np.testing.assert_array_equal(np.asarray(ix.perm),
                                      np.asarray(ip.perm))
        for a, b in zip(ix.query_batch(queries, topk=5),
                        ip.query_batch(queries, topk=5)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestBackendDispatch:
    def test_make_family_rejects_unknown_backend(self):
        with pytest.raises(ValueError, match="hash_backend"):
            make_family(_key(0), "cp-srp", DIMS, hash_backend="cuda")

    def test_resolved_backend_explicit_wins(self):
        fam_x, fam_p = _families("cp-srp")
        assert fam_x.resolved_backend() == "xla"
        assert fam_p.resolved_backend() == "pallas"

    def test_auto_resolves_by_platform(self):
        fam = make_family(_key(0), "cp-srp", DIMS, num_codes=K, num_tables=L)
        assert fam.hash_backend == "auto"
        want = "pallas" if jax.default_backend() == "tpu" else "xla"
        env = os.environ.get("REPRO_HASH_BACKEND", "").strip().lower()
        assert fam.resolved_backend() == (env or want)

    def test_env_var_overrides_auto_not_explicit(self):
        """REPRO_HASH_BACKEND steers 'auto' families (the CI pallas leg)
        but never an explicitly-pinned backend."""
        code = """
        import os
        os.environ["REPRO_HASH_BACKEND"] = "pallas"
        import jax
        from repro.core import make_family
        auto = make_family(jax.random.PRNGKey(0), "cp-srp", (7, 7, 7))
        pinned = make_family(jax.random.PRNGKey(0), "cp-srp", (7, 7, 7),
                             hash_backend="xla")
        assert auto.resolved_backend() == "pallas", auto.resolved_backend()
        assert pinned.resolved_backend() == "xla", pinned.resolved_backend()
        print("env override ok")
        """
        import textwrap
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "env override ok" in out.stdout
