"""Shared pytest configuration for the tier-1 suites."""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: statistically heavy tier-1 tests (bigger corpora / many "
        "sampling draws); run by default, deselect with -m 'not slow'")


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_programs_per_module():
    """Every compiled XLA program the suite touches stays pinned in jit
    caches, and each one holds several LLVM JIT code mappings.  Across the
    full suite that exhausts the kernel's per-process ``vm.max_map_count``
    (65530 by default) and the next compile segfaults inside XLA.  Modules
    share almost no (function, shape) cache entries, so dropping the caches
    at module boundaries caps the mapping count at the per-module peak for
    the price of a handful of recompiles."""
    yield
    import jax

    jax.clear_caches()
