"""Shared pytest configuration for the tier-1 suites."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: statistically heavy tier-1 tests (bigger corpora / many "
        "sampling draws); run by default, deselect with -m 'not slow'")
