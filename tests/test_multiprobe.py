"""Query-directed multi-probe + bucket-sampling query modes, pinned by a
seeded cross-layout parity/statistics matrix.

Four contracts, each failing loudly rather than degrading:

  1. **T=1 bit-identity.** ``probes=1`` must execute the exact single-probe
     program: for every kind x metric x layout (device, sharded S in
     {1, 2, 4}) x {fresh, mutated} cell, ``query_batch(..., probes=1)`` is
     bit-identical (ids, scores, counts) to the probes-less call, and
     ``probe_keys`` slot 0 is bit-identical to ``hash_keys``.
  2. **Expansion correctness.** The (B, L, T) candidate keys match an
     independent host-side enumeration of the perturbation set (numpy
     float32 scoring, Python stable sort, uint32 wraparound) exactly; T>1
     candidate sets are supersets of T=1 and equal the host dict reference
     (``HostLSHIndex.candidates(probes=T)``).
  3. **Planner dedup.** ``n_candidates`` equals the *distinct* member count
     across the T probed buckets per table — pinned against the host dict
     union at T in {1, 4} and through the pad-repeat regime (T - 1 > the
     expansion size), where naive per-window counting would overcount.
  4. **Sampling statistics.** ``mode="uniform"`` / ``"weighted"`` draw
     distinct members of the probed union with the advertised frequencies:
     seeded chi-square checks with generous bounds (fixed PRNG keys, fully
     deterministic — no flakiness), replay determinism per seed, and the
     explicit-seed error contract on index and service.

Sharded cells assert ``grids.assert_query_path`` so the CI 4-device leg
(which runs this file in-process) fails on a silent shard_map -> vmap
fallback instead of silently testing the wrong program.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import grids
from grids import ALL_KINDS, DIMS, SHARD_COUNTS
from repro.core import (DeviceLSHIndex, HostLSHIndex, ShardedLSHIndex,
                        make_family)
from repro.core import probing
from repro.core.index import recall_at_k
from repro.core.lsh import E2LSH_KINDS, _combine_codes, make_mults
from repro.serving.lsh_service import LSHService

N_CORPUS, N_QUERIES, TOPK = 67, 4, 5   # 67 coprime to every shard count


def _data(seed=0):
    return grids.corpus_and_queries(N_CORPUS, N_QUERIES, seed=seed)


def _family(kind):
    return grids.grid_family(kind)


def _mutate(index, corpus):
    """A small insert + delete interleaving (delta segment + tombstones
    outstanding) so the multi-probe path is exercised over a mutated
    store, not just the contiguous fresh build."""
    ins = jax.random.normal(jax.random.PRNGKey(100), (11,) + DIMS)
    index.insert(ins)
    index.delete(np.array([3, 40, 50, 70]))
    return index


def _assert_bit_identical(got, want, msg=None):
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# Host-side reference enumeration (independent of repro.core.probing's
# vectorized ranking: numpy float32 scores, Python stable sort, explicit
# uint32 delta arithmetic)
# ---------------------------------------------------------------------------


_M32 = 1 << 32


def _reference_probe_keys(fam, mults, queries, probes):
    """(B, L, T) uint32 via per-(query, table) Python enumeration: delta
    arithmetic in Python ints mod 2^32, scores in numpy float32 (matching
    the device program's dtype so the ranking ties out exactly), Python's
    stable ``sorted`` mirroring the stable argsort tie-break."""
    codes, aux = (np.asarray(a) for a in fam.hash_batch_aux(queries))
    base = _combine_codes(codes, np.asarray(mults, np.uint32))    # (B, L)
    k = fam.num_codes
    m_int = [int(m) for m in np.asarray(mults, np.uint32)]
    b, el = base.shape
    out = np.empty((b, el, probes), np.uint32)
    for i in range(b):
        for t in range(el):
            if fam.kind in E2LSH_KINDS:
                r = aux[i, t].astype(np.float32)
                s1 = list((np.float32(1.0) - r) ** 2) + list(r ** 2)
                d1 = m_int + [(-m) % _M32 for m in m_int]
                coord = list(range(k)) * 2
            else:
                v = aux[i, t].astype(np.float32)
                s1 = list(np.abs(v))
                d1 = [(-m) % _M32 if x > 0 else m
                      for x, m in zip(v, m_int)]
                coord = list(range(k))
            cand = [(s1[a], d1[a]) for a in range(len(s1))]
            cand += [(np.float32(s1[a] + s1[p]), (d1[a] + d1[p]) % _M32)
                     for a in range(len(s1)) for p in range(a + 1, len(s1))
                     if coord[a] != coord[p]]
            ranked = sorted(range(len(cand)), key=lambda j: cand[j][0])
            keys = [int(base[i, t])]
            keys += [(int(base[i, t]) + cand[j][1]) % _M32
                     for j in ranked[:probes - 1]]
            keys += [int(base[i, t])] * (probes - len(keys))  # pad regime
            out[i, t] = keys
    return out


# ---------------------------------------------------------------------------
# 1. T=1 bit-identity across the full layout matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,metric", grids.cell_params())
class TestSingleProbeBitIdentity:
    @pytest.mark.parametrize("mutated", [False, True],
                             ids=["fresh", "mutated"])
    def test_device_probes1_bit_identical(self, kind, metric, mutated):
        corpus, queries = _data()
        index = DeviceLSHIndex(_family(kind), metric=metric).build(corpus)
        if mutated:
            _mutate(index, corpus)
        _assert_bit_identical(
            index.query_batch(queries, topk=TOPK, probes=1),
            index.query_batch(queries, topk=TOPK),
            (kind, metric, "device", "mutated" if mutated else "fresh"))

    @pytest.mark.parametrize("mutated", [False, True],
                             ids=["fresh", "mutated"])
    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    def test_sharded_probes1_bit_identical(self, kind, metric, shards,
                                           mutated):
        corpus, queries = _data()
        index = ShardedLSHIndex(_family(kind), metric=metric,
                                shards=shards).build(corpus)
        if mutated:
            _mutate(index, corpus)
        grids.assert_query_path(index)
        _assert_bit_identical(
            index.query_batch(queries, topk=TOPK, probes=1),
            index.query_batch(queries, topk=TOPK),
            (kind, metric, shards, "mutated" if mutated else "fresh"))


# ---------------------------------------------------------------------------
# 2. Expansion correctness vs the host reference
# ---------------------------------------------------------------------------


def make_mults_for(fam):
    return make_mults(0, fam.num_codes)   # the index default (seed=0)


@pytest.mark.parametrize("kind", ALL_KINDS)
class TestExpansion:
    def test_probe_keys_slot0_is_hash_keys(self, kind):
        _, queries = _data()
        fam = _family(kind)
        mults = jnp.asarray(make_mults_for(fam))
        keys = probing.probe_keys(fam, mults, queries, probes=1)
        assert keys.shape == (N_QUERIES, fam.num_tables, 1)
        np.testing.assert_array_equal(
            np.asarray(keys[..., 0]),
            np.asarray(fam.hash_keys(queries, mults)))
        # slot 0 of a wide expansion is the same base key
        wide = probing.probe_keys(fam, mults, queries, probes=6)
        np.testing.assert_array_equal(np.asarray(wide[..., 0]),
                                      np.asarray(keys[..., 0]))

    @pytest.mark.parametrize("probes", [2, 8])
    def test_probe_keys_match_reference_enumeration(self, kind, probes):
        _, queries = _data()
        fam = _family(kind)
        mults = make_mults_for(fam)
        got = np.asarray(probing.probe_keys(
            fam, jnp.asarray(mults), queries, probes=probes))
        want = _reference_probe_keys(fam, mults, queries, probes)
        np.testing.assert_array_equal(got, want, err_msg=(kind, probes))

    def test_first_keys_distinct(self, kind):
        _, queries = _data()
        fam = _family(kind)
        c = probing.expansion_size(kind, fam.num_codes)
        t = min(8, c + 1)
        keys = np.asarray(probing.probe_keys(
            fam, jnp.asarray(make_mults_for(fam)), queries, probes=t))
        for i in range(N_QUERIES):
            for tb in range(fam.num_tables):
                assert len(set(keys[i, tb].tolist())) == t, (kind, i, tb)

    def test_candidates_superset_and_match_host(self, kind):
        corpus, queries = _data()
        fam = _family(kind)
        metric = grids.metric_for(kind)
        host = HostLSHIndex(fam, metric=metric).build(corpus)
        device = DeviceLSHIndex(fam, metric=metric).build(corpus)
        for i in range(N_QUERIES):
            x = queries[i]
            one = set(host.candidates(x, probes=1).tolist())
            four = set(host.candidates(x, probes=4).tolist())
            assert one <= four, (kind, i)
            cand, valid = device.candidates_batch(queries[i:i + 1], probes=4)
            dev = set(np.asarray(cand)[0][np.asarray(valid)[0]].tolist())
            assert dev == four, (kind, i)

    def test_expansion_size_values(self, kind):
        fam = _family(kind)
        k = fam.num_codes
        want = 2 * k * k if kind in E2LSH_KINDS else k + k * (k - 1) // 2
        assert probing.expansion_size(kind, k) == want

    def test_probes_validation(self, kind):
        _, queries = _data()
        fam = _family(kind)
        with pytest.raises(ValueError, match="probes"):
            probing.probe_keys(fam, jnp.asarray(make_mults_for(fam)),
                               queries, probes=0)


# ---------------------------------------------------------------------------
# 3. Planner dedup: n_candidates is the distinct probed-union size
# ---------------------------------------------------------------------------


class TestPlannerDedup:
    @pytest.mark.parametrize("probes", [1, 4])
    @pytest.mark.parametrize("kind", ["tt-e2lsh", "cp-srp"])
    def test_n_candidates_is_distinct_union(self, kind, probes):
        corpus, queries = _data()
        fam = _family(kind)
        metric = grids.metric_for(kind)
        host = HostLSHIndex(fam, metric=metric).build(corpus)
        device = DeviceLSHIndex(fam, metric=metric).build(corpus)
        _, _, n_cand = device.query_batch(queries, topk=TOPK, probes=probes)
        want = [host.candidates(queries[i], probes=probes).size
                for i in range(N_QUERIES)]
        np.testing.assert_array_equal(np.asarray(n_cand), want,
                                      err_msg=(kind, probes))

    def test_pad_repeats_collapse(self):
        """T - 1 > expansion size: the pad slots repeat the base key per
        table, so every member of the base bucket enters the window T - C
        extra times — the dedup must still count it once."""
        corpus, queries = _data()
        fam = make_family(jax.random.PRNGKey(3), "srp", DIMS, num_codes=2,
                          num_tables=3, rank=2, bucket_width=1.0)
        c = probing.expansion_size("srp", 2)
        assert c == 3  # 2 singles + 1 pair; probes=8 pads 4 repeat slots
        host = HostLSHIndex(fam, metric="cosine").build(corpus)
        device = DeviceLSHIndex(fam, metric="cosine").build(corpus)
        _, _, n_cand = device.query_batch(queries, topk=TOPK, probes=8)
        want = [host.candidates(queries[i], probes=8).size
                for i in range(N_QUERIES)]
        np.testing.assert_array_equal(np.asarray(n_cand), want)

    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    def test_sharded_n_candidates_matches_device(self, shards):
        corpus, queries = _data()
        fam = _family("tt-e2lsh")
        device = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        sharded = ShardedLSHIndex(fam, metric="euclidean",
                                  shards=shards).build(corpus)
        grids.assert_query_path(sharded)
        for probes in (1, 4):
            d = device.query_batch(queries, topk=TOPK, probes=probes)
            s = sharded.query_batch(queries, topk=TOPK, probes=probes)
            np.testing.assert_array_equal(np.asarray(s[0]),
                                          np.asarray(d[0]))
            np.testing.assert_array_equal(np.asarray(s[2]),
                                          np.asarray(d[2]))


# ---------------------------------------------------------------------------
# 4. Sampling query modes
# ---------------------------------------------------------------------------


def _host_union_and_weights(host, x, probes):
    """(union set, multiplicity dict) counting every (table, probe-slot)
    window ticket — including repeated probe keys in the pad regime —
    exactly as the device's raw pre-dedup window does."""
    mults = host._mults
    keys = np.asarray(probing.probe_keys(
        host.family, jnp.asarray(mults),
        jax.tree.map(lambda a: a[None], x), probes=int(probes)))
    weights: dict[int, int] = {}
    for t in range(host.family.num_tables):
        for key in keys[0, t]:
            for member in host._tables[t].get(int(key), ()):
                weights[member] = weights.get(member, 0) + 1
    return set(weights), weights


class TestSamplingModes:
    KIND, PROBES = "e2lsh", 4

    def _build(self, metric="euclidean"):
        corpus, queries = _data()
        fam = _family(self.KIND)
        host = HostLSHIndex(fam, metric=metric).build(corpus)
        device = DeviceLSHIndex(fam, metric=metric).build(corpus)
        return corpus, queries, host, device

    @pytest.mark.parametrize("mode", ["uniform", "weighted"])
    def test_samples_are_distinct_members_of_probed_union(self, mode):
        _, queries, host, device = self._build()
        rng = jax.random.PRNGKey(17)
        ids, scores, n_cand = device.query_batch(
            queries, topk=TOPK, probes=self.PROBES, mode=mode, rng=rng)
        t_ids, _, t_n = device.query_batch(queries, topk=TOPK,
                                           probes=self.PROBES)
        # n_candidates agrees with the exact top-k path (same dedup)
        np.testing.assert_array_equal(np.asarray(n_cand), np.asarray(t_n))
        for i in range(N_QUERIES):
            union, _ = _host_union_and_weights(host, queries[i], self.PROBES)
            row = np.asarray(ids)[i]
            valid = row[row >= 0].tolist()
            assert len(valid) == min(TOPK, len(union))
            assert len(set(valid)) == len(valid)          # distinct
            assert set(valid) <= union, (mode, i)

    @pytest.mark.parametrize("mode", ["uniform", "weighted"])
    def test_topk_at_least_union_returns_whole_union(self, mode):
        _, queries, host, device = self._build()
        big = N_CORPUS + 1
        ids, _, _ = device.query_batch(queries, topk=big, probes=self.PROBES,
                                       mode=mode, rng=jax.random.PRNGKey(5))
        for i in range(N_QUERIES):
            union, _ = _host_union_and_weights(host, queries[i], self.PROBES)
            row = np.asarray(ids)[i]
            assert set(row[row >= 0].tolist()) == union, (mode, i)

    @pytest.mark.parametrize("mode", ["uniform", "weighted"])
    def test_seed_replay_determinism(self, mode):
        _, queries, _, device = self._build()
        a = device.query_batch(queries, topk=TOPK, probes=self.PROBES,
                               mode=mode, rng=jax.random.PRNGKey(23))
        b = device.query_batch(queries, topk=TOPK, probes=self.PROBES,
                               mode=mode, rng=jax.random.PRNGKey(23))
        _assert_bit_identical(a, b, mode)
        # different seeds give different draws: 64 independent single-item
        # draws of the same query cannot coincide across seeds (the fixture
        # union has >= 5 members; checked deterministic for these seeds)
        batch = jax.tree.map(
            lambda x: jnp.broadcast_to(x[:1], (64,) + x.shape[1:]), queries)
        c = device.query_batch(batch, topk=1, probes=self.PROBES,
                               mode=mode, rng=jax.random.PRNGKey(23))
        d = device.query_batch(batch, topk=1, probes=self.PROBES,
                               mode=mode, rng=jax.random.PRNGKey(24))
        assert not np.array_equal(np.asarray(c[0]), np.asarray(d[0])), (
            "different seeds drew identical samples across 64 draws")

    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    @pytest.mark.parametrize("mode", ["uniform", "weighted"])
    def test_sharded_sampling_membership(self, mode, shards):
        corpus, queries, host, device = self._build()
        sharded = ShardedLSHIndex(_family(self.KIND), metric="euclidean",
                                  shards=shards).build(corpus)
        rng = jax.random.PRNGKey(31)
        ids, _, n_cand = sharded.query_batch(
            queries, topk=TOPK, probes=self.PROBES, mode=mode, rng=rng)
        _, _, t_n = device.query_batch(queries, topk=TOPK,
                                       probes=self.PROBES)
        np.testing.assert_array_equal(np.asarray(n_cand), np.asarray(t_n))
        for i in range(N_QUERIES):
            union, _ = _host_union_and_weights(host, queries[i], self.PROBES)
            row = np.asarray(ids)[i]
            valid = row[row >= 0].tolist()
            assert len(set(valid)) == len(valid) and set(valid) <= union

    def test_sampling_skips_tombstones(self):
        corpus, queries, _, device = self._build()
        _mutate(device, corpus)
        eff = device.effective_corpus()
        n = jax.tree.leaves(eff)[0].shape[0]
        for mode in ("uniform", "weighted"):
            ids, _, _ = device.query_batch(
                queries, topk=TOPK, probes=self.PROBES, mode=mode,
                rng=jax.random.PRNGKey(41))
            row = np.asarray(ids)
            assert row.max() < n
            # ids are effective (live) ids: parity with the topk path's
            # candidate universe
            t_ids, _, _ = device.query_batch(queries, topk=N_CORPUS,
                                             probes=self.PROBES)
            universe = set(np.asarray(t_ids)[np.asarray(t_ids) >= 0]
                           .tolist())
            assert set(row[row >= 0].tolist()) <= universe


class TestSamplingStatistics:
    """Seeded chi-square checks: one query replicated B times in a single
    batch (independent per-row draws), topk=1, so each row contributes one
    categorical sample. Bounds are ~6 sigma above the chi-square mean plus
    a flat margin — fixed seeds make the test fully deterministic; the
    bound only documents how far from the advertised distribution a broken
    sampler would land."""

    B = 2048
    PROBES = 8   # the wide expansion: unions of ~10-30 members with raw
                 # window multiplicities spread 1..4 on the grid fixture

    def _freqs(self, kind, mode, seed):
        corpus, queries = _data()
        fam = _family(kind)
        metric = grids.metric_for(kind)
        host = HostLSHIndex(fam, metric=metric).build(corpus)
        device = DeviceLSHIndex(fam, metric=metric).build(corpus)
        x = queries[1]
        batch = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.B,) + a.shape), x)
        ids, _, _ = device.query_batch(batch, topk=1, probes=self.PROBES,
                                       mode=mode, rng=jax.random.PRNGKey(seed))
        drawn = np.asarray(ids)[:, 0]
        assert (drawn >= 0).all()
        union, weights = _host_union_and_weights(host, x, self.PROBES)
        assert len(union) >= 5, "fixture bucket structure collapsed"
        counts = {m: int((drawn == m).sum()) for m in union}
        assert sum(counts.values()) == self.B   # every draw is a member
        return counts, union, weights

    @staticmethod
    def _chi2(counts, expected):
        return sum((counts[m] - e) ** 2 / e for m, e in expected.items())

    @staticmethod
    def _bound(df):
        return 2 * df + 6 * (2 * df) ** 0.5 + 20

    @pytest.mark.parametrize("kind", ["e2lsh", "tt-srp"])
    def test_uniform_frequencies(self, kind):
        counts, union, _ = self._freqs(kind, "uniform", seed=101)
        expected = {m: self.B / len(union) for m in union}
        df = len(union) - 1
        assert self._chi2(counts, expected) < self._bound(df), (
            kind, counts, expected)

    @pytest.mark.parametrize("kind", ["e2lsh", "tt-srp"])
    def test_weighted_frequencies(self, kind):
        counts, union, weights = self._freqs(kind, "weighted", seed=202)
        total = sum(weights.values())
        expected = {m: self.B * weights[m] / total for m in union}
        df = len(union) - 1
        assert max(weights.values()) > min(weights.values()), (
            "fixture has no weight spread; the test cannot distinguish "
            "weighted from uniform")
        assert self._chi2(counts, expected) < self._bound(df), (
            kind, counts, expected)

    def test_weighted_differs_from_uniform(self):
        """The weighted draw must NOT fit the uniform null: with the pad /
        overlap multiplicities of the fixture, the uniform-expected chi2 of
        the weighted draw exceeds the bound that the correctly-matched
        expectation stays under."""
        counts, union, weights = self._freqs("e2lsh", "weighted", seed=202)
        uniform_expected = {m: self.B / len(union) for m in union}
        df = len(union) - 1
        assert self._chi2(counts, uniform_expected) > self._bound(df)


class TestModeContracts:
    def _index(self):
        corpus, queries = _data()
        return (DeviceLSHIndex(_family("e2lsh"),
                               metric="euclidean").build(corpus), queries)

    def test_unknown_mode_rejected(self):
        index, queries = self._index()
        with pytest.raises(ValueError, match="unknown query mode"):
            index.query_batch(queries, mode="nearest")

    def test_topk_mode_rejects_rng(self):
        index, queries = self._index()
        with pytest.raises(ValueError, match="sampling modes only"):
            index.query_batch(queries, mode="topk",
                              rng=jax.random.PRNGKey(0))

    @pytest.mark.parametrize("mode", ["uniform", "weighted"])
    def test_sampling_requires_rng(self, mode):
        index, queries = self._index()
        with pytest.raises(ValueError, match="PRNGKey"):
            index.query_batch(queries, mode=mode)

    def test_service_contracts(self):
        corpus, queries = _data()
        fam = _family("e2lsh")
        with pytest.raises(ValueError, match="probes"):
            LSHService(fam, probes=0)
        with pytest.raises(ValueError, match="query_mode"):
            LSHService(fam, query_mode="nearest")
        svc = LSHService(fam, metric="euclidean")
        svc.build(corpus)
        with pytest.raises(ValueError, match="seed"):
            svc.query_arrays(queries, mode="uniform")       # no seed
        with pytest.raises(ValueError, match="seed"):
            svc.query_arrays(queries, mode="topk", seed=1)  # spurious seed
        with pytest.raises(ValueError, match="unknown query mode"):
            svc.query_arrays(queries, mode="nearest")

    def test_service_rejects_bad_override_values(self):
        """Per-request ``probes``/``topk`` overrides are validated at the
        service boundary — a bad value must raise, not silently dispatch a
        nonsense program (or worse, a negative-size gather)."""
        corpus, queries = _data()
        svc = LSHService(_family("e2lsh"), metric="euclidean").build(corpus)
        for probes in (0, -1, -7):
            with pytest.raises(ValueError, match="probes must be >= 1"):
                svc.query_arrays(queries, probes=probes)
        for topk in (0, -1, -5):
            with pytest.raises(ValueError, match="topk must be >= 1"):
                svc.query_arrays(queries, topk=topk)
        # the rejected requests must not have dispatched or been counted
        assert svc.stats.topk_queries == 0
        ids, _, _ = svc.query_arrays(queries, probes=2, topk=3)
        assert ids.shape == (len(queries), 3)
        assert svc.stats.topk_queries == N_QUERIES

    def test_service_mode_counters_and_replay(self):
        corpus, queries = _data()
        svc = LSHService(_family("e2lsh"), metric="euclidean", probes=4)
        svc.build(corpus)
        svc.query_arrays(queries, topk=TOPK)
        a = svc.query_arrays(queries, topk=TOPK, mode="uniform", seed=99)
        b = svc.query_arrays(queries, topk=TOPK, mode="uniform", seed=99)
        svc.query_arrays(queries, topk=TOPK, mode="weighted", seed=7)
        _assert_bit_identical(a, b, "same seed must replay the same draw")
        assert svc.stats.topk_queries == N_QUERIES
        assert svc.stats.uniform_queries == 2 * N_QUERIES
        assert svc.stats.weighted_queries == N_QUERIES
        assert svc.stats.queries == 4 * N_QUERIES


# ---------------------------------------------------------------------------
# 5. Recall pin: the (L, T) trade-off the multi-probe expansion exists for
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestRecallTradeoff:
    """A quarter of the tables at T=8 must not lose more than 0.02
    recall@10 vs the full-L single-probe index on a seeded clustered 4k
    corpus (benchmarks/index_multiprobe sweeps the same grid; measured
    slack at this seed is ~ +0.03 — multi-probe at L/4 *beats* L)."""

    def test_quarter_tables_t8_holds_recall(self):
        dims = (8, 8, 8)
        n_clusters, per_cluster, noise = 512, 8, 0.15
        kc, kn, kq, kf = jax.random.split(jax.random.PRNGKey(7), 4)
        centers = jax.random.normal(kc, (n_clusters,) + dims)
        corpus = (jnp.repeat(centers, per_cluster, axis=0)
                  + noise * jax.random.normal(
                      kn, (n_clusters * per_cluster,) + dims))
        queries = centers[:128] + noise * jax.random.normal(
            kq, (128,) + dims)

        def build(num_tables):
            fam = make_family(kf, "cp-e2lsh", dims, num_codes=4,
                              num_tables=num_tables, rank=2,
                              bucket_width=16.0)
            return DeviceLSHIndex(fam, metric="euclidean").build(corpus)

        full = recall_at_k(build(8), queries, topk=10, probes=1)
        quarter = recall_at_k(build(2), queries, topk=10, probes=8)
        assert quarter["recall"] >= full["recall"] - 0.02, (quarter, full)
        # and multi-probe actually probes more than it keeps tables
        assert quarter["mean_candidates"] > 0
