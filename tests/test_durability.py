"""Crash recovery must be invisible: the chaos matrix of the durable index.

The contract pinned here, per store layout (device + sharded S in
{1, 2, 4}) and across every hash-family kind:

* **Commit point** — an operation is durable iff its WAL append
  completed. For every named crash point (``pre_wal_append`` /
  ``post_wal_append`` / ``mid_snapshot`` / ``pre_apply_swap``) and for
  seeded random kill schedules over interleaved
  insert/delete/query/compact traffic, a recovered service answers
  queries (ids, scores, counts, candidate sets) **bit-identically** to a
  fresh service that applied exactly the committed prefix.
* **WAL edge cases** — a torn final record is dropped; checksum damage
  or an lsn gap before the tail raises ``WalCorrupted``; an empty log
  and a snapshot-with-no-log recover cleanly; replay crosses
  ``apply_swap`` epoch markers. Never a silent partial store.
* **Degraded-mode serving** — transient WAL failures retry with backoff
  on the scheduler's ingest lane; exhausted retries or an injected crash
  degrade the namespace, which then sheds with ``ServiceUnavailable``
  until ``recover_namespace()`` replays it back; poisoned mutations and
  expired requests land in the ``errors``/``timeouts`` counters instead
  of vanishing with a dropped future.
"""

import os
import struct
import time
import zlib

import numpy as np
import pytest

import grids
from repro.serving.durability import (_ALIGN, CRASH_POINTS,
                                      DurableLSHService, FaultInjector,
                                      InjectedCrash, RecoveryError,
                                      ServiceUnavailable, TransientIOError,
                                      WalCorrupted, read_wal)
from repro.serving.lsh_service import LSHService
from repro.serving.scheduler import RequestTimeout, ServingScheduler

TOPK = 6
N_CORPUS = 67          # coprime to every shard count: padded last shard
N_QUERIES = 5
KIND = "cp-e2lsh"
LAYOUTS = (None,) + grids.SHARD_COUNTS    # device + sharded S in {1,2,4}
NO_SNAP = 10 ** 9      # snapshot_every that never triggers mid-test

# Which side of the crash point the in-flight operation lands on:
# pre_wal_append fires before the record exists (not committed); the other
# points fire after the fsync (committed, even though the caller saw the
# crash).
COMMITS_INFLIGHT = {"pre_wal_append": False, "post_wal_append": True,
                    "mid_snapshot": True, "pre_apply_swap": True}


def _fixture():
    return grids.corpus_and_queries(N_CORPUS, N_QUERIES)


def _durable(directory, shards=None, kind=KIND, injector=None,
             snapshot_every=NO_SNAP, build=True, **kw):
    kw.setdefault("bucket_cap", 16)
    kw.setdefault("max_deltas", 64)
    svc = DurableLSHService(grids.grid_family(kind), str(directory),
                            metric=grids.metric_for(kind), shards=shards,
                            injector=injector,
                            snapshot_every=snapshot_every, **kw)
    if build:
        svc.build(_fixture()[0])
    return svc


def _recovered(directory, shards=None, kind=KIND, **kw):
    return _durable(directory, shards=shards, kind=kind, build=False,
                    **kw).recover()


def _plain(shards=None, kind=KIND, **kw):
    kw.setdefault("bucket_cap", 16)
    kw.setdefault("max_deltas", 64)
    return LSHService(grids.grid_family(kind), metric=grids.metric_for(kind),
                      shards=shards, **kw).build(_fixture()[0])


def _schedule(seed, n_ops, live=N_CORPUS):
    """A deterministic interleaved op list. Delete ids are drawn against
    the simulated live count, so applying any prefix to any equally-built
    service is well-defined."""
    rng = np.random.RandomState(seed)
    ops = []
    for _ in range(n_ops):
        r = rng.rand()
        if r < 0.55 or live < 16:
            k = int(rng.randint(1, 7))
            ops.append(("insert", rng.randn(k, *grids.DIMS)
                        .astype(np.float32)))
            live += k
        elif r < 0.85:
            ids = np.unique(rng.randint(0, live, size=int(rng.randint(1, 4))))
            ops.append(("delete", ids.astype(np.int64)))
            live -= len(ids)
        else:
            ops.append(("compact", None))
    return ops


def _fixed_ops():
    """insert/delete/compact mix with the epoch markers at known slots
    (records 3 and 6), so every crash point can be aimed precisely."""
    rng = np.random.RandomState(3)
    mk = lambda k: rng.randn(k, *grids.DIMS).astype(np.float32)
    return [("insert", mk(5)), ("delete", np.array([3, 11])),
            ("insert", mk(4)), ("compact", None), ("insert", mk(3)),
            ("delete", np.array([0, 20, 40])), ("compact", None),
            ("insert", mk(6))]


def _apply(svc, op):
    kind, arg = op
    if kind == "insert":
        svc.insert(arg)
    elif kind == "delete":
        svc.delete(arg)
    else:
        svc.compact()


def _run_until_crash(svc, ops, queries=None):
    """Apply ops until an injected crash; -> (applied_ops, inflight_op).
    ``queries`` interleaves query traffic between mutations (the store
    must serve bit-identically throughout; crash points never fire on the
    query path)."""
    applied = []
    for i, op in enumerate(ops):
        try:
            _apply(svc, op)
        except InjectedCrash:
            return applied, op
        applied.append(op)
        if queries is not None and i % 3 == 2:
            svc.query_arrays(queries[:2], topk=4)
    return applied, None


def _committed(applied, inflight, point):
    if inflight is not None and COMMITS_INFLIGHT[point]:
        return applied + [inflight]
    return applied


def _assert_bit_identical(got, want, queries):
    """ids, scores, counts AND candidate sets, all exactly equal."""
    a, b = got.query_arrays(queries, topk=TOPK), \
        want.query_arrays(queries, topk=TOPK)
    for name, x, y in zip(("ids", "scores", "n_cand"), a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)
    for q in np.asarray(queries)[:3]:
        np.testing.assert_array_equal(got.index.candidates(q),
                                      want.index.candidates(q),
                                      err_msg="candidate set")


def _wal_paths(directory):
    return sorted(os.path.join(str(directory), n)
                  for n in os.listdir(str(directory))
                  if n.startswith("wal_") and n.endswith(".log"))


def _frames(path):
    """(offset, length) of each record in a segment (aligned stepping,
    zero-length sentinel = end)."""
    with open(path, "rb") as f:
        data = f.read()
    out, off = [], 0
    while off + 8 <= len(data):
        length, _ = struct.unpack_from("<II", data, off)
        if length == 0:
            break
        out.append((off, length))
        off = (off + 8 + length + _ALIGN - 1) // _ALIGN * _ALIGN
    return out


# ---------------------------------------------------------------------------
# Recovery parity
# ---------------------------------------------------------------------------


class TestRecoveryParity:
    @pytest.mark.parametrize("shards", LAYOUTS)
    def test_clean_recovery_matches_live_service(self, tmp_path, shards):
        _, queries = _fixture()
        svc = _durable(tmp_path, shards=shards)
        for op in _fixed_ops():
            _apply(svc, op)
        rec = _recovered(tmp_path, shards=shards)
        if shards is not None:
            grids.assert_query_path(rec.index)
        _assert_bit_identical(rec, svc, queries)
        assert rec.stats.recoveries == 1
        assert rec.stats.compactions == 2     # replayed both epoch markers
        assert rec.health == "serving"
        # the recovered WAL accepts new commits and they recover again
        # (close the original's log first: one writer per directory)
        svc.close()
        extra = np.float32(np.random.RandomState(5).randn(3, *grids.DIMS))
        rec.insert(extra)
        ref = _plain(shards=shards)
        for op in _fixed_ops() + [("insert", extra)]:
            _apply(ref, op)
        _assert_bit_identical(_recovered(tmp_path, shards=shards), ref,
                              queries)

    @pytest.mark.parametrize("shards", LAYOUTS)
    @pytest.mark.parametrize("point", CRASH_POINTS)
    def test_crash_point_matrix(self, tmp_path, shards, point):
        """Every durability boundary: kill there, recover, compare to a
        fresh service that applied exactly the committed prefix."""
        inj = FaultInjector()
        snap_every = 4 if point == "mid_snapshot" else NO_SNAP
        # mid_snapshot: skip the build's initial snapshot, hit the first
        # periodic one; the append points aim mid-schedule
        inj.crash_at(point, after={"pre_apply_swap": 0, "mid_snapshot": 1}
                     .get(point, 3))
        _, queries = _fixture()
        svc = _durable(tmp_path, shards=shards, injector=inj,
                       snapshot_every=snap_every)
        applied, inflight = _run_until_crash(svc, _fixed_ops(), queries)
        assert inflight is not None, "the armed crash point never fired"
        rec = _recovered(tmp_path, shards=shards)
        ref = _plain(shards=shards)
        for op in _committed(applied, inflight, point):
            _apply(ref, op)
        _assert_bit_identical(rec, ref, queries)

    @pytest.mark.parametrize("shards", LAYOUTS)
    @pytest.mark.parametrize("seed", (1, 2))
    def test_random_kill_schedule(self, tmp_path, shards, seed):
        """Seeded chaos: a random kill point over a random interleaved
        schedule, with periodic snapshots in the mix."""
        rng = np.random.RandomState(97 * seed + (0 if shards is None
                                                 else shards))
        point = CRASH_POINTS[rng.randint(len(CRASH_POINTS))]
        after = int(rng.randint(0, 8)) + (point == "mid_snapshot")
        inj = FaultInjector().crash_at(point, after=after)
        _, queries = _fixture()
        svc = _durable(tmp_path, shards=shards, injector=inj,
                       snapshot_every=6)
        ops = _schedule(seed=int(rng.randint(10 ** 6)), n_ops=14)
        applied, inflight = _run_until_crash(svc, ops, queries)
        rec = _recovered(tmp_path, shards=shards)
        ref = _plain(shards=shards)
        for op in _committed(applied, inflight, point):
            _apply(ref, op)
        _assert_bit_identical(rec, ref, queries)

    # one chaos cell per kind x shard-count; the fast leg keeps the
    # canonical kind's full S sweep plus every kind at S=2, the full leg
    # runs the whole matrix
    @pytest.mark.parametrize(
        "kind,shards",
        [pytest.param(kind, s,
                      marks=() if (kind == KIND or s == 2)
                      else (pytest.mark.slow,))
         for kind in grids.ALL_KINDS for s in grids.SHARD_COUNTS])
    def test_chaos_cell_across_kinds(self, tmp_path, kind, shards):
        rng = np.random.RandomState((len(kind) * 131 + shards) % (2 ** 31))
        point = CRASH_POINTS[rng.randint(len(CRASH_POINTS))]
        after = int(rng.randint(0, 6)) + (point == "mid_snapshot")
        inj = FaultInjector().crash_at(point, after=after)
        _, queries = _fixture()
        svc = _durable(tmp_path, shards=shards, kind=kind, injector=inj,
                       snapshot_every=5)
        applied, inflight = _run_until_crash(
            svc, _schedule(seed=11, n_ops=10), queries)
        rec = _recovered(tmp_path, shards=shards, kind=kind)
        ref = _plain(shards=shards, kind=kind)
        for op in _committed(applied, inflight, point):
            _apply(ref, op)
        _assert_bit_identical(rec, ref, queries)

    def test_periodic_snapshots_rotate_and_prune(self, tmp_path):
        svc = _durable(tmp_path, snapshot_every=3, keep_snapshots=2)
        _, queries = _fixture()
        for op in _schedule(seed=23, n_ops=11):
            _apply(svc, op)
        snaps = [n for n in os.listdir(tmp_path) if n.startswith("snap_")
                 and not n.endswith(".tmp")]
        assert svc.stats.snapshots >= 3       # the build's + periodic ones
        assert len(snaps) <= 2                # pruned to keep_snapshots
        assert len(_wal_paths(tmp_path)) <= 2  # rotated + pruned with them
        _assert_bit_identical(_recovered(tmp_path), svc, queries)


# ---------------------------------------------------------------------------
# WAL edge cases
# ---------------------------------------------------------------------------


class TestWalEdgeCases:
    def _three_inserts(self, tmp_path):
        svc = _durable(tmp_path)
        rng = np.random.RandomState(13)
        batches = [rng.randn(k, *grids.DIMS).astype(np.float32)
                   for k in (5, 4, 3)]
        for b in batches:
            svc.insert(b)
        return svc, batches

    def test_torn_final_record_is_dropped(self, tmp_path):
        _, queries = _fixture()
        svc, batches = self._three_inserts(tmp_path)
        svc.close()
        path = _wal_paths(tmp_path)[-1]
        last_off, _ = _frames(path)[-1]
        with open(path, "r+b") as f:          # cut the tail mid-record
            f.truncate(last_off + 100)
        rec = _recovered(tmp_path)
        ref = _plain()
        ref.insert(batches[0]).insert(batches[1])   # the torn third is gone
        _assert_bit_identical(rec, ref, queries)
        # and the truncated tail was healed: new commits recover fine
        rec.insert(batches[2])
        _assert_bit_identical(_recovered(tmp_path), rec, queries)

    def test_checksum_corruption_mid_log_fails_loudly(self, tmp_path):
        svc, _ = self._three_inserts(tmp_path)
        path = _wal_paths(tmp_path)[-1]
        with open(path, "r+b") as f:          # flip a byte inside record 0
            f.seek(12)
            byte = f.read(1)
            f.seek(12)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(WalCorrupted, match="checksum"):
            read_wal(str(tmp_path))
        fresh = _durable(tmp_path, build=False)
        with pytest.raises(WalCorrupted, match="checksum"):
            fresh.recover()
        assert fresh.health == "degraded"     # it never half-serves
        with pytest.raises(ServiceUnavailable):
            fresh.query_arrays(_fixture()[1], topk=4)

    def test_lsn_gap_fails_loudly(self, tmp_path):
        svc, _ = self._three_inserts(tmp_path)
        svc.close()
        path = _wal_paths(tmp_path)[-1]
        with open(path, "rb") as f:
            data = f.read()
        offs = _frames(path)                  # reframe, dropping record 1
        rec = [data[o:o + 8 + n] for o, n in offs]
        pad = b"\0" * (offs[1][0] - len(rec[0]))
        with open(path, "wb") as f:
            f.write(rec[0] + pad + rec[2])
        with pytest.raises(WalCorrupted, match="discontinuity"):
            _durable(tmp_path, build=False).recover()

    def test_empty_log_recovers_to_snapshot(self, tmp_path):
        _, queries = _fixture()
        svc = _durable(tmp_path)              # build writes snapshot + empty
        rec = _recovered(tmp_path)            # WAL: zero records replayed
        _assert_bit_identical(rec, svc, queries)
        assert rec.stats.wal_appends == 0

    def test_snapshot_with_no_log_recovers(self, tmp_path):
        _, queries = _fixture()
        svc, _ = self._three_inserts(tmp_path)
        svc.snapshot()                        # covers every record so far
        for p in _wal_paths(tmp_path):
            os.remove(p)                      # lose the (rotated) log
        rec = _recovered(tmp_path)
        _assert_bit_identical(rec, svc, queries)

    def test_replay_across_epoch_marker(self, tmp_path):
        _, queries = _fixture()
        svc = _durable(tmp_path, shards=2)
        rng = np.random.RandomState(31)
        svc.insert(rng.randn(6, *grids.DIMS).astype(np.float32))
        svc.delete(np.array([2, 40]))
        svc.compact()                         # epoch marker mid-log
        svc.insert(rng.randn(4, *grids.DIMS).astype(np.float32))
        svc.rebalance()                       # second marker kind
        records, _ = read_wal(str(tmp_path))
        assert [k for _, k, _ in records] == [
            "insert", "delete", "compact", "insert", "rebalance"]
        rec = _recovered(tmp_path, shards=2)
        assert rec.stats.compactions == 1 and rec.stats.rebalances == 1
        assert not rec.index.store.mutated
        _assert_bit_identical(rec, svc, queries)

    def test_no_snapshot_fails_loudly(self, tmp_path):
        with pytest.raises(RecoveryError, match="no complete snapshot"):
            _durable(tmp_path, build=False).recover()

    def test_config_mismatch_refuses_replay(self, tmp_path):
        self._three_inserts(tmp_path)
        other = DurableLSHService(
            grids.grid_family(KIND, num_tables=2), str(tmp_path),
            metric="euclidean", bucket_cap=16)
        with pytest.raises(RecoveryError, match="num_tables"):
            other.recover()

    def test_interrupted_snapshot_leaves_last_complete_one(self, tmp_path):
        """A crash mid-snapshot leaves only an ignored .tmp dir; recovery
        restores the previous snapshot and replays the full log."""
        inj = FaultInjector().crash_at("mid_snapshot", after=1)
        _, queries = _fixture()
        svc = _durable(tmp_path, injector=inj)
        svc.insert(np.random.RandomState(7)
                   .randn(5, *grids.DIMS).astype(np.float32))
        with pytest.raises(InjectedCrash):
            svc.snapshot()
        assert any(n.endswith(".tmp") for n in os.listdir(tmp_path))
        rec = _recovered(tmp_path)
        _assert_bit_identical(rec, svc, queries)


# ---------------------------------------------------------------------------
# Degraded-mode serving (scheduler integration)
# ---------------------------------------------------------------------------


class TestDegradedServing:
    def _batch(self, k=5, seed=0):
        return np.random.RandomState(seed).randn(
            k, *grids.DIMS).astype(np.float32)

    def test_transient_wal_failure_retries_and_succeeds(self, tmp_path):
        inj = FaultInjector().fail_transient("pre_wal_append", times=2)
        svc = _durable(tmp_path, injector=inj)
        with ServingScheduler(svc, retry_backoff_ms=1.0) as sched:
            sched.insert(self._batch()).result(timeout=60)
            assert sched.stats.retries == 2
            assert svc.stats.retries == 2
            assert sched.stats.errors == 0
            assert svc.health == "serving"
        # nothing was committed by the failed attempts: exactly one record
        records, _ = read_wal(str(tmp_path))
        assert [k for _, k, _ in records] == ["insert"]

    def test_exhausted_retries_degrade_then_recover(self, tmp_path):
        _, queries = _fixture()
        inj = FaultInjector()
        svc = _durable(tmp_path, injector=inj)
        with ServingScheduler(svc, ingest_retries=2,
                              retry_backoff_ms=1.0) as sched:
            sched.insert(self._batch(seed=1)).result(timeout=60)
            inj.fail_transient("pre_wal_append", times=3)  # 1 try + 2 retries
            with pytest.raises(TransientIOError):
                sched.insert(self._batch(seed=2)).result(timeout=60)
            assert svc.health == "degraded"
            assert sched.stats.errors == 1
            assert "TransientIOError" in sched.tenant_stats().last_error
            # degraded namespaces shed every request, typed
            with pytest.raises(ServiceUnavailable):
                sched.query(np.asarray(queries[0]))
            with pytest.raises(ServiceUnavailable):
                sched.insert(self._batch(seed=3))
            assert sched.stats.shed == 2
            assert svc.stats.unavailable >= 2
            sched.recover_namespace().result(timeout=120)
            assert svc.health == "serving"
            assert svc.stats.recoveries == 1
            sched.query(np.asarray(queries[0]), topk=4).result(timeout=60)
        # the shed insert never committed: replaying yields insert #1 only
        ref = _plain()
        ref.insert(self._batch(seed=1))
        _assert_bit_identical(svc, ref, queries)

    def test_injected_crash_degrades_namespace_end_to_end(self, tmp_path):
        """The full story: a crash mid-commit through the scheduler
        degrades the tenant, queries shed, recovery replays the committed
        prefix bit-identically and serving resumes."""
        _, queries = _fixture()
        inj = FaultInjector().crash_at("post_wal_append", after=1)
        svc = _durable(tmp_path, injector=inj)
        with ServingScheduler(svc) as sched:
            sched.insert(self._batch(seed=4)).result(timeout=60)
            with pytest.raises(InjectedCrash):
                sched.delete(np.array([1, 8])).result(timeout=60)
            assert svc.health == "degraded"
            assert sched.stats.errors == 1
            with pytest.raises(ServiceUnavailable):
                sched.query(np.asarray(queries[0]))
            sched.recover_namespace().result(timeout=120)
            got = sched.query(np.asarray(queries[0]),
                              topk=TOPK).result(timeout=60)
        ref = _plain()
        ref.insert(self._batch(seed=4))
        ref.delete(np.array([1, 8]))          # post-append: it committed
        _assert_bit_identical(svc, ref, queries)
        np.testing.assert_array_equal(
            got[0], ref.query_arrays(queries[:1], topk=TOPK)[0][0])

    def test_poisoned_insert_increments_error_counters(self, tmp_path):
        svc = _durable(tmp_path)
        poison = np.zeros((2, 3), np.float32)     # wrong dims for the family
        with ServingScheduler(svc) as sched:
            with pytest.raises(Exception) as exc_info:
                sched.insert(poison).result(timeout=60)
            assert not isinstance(exc_info.value,
                                  (TransientIOError, InjectedCrash))
            assert sched.stats.errors == 1
            assert sched.tenant_stats().errors == 1
            assert sched.tenant_stats().last_error != ""
            assert svc.health == "serving"    # poison isn't an IO outage
            sched.insert(self._batch()).result(timeout=60)  # lane lives on

    def test_flush_timeout_raises(self, tmp_path):
        svc = _plain()
        with ServingScheduler(svc) as sched:
            orig = svc.insert
            svc.insert = lambda b: (time.sleep(0.6), orig(b))[1]
            fut = sched.insert(self._batch())
            with pytest.raises(TimeoutError, match="flush timed out"):
                sched.flush(timeout=0.05)
            fut.result(timeout=60)            # the lane still drains
            sched.flush(timeout=60)           # and a patient flush returns

    def test_request_timeout_expires_queued_queries(self, tmp_path):
        _, queries = _fixture()
        svc = _plain()
        with ServingScheduler(svc, request_timeout_ms=0.0,
                              deadline_ms=1.0) as sched:
            fut = sched.query(np.asarray(queries[0]))
            with pytest.raises(RequestTimeout):
                fut.result(timeout=60)
            assert isinstance(fut.exception(timeout=60), TimeoutError)
            assert sched.stats.timeouts == 1
            assert svc.stats.timeouts == 1


# ---------------------------------------------------------------------------
# Direct durable-service gating
# ---------------------------------------------------------------------------


class TestHealthGating:
    def test_cold_and_degraded_services_refuse_requests(self, tmp_path):
        svc = _durable(tmp_path, build=False)
        assert svc.health == "cold"
        with pytest.raises(ServiceUnavailable):
            svc.insert(np.zeros((1,) + grids.DIMS, np.float32))
        with pytest.raises(ServiceUnavailable):
            svc.query_arrays(np.zeros((1,) + grids.DIMS, np.float32))
        assert svc.stats.unavailable == 2

    def test_injector_rejects_unknown_points(self):
        with pytest.raises(ValueError, match="unknown crash point"):
            FaultInjector().crash_at("pre_frobnicate")
