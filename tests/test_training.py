"""Training substrate tests: optimizer, convergence, checkpointing,
fault-tolerant restart (bit-identical), straggler watchdog, gradient
compression, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models import params as params_lib
from repro.serving.engine import greedy_generate
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training.compression import CompressionConfig
from repro.training.fault_tolerance import (FailureInjector, InjectedFailure,
                                            StepWatchdog, run_training)
from repro.training.train_loop import TrainConfig, init_state, make_train_step

CFG = get_config("stablelm-3b", "smoke")


def _setup(tc=None, seed=0):
    tc = tc or TrainConfig(adamw=opt_lib.AdamWConfig(
        peak_lr=1e-3, warmup_steps=5, decay_steps=100))
    state, sketch = init_state(CFG, tc, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(CFG, tc, sketch=sketch))
    dc = DataConfig(batch_size=4, seq_len=64, seed=seed)
    return state, step, dc


class TestOptimizer:
    def test_schedule_shape(self):
        c = opt_lib.AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(opt_lib.schedule(c, jnp.asarray(s))) for s in
               [0, 5, 10, 55, 100, 200]]
        assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[4] == pytest.approx(0.1, abs=1e-6)
        assert lrs[5] == pytest.approx(0.1, abs=1e-6)

    def test_clipping(self):
        c = opt_lib.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.full((4,), 100.0)}
        st = opt_lib.init(params)
        _, _, m = opt_lib.update(c, grads, st, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_loss_decreases(self):
        state, step, dc = _setup()
        losses = []
        for i in range(25):
            state, metrics = step(state, batch_at(dc, CFG, i))
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.8, losses[::6]


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state, step, dc = _setup()
        state, _ = step(state, batch_at(dc, CFG, 0))
        ckpt_lib.save(str(tmp_path), 7, state, meta={"arch": CFG.name})
        restored, meta = ckpt_lib.restore(str(tmp_path), 7, state)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     state, restored)
        assert meta["arch"] == CFG.name
        assert ckpt_lib.latest_step(str(tmp_path)) == 7

    def test_async_save(self, tmp_path):
        state, _, _ = _setup()
        t = ckpt_lib.save(str(tmp_path), 3, state, async_=True)
        t.join()
        restored, _ = ckpt_lib.restore(str(tmp_path), 3, state)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     state, restored)

    def test_corruption_detected(self, tmp_path):
        state, _, _ = _setup()
        ckpt_lib.save(str(tmp_path), 1, state)
        leaf = os.path.join(str(tmp_path), "step_00000001", "leaf_00000.npy")
        arr = np.load(leaf)
        arr.reshape(-1)[0] += 1.0
        np.save(leaf, arr)
        with pytest.raises(IOError, match="corruption"):
            ckpt_lib.restore(str(tmp_path), 1, state)

    def test_partial_save_is_invisible(self, tmp_path):
        """A .tmp dir (crash mid-save) must not count as a checkpoint."""
        state, _, _ = _setup()
        ckpt_lib.save(str(tmp_path), 5, state)
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        assert ckpt_lib.latest_step(str(tmp_path)) == 5


class TestFaultTolerance:
    def test_restart_is_bit_identical(self, tmp_path):
        """Crash at step 12, restart, final state == uninterrupted run."""
        def run(ckpt_dir, injector):
            state0, step, dc = _setup(seed=3)
            return run_training(
                train_step=step, init_state_fn=lambda: state0,
                batch_fn=lambda s: batch_at(dc, CFG, s),
                num_steps=20, ckpt_dir=ckpt_dir, ckpt_every=5,
                injector=injector, log_every=0, log_fn=lambda m: None)

        d1 = str(tmp_path / "a")
        with pytest.raises(InjectedFailure):
            run(d1, FailureInjector(fail_at_step=12))
        # restart resumes from step 10 checkpoint
        state_a, _ = run(d1, FailureInjector())
        d2 = str(tmp_path / "b")
        state_b, _ = run(d2, FailureInjector())
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     state_a, state_b)

    def test_watchdog_flags_stragglers(self):
        wd = StepWatchdog(threshold_x=2.0)
        for i in range(10):
            wd.observe(i, 0.1)
        wd.observe(10, 0.5)
        assert wd.straggler_steps == [10]

    def test_data_skip_ahead_determinism(self):
        dc = DataConfig(batch_size=2, seq_len=16, seed=9)
        b1 = batch_at(dc, CFG, 1234)
        b2 = batch_at(dc, CFG, 1234)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = batch_at(dc, CFG, 1235)
        assert not np.array_equal(b1["tokens"], b3["tokens"])


class TestCompression:
    def test_sketch_roundtrip_reduces_comm_and_trains(self):
        tc = TrainConfig(
            adamw=opt_lib.AdamWConfig(peak_lr=1e-3, warmup_steps=5,
                                      decay_steps=100),
            compression=CompressionConfig(num_projections=256, rank=2,
                                          min_size=4096))
        state, step, dc = _setup(tc=tc)
        losses = []
        for i in range(30):
            state, metrics = step(state, batch_at(dc, CFG, i))
            losses.append(float(metrics["loss"]))
        assert float(metrics["comm_ratio"]) < 0.05  # >20x comm reduction
        # EF-sketched grads transmit ~K/D of the energy per step: expect a
        # clear but slower descent than raw grads over 30 steps
        assert losses[-1] < losses[0] - 0.25, losses[::6]

    def test_error_feedback_accumulates(self):
        from repro.training import compression as C
        cfg = C.CompressionConfig(num_projections=8, rank=2, min_size=1)
        params = {"w": jnp.zeros((64, 64))}
        sk, st = C.init_compressor(cfg, params)
        g = {"w": jnp.ones((64, 64))}
        ghat, st2, _ = C.roundtrip(cfg, sk, st, g)
        # EF: g - ghat stored as error
        np.testing.assert_allclose(np.asarray(st2.error["w"]),
                                   np.asarray(g["w"] - ghat["w"]), atol=1e-5)


class TestServing:
    def test_greedy_generate_shapes(self):
        cfg = get_config("stablelm-3b", "smoke")
        params = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                    cfg.vocab_size)
        out = greedy_generate(cfg, params, {"tokens": tokens}, steps=5,
                              max_len=32)
        assert out.shape == (2, 5)
        assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

    def test_generation_follows_learned_bigram(self):
        """After training on the affine-bigram stream, greedy generation
        should follow the rule far above chance (1/V)."""
        from repro.data.synthetic import bigram_next
        tc = TrainConfig(adamw=opt_lib.AdamWConfig(
            peak_lr=2e-3, warmup_steps=5, decay_steps=200))
        state, step, dc = _setup(tc=tc)
        for i in range(60):
            state, _ = step(state, batch_at(dc, CFG, i))
        batch = batch_at(dc, CFG, 999)
        prompt = batch["tokens"][:, :48]
        out = greedy_generate(CFG, state.params, {"tokens": prompt},
                              steps=8, max_len=64)
        prev = jnp.concatenate([prompt[:, -1:], out[:, :-1]], axis=1)
        want = bigram_next(dc, CFG, prev)
        acc = float((out == want).mean())
        assert acc > 0.5, acc  # chance is 1/256
