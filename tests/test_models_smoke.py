"""Per-arch smoke tests (reduced configs): one forward/train step on CPU
asserting output shapes + no NaNs, plus decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import params as params_lib
from repro.models import transformer as T


def make_batch(cfg, batch_size, seq, key):
    kt, kv, kf = jax.random.split(key, 3)
    tokens = jax.random.randint(kt, (batch_size, seq), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            kv, (batch_size, cfg.vision_tokens, cfg.d_model), jnp.float32)
        batch["labels"] = batch["labels"].at[:, :cfg.vision_tokens].set(-1)
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(
            kf, (batch_size, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


# archs whose decode path is exact w.r.t. the full forward (LSH attention is
# an approximation by construction, so phi3's variant checks finiteness only)
EXACT_DECODE = {a: a != "phi3-mini-3.8b" for a in ARCH_IDS}
# MoE decode tolerance is structural: single-token dispatch never drops,
# batched prefill may -> a token's expert set can differ near capacity.
TOL = {a: (0.12 if "moe" in a or "mixtral" in a or "llama4" in a else 0.05)
       for a in ARCH_IDS}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch, "smoke")
        key = jax.random.PRNGKey(0)
        params = params_lib.init_params(cfg, key)
        b, s = 2, 32
        batch = make_batch(cfg, b, s, key)

        logits, _, _ = T.forward(cfg, params, batch)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), "non-finite logits"

        loss, metrics = jax.jit(
            lambda p, bt: T.loss_fn(cfg, p, bt))(params, batch)
        assert np.isfinite(float(loss))
        assert 1.0 < float(metrics["ce"]) < 20.0  # ~ln(V) at init

        grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat)
        gnorm = sum(float(jnp.abs(g).sum()) for g in flat)
        assert gnorm > 0.0, "no gradient signal"

    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch, "smoke")
        key = jax.random.PRNGKey(1)
        params = params_lib.init_params(cfg, key)
        b, s, n_decode = 2, 32, 3
        batch = make_batch(cfg, b, s, key)
        logits, _, _ = T.forward(cfg, params, batch)

        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :s - n_decode]
        last, cache = T.prefill(cfg, params, pre, max_len=s)
        errs = []
        if EXACT_DECODE[arch]:
            errs.append(np.abs(np.asarray(last)
                               - np.asarray(logits[:, s - n_decode - 1])).max())
        cur = s - n_decode
        for _ in range(n_decode):
            step_logits, cache = T.decode_step(
                cfg, params, batch["tokens"][:, cur:cur + 1], cache,
                jnp.asarray(cur, jnp.int32))
            assert step_logits.shape == (b, cfg.vocab_size)
            assert bool(jnp.isfinite(step_logits).all())
            if EXACT_DECODE[arch]:
                errs.append(np.abs(np.asarray(step_logits)
                                   - np.asarray(logits[:, cur])).max())
            cur += 1
        if errs:
            scale = float(np.abs(np.asarray(logits)).max())
            assert max(errs) < TOL[arch] * max(scale, 1.0), (arch, errs)

    def test_param_count_full_config(self, arch):
        """Full config instantiates abstractly and matches the family scale."""
        cfg = get_config(arch, "full")
        n = params_lib.count_params(cfg)
        expected = {
            "stablelm-3b": (2.5e9, 4.5e9),
            "gemma-7b": (7e9, 10e9),
            "phi3-mini-3.8b": (3.2e9, 4.5e9),
            "mistral-large-123b": (110e9, 130e9),
            "zamba2-7b": (6e9, 9e9),
            "pixtral-12b": (10e9, 14e9),
            "whisper-tiny": (2.5e7, 7e7),
            "mixtral-8x22b": (125e9, 150e9),
            "llama4-maverick-400b-a17b": (330e9, 430e9),
            "mamba2-130m": (1.0e8, 1.8e8),
        }[arch]
        assert expected[0] < n < expected[1], f"{arch}: {n:.3e}"
        # abstract init must not allocate
        sds = params_lib.abstract_params(cfg)
        assert all(isinstance(x, jax.ShapeDtypeStruct)
                   for x in jax.tree.leaves(sds))
