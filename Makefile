# Tier-1 verification and benchmarks. Kernels run with interpret=True on
# CPU (the Pallas TPU lowering is exercised on real hardware only).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-index bench-index-sharded \
	bench-index-mut bench-multiprobe bench-ingest bench-slo \
	bench-recovery bench-hash bench-kernels bench-fused-probe

test:
	$(PYTHON) -m pytest -x -q

# The CI default leg: skips the slow-marked redundant grid cells
# (tests/grids.py) — full coverage stays on `make test` / the full CI leg.
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

bench:
	$(PYTHON) -m benchmarks.run

bench-index:
	$(PYTHON) -m benchmarks.index_qps

bench-index-sharded:
	$(PYTHON) -m benchmarks.index_sharded

bench-index-mut:
	$(PYTHON) -m benchmarks.index_mutation

bench-multiprobe:
	$(PYTHON) -m benchmarks.index_multiprobe

bench-ingest:
	$(PYTHON) -m benchmarks.index_ingest

bench-slo:
	$(PYTHON) -m benchmarks.serving_slo

bench-recovery:
	$(PYTHON) -m benchmarks.durability

bench-hash:
	$(PYTHON) -m benchmarks.hash_throughput

bench-kernels:
	$(PYTHON) -m benchmarks.kernels

bench-fused-probe:
	$(PYTHON) -m benchmarks.fused_probe
