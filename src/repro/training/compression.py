"""Gradient compression via the paper's tensorized random projection.

Each gradient matrix G in R^{d1 x d2} is sketched with K fresh
CP-Rademacher projection tensors (Definition 6/8): s_k = <P_k, G>. On a
real pod the DP all-reduce moves the K-vector instead of d1*d2 numbers
(all workers derive the same P_k from the shared (seed, step), so only s
crosses the wire), and the projection factors occupy O(K (d1+d2) R) — the
paper's space win — versus O(K d1 d2) for a dense sketch.

Decompression is *sketch-and-project*: G^ = argmin ||G^||_F s.t.
<P_k, G^> = s_k, i.e. G^ = sum_k alpha_k P_k with (Gram M) alpha = s and
M[k,l] = <P_k, P_l> computed by the paper's CP x CP contraction. Because
G - G^ is an ORTHOGONAL projection of G, the error-feedback recursion
e <- (I - Proj_step)(g + e) is non-expansive, and with projections
re-sampled every step it contracts at rate ~(1 - K/(d1 d2)) in expectation
— unlike the naive unbiased estimate (1/K) sum s_k P_k, whose EF loop
diverges (documented negative result, see EXPERIMENTS.md §Perf notes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    error: Any  # error-feedback accumulator, f32, like params


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    num_projections: int = 64   # K
    rank: int = 2               # R
    min_size: int = 65536       # leaves smaller than this are sent raw
    seed: int = 1234
    ridge: float = 1e-5


def _matricize_shape(shape) -> tuple[int, int] | None:
    if len(shape) < 2:
        return None
    d1 = shape[0]
    d2 = math.prod(shape[1:])
    return d1, d2


def init_compressor(cfg: CompressionConfig, params, key=None):
    """Returns (sketch_params, state). sketch_params is the static seed —
    factors are re-derived per (step, leaf), never stored."""
    del key
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return jnp.asarray(cfg.seed, jnp.uint32), CompressorState(error=err)


def _rademacher(key, shape):
    return (2.0 * jax.random.bernoulli(key, 0.5, shape).astype(jnp.float32)
            ) - 1.0


def _factors(cfg: CompressionConfig, seed, step, leaf_idx, d1, d2):
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), leaf_idx)
    k1, k2 = jax.random.split(key)
    fa = _rademacher(k1, (cfg.num_projections, d1, cfg.rank))
    fb = _rademacher(k2, (cfg.num_projections, d2, cfg.rank))
    return fa, fb


def _sketch(g2, fa, fb, rank):
    # s_k = (1/sqrt(R)) sum_r a_{k,:,r}^T G b_{k,:,r}   (paper Eq. 3.11)
    t = jnp.einsum("ij,kjr->kir", g2, fb)
    return jnp.einsum("kir,kir->k", t, fa) / math.sqrt(rank)


def _projection_gram(fa, fb, rank):
    """M[k,l] = <P_k, P_l> via the paper's CP x CP contraction (Hadamard
    of per-mode Grams, batched over the (k,l) pair grid)."""
    ga = jnp.einsum("kir,lis->klrs", fa, fa)
    gb = jnp.einsum("kjr,ljs->klrs", fb, fb)
    return jnp.einsum("klrs,klrs->kl", ga, gb) / rank


def _project(s, fa, fb, rank, ridge):
    """Least-norm G^ with <P_k, G^> = s_k (sketch-and-project)."""
    m = _projection_gram(fa, fb, rank)
    k = m.shape[0]
    alpha = jnp.linalg.solve(m + ridge * jnp.trace(m) / k * jnp.eye(k), s)
    return jnp.einsum("k,kir,kjr->ij", alpha, fa, fb) / math.sqrt(rank)


def roundtrip(cfg: CompressionConfig, sketch_seed, state: CompressorState,
              grads, step=None):
    """compress -> (where the DP all-reduce of `s` would run) -> project
    back + error feedback. Returns (approx_grads, new_state, metrics)."""
    if step is None:
        step = jnp.zeros((), jnp.uint32)
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(state.error)
    out_g, out_e, ratios = [], [], []
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        ms = _matricize_shape(g.shape)
        if ms is None or g.size < cfg.min_size:
            out_g.append(g)
            out_e.append(jnp.zeros_like(e))
            continue
        d1, d2 = ms
        fa, fb = _factors(cfg, sketch_seed, step, i, d1, d2)
        gf = g.astype(jnp.float32) + e
        g2 = gf.reshape(d1, d2)
        s = _sketch(g2, fa, fb, cfg.rank)           # <- the only comm
        ghat = _project(s, fa, fb, cfg.rank, cfg.ridge).reshape(g.shape)
        out_g.append(ghat.astype(g.dtype))
        out_e.append(gf - ghat)
        ratios.append(s.size / g.size)
    new_err = jax.tree.unflatten(treedef, out_e)
    mean_ratio = (sum(ratios) / len(ratios)) if ratios else 1.0
    return (jax.tree.unflatten(treedef, out_g),
            CompressorState(error=new_err),
            {"comm_ratio": jnp.asarray(mean_ratio, jnp.float32)})
