"""Fault-tolerance machinery: the resumable training driver, failure
injection, and a straggler watchdog.

The contract this provides for 1000+-node runs:
  * checkpoint/restart — `run_training` checkpoints every `ckpt_every`
    steps (async, atomic) and auto-resumes from the latest complete
    checkpoint; data is a pure function of step (skip-ahead), so the
    restarted trajectory is bit-identical (tested in test_fault_tolerance).
  * node failure — on a pod, a dead host makes the collective time out; the
    controller restarts the job and this driver resumes. `FailureInjector`
    simulates the crash in-process for tests.
  * stragglers — `StepWatchdog` tracks a robust moving estimate of step
    time; steps slower than `threshold_x` the median are logged and counted.
    On a real pod the hook triggers redispatch of that host's data shard
    (pure-function-of-step data makes recomputation free); here the hook is
    a callback.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.training import checkpoint as ckpt_lib


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises at a given step, once — simulates a mid-run node failure."""
    fail_at_step: int | None = None
    fired: bool = False

    def maybe_fail(self, step: int):
        if (self.fail_at_step is not None and not self.fired
                and step == self.fail_at_step):
            self.fired = True
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StepWatchdog:
    threshold_x: float = 3.0
    on_straggler: Callable[[int, float, float], None] | None = None
    times: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float):
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold_x * med:
                self.straggler_steps.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        if len(self.times) > 100:
            self.times.pop(0)


def run_training(*, train_step, init_state_fn, batch_fn, num_steps: int,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 injector: FailureInjector | None = None,
                 watchdog: StepWatchdog | None = None,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> tuple[Any, list]:
    """Resumable loop. Returns (final_state, metrics_history)."""
    state = init_state_fn()
    start = 0
    if ckpt_dir:
        restored, step, _ = ckpt_lib.restore_latest(ckpt_dir, state)
        if restored is not None:
            state, start = restored, step
            log_fn(f"[ft] resumed from checkpoint step {step}")

    history = []
    pending = None
    for step in range(start, num_steps):
        if injector is not None:
            injector.maybe_fail(step)
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if watchdog is not None:
            watchdog.observe(step, dt)
        history.append({k: float(v) for k, v in metrics.items()})
        if log_every and step % log_every == 0:
            log_fn(f"[train] step={step} loss={history[-1]['loss']:.4f} "
                   f"({dt*1e3:.0f} ms)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt_lib.save(ckpt_dir, step + 1, state, async_=True)
    if pending is not None:
        pending.join()
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, num_steps, state)
    return state, history
