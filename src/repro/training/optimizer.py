"""AdamW with global-norm clipping and warmup-cosine schedule (from scratch;
no optax in this environment). Optimizer state mirrors param sharding, so
FSDP-sharded params get ZeRO-sharded moments for free."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any          # first moment, f32, like params
    nu: Any          # second moment, f32, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # bf16 halves optimizer HBM at 400B scale


def schedule(c: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    progress = jnp.clip((step - c.warmup_steps)
                        / jnp.maximum(c.decay_steps - c.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(math.pi * progress))
    decay = c.min_lr_ratio + (1 - c.min_lr_ratio) * cos
    return c.peak_lr * jnp.where(step < c.warmup_steps, warm, decay)


def init(params, moment_dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(moment_dtype))
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(c: AdamWConfig, grads, state: OptState, params):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(c.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = (c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g)
        v = (c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g * g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return (new_params, OptState(step=step, mu=new_mu, nu=new_nu),
            {"grad_norm": gnorm, "lr": lr})
