"""Train step factory: value_and_grad + microbatch accumulation + optional
tensorized-sketch gradient compression + AdamW, all donate-able and
pjit-friendly (shardings are applied by the launcher via sharding rules)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    compressor: comp_lib.CompressorState | None = None


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt_lib.AdamWConfig = dataclasses.field(default_factory=opt_lib.AdamWConfig)
    grad_accum: int = 1
    compression: comp_lib.CompressionConfig | None = None


def init_state(cfg: ModelConfig, tc: TrainConfig, key) -> tuple[TrainState, Any]:
    from repro.models import params as params_lib
    kp, kc = jax.random.split(key)
    params = params_lib.init_params(cfg, kp)
    opt = opt_lib.init(params, tc.adamw.moment_dtype)
    sketch, cstate = (None, None)
    if tc.compression is not None:
        sketch, cstate = comp_lib.init_compressor(tc.compression, params)
    return TrainState(params=params, opt=opt, compressor=cstate), sketch


def abstract_state(cfg: ModelConfig, tc: TrainConfig) -> TrainState:
    """ShapeDtypeStruct state for AOT lowering (dry-run)."""
    from repro.models import params as params_lib
    p = params_lib.abstract_params(cfg)
    mdt = jnp.dtype(tc.adamw.moment_dtype)
    mom = lambda s: jax.ShapeDtypeStruct(s.shape, mdt)
    return TrainState(
        params=p,
        opt=opt_lib.OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                             mu=jax.tree.map(mom, p), nu=jax.tree.map(mom, p)),
        compressor=None)


def state_axes(cfg: ModelConfig) -> TrainState:
    """Logical-axis tree matching abstract_state (opt moments like params)."""
    from repro.models import params as params_lib
    axes = params_lib.param_axes(cfg)
    return TrainState(
        params=axes,
        opt=opt_lib.OptState(step=(), mu=axes, nu=axes),
        compressor=None)


def dryrun_train_config(cfg: ModelConfig) -> TrainConfig:
    """Production train hyper-structure per arch scale: >=50B params train
    with 4-way gradient accumulation (65k tokens/chip/pass blows HBM on an
    88-layer residual stack otherwise); >=300B also uses bf16 Adam moments
    (f32 moments alone are 12.5 GiB/chip for llama4 on 256 chips)."""
    from repro.models import params as params_lib
    n = params_lib.count_params(cfg)
    accum = 8 if n > 100e9 else (4 if n > 50e9 else 1)
    mdt = "bfloat16" if n > 300e9 else "float32"
    return TrainConfig(adamw=opt_lib.AdamWConfig(moment_dtype=mdt),
                       grad_accum=accum)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, sketch=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if tc.grad_accum > 1:
            # split the batch into microbatches along the batch axis
            def micro(c, mb):
                loss_sum, g_sum = c
                loss, _, g = grads_of(state.params, mb)
                return (loss_sum + loss,
                        jax.tree.map(jnp.add, g_sum, g)), None

            mbs = jax.tree.map(
                lambda a: a.reshape((tc.grad_accum,
                                     a.shape[0] // tc.grad_accum) + a.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zero), mbs,
                unroll=True if cfg.scan_unroll else 1)
            loss = loss / tc.grad_accum
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            metrics = {"ce": loss}
        else:
            loss, metrics, grads = grads_of(state.params, batch)

        cstate = state.compressor
        if tc.compression is not None:
            grads, cstate, cm = comp_lib.roundtrip(
                tc.compression, sketch, cstate, grads,
                step=state.opt.step.astype(jnp.uint32))
            metrics = {**metrics, **cm}

        params, opt, om = opt_lib.update(tc.adamw, grads, state.opt,
                                         state.params)
        metrics = {**metrics, **om, "loss": loss}
        return TrainState(params=params, opt=opt, compressor=cstate), metrics

    return train_step
