"""Sharded, atomic, async checkpointing with integrity checks.

Layout (one directory per step):
    <dir>/step_000100.tmp/...   -> atomically renamed to step_000100/
        manifest.json   {step, leaf paths, shapes, dtypes, crc32s, meta}
        <leaf_i>.npy    one file per pytree leaf

* atomic: writes go to a .tmp dir, fsync'd, then os.rename — a crash mid-
  save never corrupts the latest complete checkpoint (restart test relies
  on this).
* async: save() can run on a background thread; the caller keeps training
  (the arrays are device-fetched before the thread starts).
* integrity: crc32 per leaf, verified on restore; mismatches raise.
* multi-host note: on a real pod each host writes its addressable shards
  under host_<k>/ and the manifest records the global mesh + PartitionSpecs
  (the elastic reshard path in launch/elastic.py consumes those).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(re.sub(r"[^\w.]", "", str(p)) for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree, meta: dict | None = None,
         async_: bool = False) -> threading.Thread | None:
    os.makedirs(directory, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}

    def write():
        name = f"step_{step:08d}"
        tmp = os.path.join(directory, name + ".tmp")
        final = os.path.join(directory, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "meta": meta or {}}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, step: int, like) -> tuple[Any, dict]:
    """Restore into the structure of `like` (arrays or SDS). Verifies CRCs."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    restored = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(path, info["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != info["crc32"]:
            raise IOError(f"checkpoint corruption in leaf {key!r}")
        restored[key] = arr
    missing = set(flat_like) - set(restored)
    if missing:
        raise IOError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys_in_order = list(_flatten(like).keys())
    out_leaves = [jax.numpy.asarray(restored[k]) for k in keys_in_order]
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest["meta"]


def restore_latest(directory: str, like):
    step = latest_step(directory)
    if step is None:
        return None, None, None
    tree, meta = restore(directory, step, like)
    return tree, step, meta
