"""Serving engine: prefill / decode step factories + a batched generation
loop. These are the functions the dry-run lowers for the inference cells
and the functions examples/serve-style drivers call on real hardware."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """prefill_step(params, batch) -> (last_logits (B, V), DecodeCache)."""
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch, max_len=max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, token, cur_pos) -> (logits, cache).
    One new token against a KV cache of the cell's seq_len; the cache is
    donated by the launcher so decode is in-place on device."""
    def serve_step(params, cache, token, cur_pos):
        return T.decode_step(cfg, params, token, cache, cur_pos)
    return serve_step


def greedy_generate(cfg: ModelConfig, params, batch, *, steps: int,
                    max_len: int, temperature: float = 0.0, key=None):
    """Host-driven generation loop (examples + tests)."""
    serve = jax.jit(make_serve_step(cfg))

    def mask_pad(logits):  # padded vocab ids are never sampled
        if cfg.padded_vocab == cfg.vocab_size:
            return logits
        return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                         logits, -jnp.inf)

    last, cache = jax.jit(
        make_prefill_step(cfg, max_len))(params, batch)
    last = mask_pad(last)
    cur = batch["tokens"].shape[1]
    tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        logits, cache = serve(params, cache, tok, jnp.asarray(cur, jnp.int32))
        logits = mask_pad(logits)
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        cur += 1
    return jnp.concatenate(out, axis=1)
