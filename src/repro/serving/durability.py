"""Durability for the mutable index: write-ahead mutation log, atomic
snapshots, crash-point fault injection, and snapshot+replay recovery.

The paper's families make LSH parameters *small*, so a served index's
durable identity is tiny: the family config + the mutation history. This
module persists exactly that. ``DurableLSHService`` wraps every mutation
of ``LSHService`` in a write-ahead commit:

* **WAL** (``MutationLog``): an append-only log of mutation records —
  insert batches (stored as the raw items; replay re-hashes them through
  the fused ``hash_keys`` path), delete id-sets, and compact/rebalance
  epoch markers. Records are framed ``[u32 length][u32 crc32-of-head]
  [head][raw blobs]`` (each blob carries a 64-bit xor-fold in the head)
  at 4 KiB-aligned offsets in preallocated, prezeroed segments, written
  ``O_DIRECT`` + ``fdatasync`` where the filesystem allows (buffered +
  ``fdatasync`` otherwise) on a committer thread that overlaps the
  device-side apply — near-zero commit CPU, which is what holds the
  bench-ingest gate (WAL-on insert throughput within 10% of WAL-off)
  even on one core. A mutation returns only after *both* the sync and
  the apply complete, so an operation is committed iff its append
  completed, and a failed apply cancels its record. A torn tail (a final
  record damaged by a crash mid-append) is tolerated on replay; the same
  damage with intact records after it raises ``WalCorrupted`` — never a
  silent partial store.
* **Snapshots**: periodic atomic dumps of the ``SegmentStore`` (segment
  arrays + ``host_state()``), written with the ``training/checkpoint.py``
  idiom — temp dir, per-array crc32 manifest, fsync, ``os.rename`` — so a
  crash mid-snapshot never corrupts the last complete one. Each snapshot
  rotates the WAL; older segments and snapshots are pruned.
* **Recovery** (``recover()``): restore the latest complete snapshot,
  replay the WAL suffix. Because the whole mutation plane is
  deterministic (fused hashing, water-fill routing, sequence-order
  effective ids, stable sorts), the recovered store answers queries
  **bit-identically** to the uninterrupted process. ``max_deltas``
  auto-compactions are deliberately *not* logged — replayed inserts
  re-trigger them at exactly the same points.
* **Fault injection** (``FaultInjector``): named crash points at every
  durability boundary — ``pre_wal_append`` / ``post_wal_append`` (either
  side of the commit), ``mid_snapshot`` (between the array dump and the
  rename), ``pre_apply_swap`` (between the epoch-marker commit and the
  pointer flip) — drive the chaos-matrix tests, plus armable transient IO
  failures (``TransientIOError``) that the serving scheduler's ingest
  lane retries with bounded backoff.

Health states: ``"cold"`` (constructed), ``"serving"``, ``"recovering"``
(inside ``recover()``), ``"degraded"`` (a recovery failed, or the
scheduler marked the namespace down after exhausting retries). Any
request against a non-serving durable service raises the typed
``ServiceUnavailable`` instead of hanging or answering from a
possibly-inconsistent store.
"""

from __future__ import annotations

import base64
import dataclasses
import itertools
import json
import mmap
import os
import pickle
import re
import shutil
import struct
import time
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

from repro.core.index import ShardedLSHIndex
from repro.core.segments import SegmentStore, ShardedSegment, TableSegment
from repro.serving.lsh_service import LSHService


# ---------------------------------------------------------------------------
# Typed errors
# ---------------------------------------------------------------------------


class DurabilityError(RuntimeError):
    """Base of the durability error family."""


class WalCorrupted(DurabilityError):
    """The WAL is damaged before its tail (bad checksum, truncated frame
    in a non-final segment, lsn discontinuity) — replay refuses to build
    a silently partial store."""


class RecoveryError(DurabilityError):
    """Recovery cannot produce a consistent store (no complete snapshot,
    config mismatch, snapshot corruption, missing log suffix)."""


class TransientIOError(OSError):
    """A retryable IO failure on the durability plane — the scheduler's
    ingest lane retries these with bounded exponential backoff."""


class ServiceUnavailable(RuntimeError):
    """The namespace is degraded/recovering; the request was shed instead
    of served from a possibly-inconsistent store."""


class InjectedCrash(RuntimeError):
    """A ``FaultInjector`` crash point fired — stands in for process
    death in the chaos tests (state past the fired boundary is lost)."""


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------


CRASH_POINTS = ("pre_wal_append", "post_wal_append", "mid_snapshot",
                "pre_apply_swap")


class FaultInjector:
    """Armable faults at the named durability boundaries.

    ``crash_at(point, after=k)`` raises ``InjectedCrash`` the (k+1)-th
    time ``point`` fires (then disarms); ``fail_transient(point, times)``
    raises ``TransientIOError`` the next ``times`` firings (the retry
    path's test hook). ``fired`` records every firing in order.
    """

    def __init__(self):
        self._crash: dict[str, int] = {}
        self._transient: dict[str, int] = {}
        self.fired: list[str] = []

    @staticmethod
    def _check(point: str) -> None:
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r}; expected one "
                             f"of {CRASH_POINTS}")

    def crash_at(self, point: str, after: int = 0) -> "FaultInjector":
        self._check(point)
        self._crash[point] = int(after)
        return self

    def fail_transient(self, point: str, times: int = 1) -> "FaultInjector":
        self._check(point)
        self._transient[point] = int(times)
        return self

    def fire(self, point: str) -> None:
        self.fired.append(point)
        left = self._transient.get(point, 0)
        if left > 0:
            self._transient[point] = left - 1
            raise TransientIOError(
                f"injected transient IO failure at {point!r}")
        if point in self._crash:
            if self._crash[point] > 0:
                self._crash[point] -= 1
            else:
                del self._crash[point]
                raise InjectedCrash(f"injected crash at {point!r}")


# ---------------------------------------------------------------------------
# Record payloads: pytrees <-> bytes
# ---------------------------------------------------------------------------

# A record payload is one JSON head (lsn, kind, pytree skeleton, per-leaf
# dtype/shape/byte-length/fold) followed by the leaves as concatenated
# raw little-endian blobs. The skeleton is the pytree with every leaf
# replaced by a placeholder string (jax treats None as an empty subtree,
# so None can't mark leaf sites); registered-dataclass formats like
# CPTensor/TTTensor pickle structurally.
#
# Integrity is two-tier, sized to the commit hot path on one core: the
# frame's crc32 covers only the (small) head section, and each blob
# carries a 64-bit xor-fold — one streaming pass at memory bandwidth
# instead of a crc over megabytes of items, still flipping on any single
# damaged burst (torn write, zeroed block, bit flip).

_LEAF = "__leaf__"
_HEAD = struct.Struct("<I")
_FRAME = struct.Struct("<II")    # record length + crc32 of the head section
_ALIGN = 4096                    # records start on direct-IO block bounds


class _BlobDamage(Exception):
    """A record's head validated but a blob's fold did not (torn or
    corrupted item data). Internal to ``read_wal``'s torn-tail logic."""


def _aligned(n: int) -> int:
    return (int(n) + _ALIGN - 1) // _ALIGN * _ALIGN


def _fold64(arr: np.ndarray) -> int:
    b = arr.reshape(-1).view(np.uint8)
    n = b.nbytes - b.nbytes % 8
    acc = int(np.bitwise_xor.reduce(b[:n].view(np.uint64))) if n else 0
    if b.nbytes > n:
        acc ^= int.from_bytes(
            bytes(b[n:]) + b"\0" * (8 - b.nbytes + n), "little")
    return acc


def _tree_to_blobs(tree) -> tuple[dict, list[np.ndarray]]:
    if tree is None:
        return {"skeleton": None, "leaves": []}, []
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    skeleton = jax.tree_util.tree_unflatten(treedef, [_LEAF] * len(leaves))
    blobs = [np.ascontiguousarray(np.asarray(leaf)) for leaf in leaves]
    head = {"skeleton": base64.b64encode(pickle.dumps(skeleton)).decode(),
            "leaves": [{"dtype": b.dtype.str, "shape": list(b.shape),
                        "len": int(b.nbytes), "fold": _fold64(b)}
                       for b in blobs]}
    return head, blobs


def _encode_record(lsn: int, kind: str, tree) -> tuple[bytes, list]:
    """-> (frame header + head section, raw blob arrays to follow it)."""
    head, blobs = _tree_to_blobs(tree)
    head.update(lsn=int(lsn), kind=kind)
    hb = json.dumps(head).encode()
    sect = _HEAD.pack(len(hb)) + hb
    length = len(sect) + sum(b.nbytes for b in blobs)
    return _FRAME.pack(length, zlib.crc32(sect)) + sect, blobs


def _decode_record(payload) -> tuple[int, str, Any]:
    """Decode one payload (head crc already verified by the caller);
    raises ``_BlobDamage`` on a blob fold mismatch."""
    (hlen,) = _HEAD.unpack_from(payload, 0)
    head = json.loads(bytes(payload[_HEAD.size:_HEAD.size + hlen]).decode())
    if head["skeleton"] is None:
        return int(head["lsn"]), head["kind"], None
    skeleton = pickle.loads(base64.b64decode(head["skeleton"]))
    treedef = jax.tree_util.tree_structure(skeleton)
    leaves, off = [], _HEAD.size + hlen
    for spec in head["leaves"]:
        # bytes() realigns the slice so the uint64 fold view is valid
        raw = np.frombuffer(bytes(payload[off:off + spec["len"]]),
                            dtype=np.dtype(spec["dtype"]))
        arr = raw.reshape(spec["shape"])
        if _fold64(arr) != spec["fold"]:
            raise _BlobDamage(f"blob checksum mismatch at payload "
                              f"offset {off}")
        leaves.append(arr)
        off += spec["len"]
    return (int(head["lsn"]), head["kind"],
            jax.tree_util.tree_unflatten(treedef, leaves))


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------

_WAL_RE = re.compile(r"wal_(\d{12})\.log")
_SNAP_RE = re.compile(r"snap_(\d{12})")


def _wal_files(directory: str) -> list[tuple[int, str]]:
    """(start_lsn, path) of every WAL segment, in lsn order."""
    out = []
    for name in os.listdir(directory):
        m = _WAL_RE.fullmatch(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    return sorted(out)


def _head_valid(data, off) -> bool:
    """Does a plausible record with a passing head crc start at off?"""
    if len(data) - off < _FRAME.size:
        return False
    length, crc = _FRAME.unpack_from(data, off)
    if length < _HEAD.size or off + _FRAME.size + length > len(data):
        return False
    (hlen,) = _HEAD.unpack_from(data, off + _FRAME.size)
    sect_end = off + _FRAME.size + _HEAD.size + hlen
    if _HEAD.size + hlen > length:
        return False
    return zlib.crc32(data[off + _FRAME.size:sect_end]) == crc


def _any_record_beyond(data, off) -> bool:
    """Scan aligned offsets strictly past ``off`` (the damaged record's
    start) for any valid-looking record — distinguishes a torn tail
    (nothing but zeros/garbage follows) from mid-log corruption (intact
    records follow the damage)."""
    off = _aligned(off + 1)
    while off < len(data):
        if _head_valid(data, off):
            return True
        off += _ALIGN
    return False


def read_wal(directory: str):
    """Scan every WAL segment -> (records, tail).

    Records sit at ``_ALIGN``-ed offsets; a zero length field marks the
    end of a prezeroed segment. ``records`` is ``[(lsn, kind, tree),
    ...]`` in commit order; ``tail`` is ``(path, valid_end)`` of the
    newest segment — the byte offset after its last whole record, where
    recovery resumes appending. A damaged final record *of the newest
    segment* (short frame, failed head checksum, failed blob fold) is a
    torn tail — a crash mid-append — and is dropped; the same damage with
    intact records after it, or in any older segment, raises
    ``WalCorrupted``, as does an lsn discontinuity between records.
    """
    files = _wal_files(directory)
    records: list[tuple[int, str, Any]] = []
    tail = None
    for idx, (start, path) in enumerate(files):
        last = idx == len(files) - 1
        with open(path, "rb") as f:
            data = f.read()
        view = memoryview(data)
        off = 0
        while len(data) - off >= _FRAME.size:
            length, crc = _FRAME.unpack_from(data, off)
            if length == 0:
                break                       # prezeroed tail: end of log
            end = off + _FRAME.size + length
            bad = None
            if length < _HEAD.size or end > len(data):
                bad = "truncated record"
            elif not _head_valid(data, off):
                bad = "checksum mismatch"
            else:
                try:
                    rec = _decode_record(view[off + _FRAME.size:end])
                except _BlobDamage as e:
                    bad = str(e)
            if bad is None:
                records.append(rec)
                off = _aligned(end)
                continue
            if last and not _any_record_beyond(data, off):
                break                       # torn tail: crash mid-append
            raise WalCorrupted(f"{path}: {bad} at offset {off}")
        if last:
            tail = (path, off)
    for (a, _, _), (b, _, _) in zip(records, records[1:]):
        if b != a + 1:
            raise WalCorrupted(f"lsn discontinuity: record {a} followed "
                               f"by {b}")
    return records, tail


_MIN_SEG = 256 * 1024            # first segment; sized up as records grow
_MAX_SEG = 64 * 1024 * 1024


class MutationLog:
    """One open WAL segment with an overlapped, near-zero-CPU commit.

    Segments are preallocated and prezeroed, records start on ``_ALIGN``
    boundaries, and appends go through ``O_DIRECT`` where the filesystem
    allows it (buffered + ``fdatasync`` otherwise) — with the extents
    already materialized, the per-commit ``fdatasync`` is a device flush
    with no metadata journaling, so almost the whole append is DMA/iowait
    the committer thread can hide under the caller's apply even on one
    core.

    ``begin`` fires ``pre_wal_append`` on the caller's thread (nothing is
    written if it faults) and hands the encode + write + sync to a single
    committer thread. ``finish`` joins the committer and fires
    ``post_wal_append`` — when it returns, the record survives process
    death. ``cancel`` rolls a begun record back out of the log (the apply
    failed, so the record must not replay). ``append`` is the plain
    synchronous composition for small records (epoch markers). On any
    failure mid-append the record's region is wound back to zeros so a
    retry never leaves a torn record *inside* the log. ``rotate(lsn)``
    starts a fresh segment (after a snapshot covering ``lsn``).
    """

    def __init__(self, directory: str, *, next_lsn: int,
                 path: str | None = None, append_at: int = 0,
                 injector: FaultInjector | None = None):
        self.directory = directory
        self.next_lsn = int(next_lsn)
        self.injector = injector or FaultInjector()
        self._committer = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="wal-commit")
        self._buf: mmap.mmap | None = None
        self._fd = None
        self._max_record = 0
        self._open_segment(
            path or os.path.join(directory,
                                 f"wal_{self.next_lsn:012d}.log"),
            append_at=append_at)

    # -- segment management --------------------------------------------------

    def _open_segment(self, path: str, *, append_at: int = 0,
                      min_size: int = 0) -> None:
        """Open ``path`` for appending at ``append_at`` (aligned up):
        anything beyond — a torn tail, a stale prezeroed area — is cut
        and re-zeroed out to the segment's preallocated size."""
        if self._fd is not None:
            os.close(self._fd)
        self._path = path
        self._off = _aligned(append_at)
        size = max(_MIN_SEG, _aligned(min_size), self._off,
                   _aligned(os.path.getsize(path))
                   if os.path.exists(path) else 0)
        with open(path, "r+b" if os.path.exists(path) else "w+b") as f:
            f.truncate(self._off)
            f.seek(self._off)
            left = size - self._off
            chunk = b"\0" * min(1 << 22, max(left, 1))
            while left > 0:
                left -= f.write(chunk[:min(len(chunk), left)])
            f.flush()
            os.fsync(f.fileno())
        self._size = size
        try:
            self._fd = os.open(path, os.O_WRONLY | os.O_DIRECT)
            self._direct = True
        except OSError:                      # filesystem without direct IO
            self._fd = os.open(path, os.O_WRONLY)
            self._direct = False

    def _staging(self, n: int) -> mmap.mmap:
        """A reusable page-aligned buffer of >= n bytes (direct IO needs
        block-aligned memory; mmap pages are)."""
        if self._buf is None or len(self._buf) < n:
            if self._buf is not None:
                self._buf.close()
            self._buf = mmap.mmap(-1, max(_aligned(n), _MIN_SEG))
        return self._buf

    def _wind_back(self, start: int, need: int) -> None:
        """Return the region of a failed/cancelled append to zeros."""
        try:
            if self._direct:
                buf = self._staging(need)
                buf[:need] = b"\0" * need
                os.pwrite(self._fd, memoryview(buf)[:need], start)
                os.fdatasync(self._fd)
            else:
                os.truncate(self._path, start)
        except OSError:
            pass
        self._off = start

    def _append_sync(self, kind: str, tree) -> tuple[int, int, int]:
        """Committer-thread body: -> (lsn, record offset, aligned size)."""
        frame, blobs = _encode_record(self.next_lsn, kind, tree)
        need = _aligned(len(frame) + sum(b.nbytes for b in blobs))
        self._max_record = max(self._max_record, need)
        if self._off + need > self._size:
            self._open_segment(
                os.path.join(self.directory,
                             f"wal_{self.next_lsn:012d}.log"),
                min_size=max(32 * need, min(32 * self._max_record,
                                            _MAX_SEG)))
        start = self._off
        try:
            if self._direct:
                buf = self._staging(need)
                buf[:len(frame)] = frame
                pos = len(frame)
                for b in blobs:
                    if b.nbytes:
                        buf[pos:pos + b.nbytes] = b.reshape(-1).view(
                            np.uint8).data
                        pos += b.nbytes
                buf[pos:need] = b"\0" * (need - pos)
                os.pwrite(self._fd, memoryview(buf)[:need], start)
            else:
                os.lseek(self._fd, start, os.SEEK_SET)
                os.write(self._fd, frame)
                for b in blobs:
                    if b.nbytes:
                        os.write(self._fd, b.reshape(-1).view(np.uint8).data)
            os.fdatasync(self._fd)
        except BaseException:
            self._wind_back(start, need)
            raise
        self._off = start + need
        lsn = self.next_lsn
        self.next_lsn += 1
        return lsn, start, need

    # -- commit protocol -----------------------------------------------------

    def begin(self, kind: str, tree) -> Future:
        """Start committing one record. Raises before touching the file
        on an armed ``pre_wal_append`` fault (the record is *not*
        committed); otherwise the write + sync proceed on the committer
        thread while the caller applies the mutation in memory."""
        self.injector.fire("pre_wal_append")
        return self._committer.submit(self._append_sync, kind, tree)

    def finish(self, token: Future) -> int:
        """Join a ``begin``; -> the record's lsn, now durable. An armed
        ``post_wal_append`` fault fires with the record already synced."""
        lsn, _, _ = token.result()
        self.injector.fire("post_wal_append")
        return lsn

    def cancel(self, token: Future) -> None:
        """Roll a begun record back out (the apply failed): if the
        committer got it onto disk, zero it back off; a committer failure
        already wound itself back (and is swallowed — the caller is
        re-raising the apply's error)."""
        try:
            _, start, need = token.result()
        except BaseException:
            return
        self._wind_back(start, need)
        self.next_lsn -= 1

    def append(self, kind: str, tree) -> int:
        """Synchronous commit of one record; returns its lsn."""
        return self.finish(self.begin(kind, tree))

    def rotate(self, lsn: int) -> None:
        path = os.path.join(self.directory, f"wal_{int(lsn):012d}.log")
        if path == self._path and self._off == 0:
            return                           # already a fresh, empty segment
        self._open_segment(path,
                           min_size=min(32 * self._max_record, _MAX_SEG))

    def close(self) -> None:
        if self._fd is not None:
            self._committer.shutdown(wait=True)
            os.close(self._fd)
            self._fd = None
            if self._buf is not None:
                self._buf.close()
                self._buf = None


# ---------------------------------------------------------------------------
# Atomic snapshots
# ---------------------------------------------------------------------------


def _service_config(svc: LSHService) -> dict:
    """The identity a snapshot is only valid for: family + index layout.
    Recovery compares this against the recovering service's own config and
    refuses on any mismatch — replay through a different family would
    silently produce a different index."""
    fam, index = svc.index.family, svc.index
    return {
        "index": type(index).__name__,
        "metric": index.metric,
        "seed": int(index.seed),
        "kind": fam.kind,
        "num_codes": int(fam.num_codes),
        "num_tables": int(fam.num_tables),
        "bucket_width": float(fam.bucket_width),
        "shards": int(getattr(index, "shards", 0)),
        "bucket_cap": index.bucket_cap,
        "max_deltas": int(index.max_deltas),
    }


def latest_snapshot(directory: str) -> int | None:
    """lsn of the newest *complete* snapshot (manifest present), if any."""
    if not os.path.isdir(directory):
        return None
    lsns = []
    for name in os.listdir(directory):
        m = _SNAP_RE.fullmatch(name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            lsns.append(int(m.group(1)))
    return max(lsns) if lsns else None


def write_snapshot(directory: str, lsn: int, svc: LSHService,
                   injector: FaultInjector | None = None) -> str:
    """Atomically dump the service's ``SegmentStore`` as of log position
    ``lsn`` (= number of WAL records the state includes). checkpoint.py's
    idiom: write everything into ``snap_<lsn>.tmp/``, fsync the crc32
    manifest, then one ``os.rename`` publishes it — a crash anywhere in
    between leaves only an ignored ``.tmp`` directory behind."""
    injector = injector or FaultInjector()
    store = svc.index.store
    state = store.host_state()
    name = f"snap_{int(lsn):012d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    counter = itertools.count()

    def put(arr) -> dict:
        arr = np.asarray(arr)
        fname = f"arr_{next(counter):05d}.npy"
        np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        return {"file": fname,
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes())}

    manifest: dict = {"lsn": int(lsn), "config": _service_config(svc),
                      "seq_len": state["seq_len"],
                      "live_window": state["live_window"], "segments": []}
    for seg, pos in zip([store.base] + store.deltas, state["slot_pos"]):
        leaves, treedef = jax.tree_util.tree_flatten(seg.corpus)
        skeleton = jax.tree_util.tree_unflatten(treedef,
                                                [_LEAF] * len(leaves))
        entry = {"type": type(seg).__name__, "cap": int(seg.cap),
                 "keys": put(seg.keys), "sorted_keys": put(seg.sorted_keys),
                 "perm": put(seg.perm), "slot_pos": put(pos),
                 "corpus_skeleton": base64.b64encode(
                     pickle.dumps(skeleton)).decode(),
                 "corpus": [put(leaf) for leaf in leaves]}
        if isinstance(seg, ShardedSegment):
            entry["counts"] = [int(c) for c in seg.counts]
        manifest["segments"].append(entry)
    injector.fire("mid_snapshot")
    manifest["live_host"] = put(state["live_host"])
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_snapshot(directory: str, lsn: int, config: dict):
    """-> (segments, host_state) of snapshot ``lsn``, crc-verified.
    Raises ``RecoveryError`` on a config mismatch or corrupt array."""
    path = os.path.join(directory, f"snap_{int(lsn):012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    diffs = {k: (manifest["config"].get(k), v) for k, v in config.items()
             if manifest["config"].get(k) != v}
    if diffs:
        raise RecoveryError(
            f"snapshot {path} was written by a differently-configured "
            f"service; mismatched (snapshot, live) fields: {diffs}")

    def get(ref: dict) -> np.ndarray:
        arr = np.load(os.path.join(path, ref["file"]), allow_pickle=False)
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != ref["crc32"]:
            raise RecoveryError(
                f"snapshot corruption in {path}/{ref['file']}")
        return arr

    segs, slot_pos = [], []
    for entry in manifest["segments"]:
        skeleton = pickle.loads(base64.b64decode(entry["corpus_skeleton"]))
        treedef = jax.tree_util.tree_structure(skeleton)
        corpus = jax.tree_util.tree_unflatten(
            treedef, [jax.numpy.asarray(get(r)) for r in entry["corpus"]])
        keys = jax.numpy.asarray(get(entry["keys"]))
        sorted_keys = jax.numpy.asarray(get(entry["sorted_keys"]))
        perm = jax.numpy.asarray(get(entry["perm"]))
        if entry["type"] == "ShardedSegment":
            segs.append(ShardedSegment(
                keys=keys, sorted_keys=sorted_keys, perm=perm, corpus=corpus,
                cap=int(entry["cap"]), counts=tuple(entry["counts"])))
        else:
            segs.append(TableSegment(
                keys=keys, sorted_keys=sorted_keys, perm=perm, corpus=corpus,
                cap=int(entry["cap"])))
        slot_pos.append(get(entry["slot_pos"]))
    state = {"slot_pos": slot_pos, "live_host": get(manifest["live_host"]),
             "seq_len": int(manifest["seq_len"]),
             "live_window": bool(manifest["live_window"])}
    return segs, state


def _prune(directory: str, cover: int, keep_snapshots: int) -> None:
    """Drop snapshots beyond the newest ``keep_snapshots`` and every WAL
    segment that ends at or before the oldest kept snapshot."""
    snaps = sorted(
        int(m.group(1)) for name in os.listdir(directory)
        if (m := _SNAP_RE.fullmatch(name))
        and os.path.exists(os.path.join(directory, name, "manifest.json")))
    for lsn in snaps[:-keep_snapshots] if keep_snapshots else snaps:
        shutil.rmtree(os.path.join(directory, f"snap_{lsn:012d}"),
                      ignore_errors=True)
    oldest_kept = snaps[-keep_snapshots] if snaps else cover
    files = _wal_files(directory)
    for (start, path), (next_start, _) in zip(files, files[1:]):
        if next_start <= oldest_kept:
            os.remove(path)


# ---------------------------------------------------------------------------
# Durable service
# ---------------------------------------------------------------------------


class DurableLSHService(LSHService):
    """``LSHService`` whose mutations are write-ahead committed.

    ``build()`` starts a fresh durable identity under ``directory``
    (snapshot at lsn 0 + a new WAL); every ``insert``/``delete`` and
    every published swap appends an fsync'd record, overlapped with the
    in-memory apply but joined before the call returns — committed iff
    appended. Every ``snapshot_every`` records a new snapshot is written
    and the WAL rotated. ``recover()`` — on a freshly constructed,
    identically-configured instance, or in place on a degraded one —
    restores the latest complete snapshot and replays the log suffix,
    bit-identically.
    """

    def __init__(self, family, directory: str, *, snapshot_every: int = 512,
                 keep_snapshots: int = 2,
                 injector: FaultInjector | None = None, **kwargs):
        super().__init__(family, **kwargs)
        if int(snapshot_every) < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        self.directory = str(directory)
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = int(keep_snapshots)
        self.injector = injector or FaultInjector()
        self.health = "cold"
        self._log: MutationLog | None = None
        self._cover = 0          # lsn the latest snapshot covers

    # -- lifecycle ----------------------------------------------------------

    def build(self, corpus, batch_size: int = 2048) -> "DurableLSHService":
        """(Re)build from a corpus and start a fresh durable identity:
        prior snapshots/WAL under the directory belong to a corpus this
        instance no longer serves and are removed."""
        os.makedirs(self.directory, exist_ok=True)
        self._close_log()
        for name in os.listdir(self.directory):
            if _WAL_RE.fullmatch(name):
                os.remove(os.path.join(self.directory, name))
            elif _SNAP_RE.fullmatch(name) or _SNAP_RE.fullmatch(
                    name.removesuffix(".tmp")):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)
        super().build(corpus, batch_size=batch_size)
        self._write_snapshot(0)
        self._cover = 0
        self._log = MutationLog(self.directory, next_lsn=0,
                                injector=self.injector)
        self.health = "serving"
        return self

    def close(self) -> None:
        self._close_log()

    def _close_log(self) -> None:
        if self._log is not None:
            self._log.close()
            self._log = None

    def _require_serving(self, what: str) -> None:
        if self.health != "serving":
            self.stats.unavailable += 1
            raise ServiceUnavailable(
                f"{what} rejected: durable service is {self.health!r} "
                "(recover() restores it to 'serving')")

    # -- write-ahead commit --------------------------------------------------

    def _commit(self, kind: str, tree) -> int:
        t0 = time.perf_counter()
        lsn = self._log.append(kind, tree)
        self.stats.wal_ms += (time.perf_counter() - t0) * 1e3
        self.stats.wal_appends += 1
        return lsn

    def _commit_overlapped(self, kind: str, tree, apply_fn) -> None:
        """Commit a record while ``apply_fn`` runs: the fsync proceeds on
        the committer thread under the device-side apply, and the caller
        returns only once both are done — externally the same
        commit-then-apply contract as ``_commit``, without paying the two
        latencies serially. An apply failure cancels the record (it must
        not replay); a commit failure after a successful apply leaves
        memory ahead of the log, so the service degrades rather than
        commit further ops on top of unlogged state."""
        t0 = time.perf_counter()
        token = self._log.begin(kind, tree)
        t_begin = time.perf_counter()
        try:
            apply_fn()
        except BaseException:
            self._log.cancel(token)
            raise
        t_apply = time.perf_counter()
        try:
            self._log.finish(token)
        except InjectedCrash:
            raise               # durable AND applied: consistent as it lies
        except BaseException:
            self.health = "degraded"
            raise
        self.stats.wal_ms += ((t_begin - t0)
                              + (time.perf_counter() - t_apply)) * 1e3
        self.stats.wal_appends += 1

    def _maybe_snapshot(self) -> None:
        if self._log.next_lsn - self._cover >= self.snapshot_every:
            self.snapshot()

    def snapshot(self) -> "DurableLSHService":
        """Write a snapshot now, rotate the WAL, prune old state."""
        self._require_serving("snapshot")
        lsn = self._log.next_lsn
        self._write_snapshot(lsn)
        self._cover = lsn
        self._log.rotate(lsn)
        _prune(self.directory, lsn, self.keep_snapshots)
        return self

    def _write_snapshot(self, lsn: int) -> None:
        t0 = time.perf_counter()
        write_snapshot(self.directory, lsn, self, self.injector)
        self.stats.snapshot_ms += (time.perf_counter() - t0) * 1e3
        self.stats.snapshots += 1

    # -- mutations (logged) --------------------------------------------------

    def query_arrays(self, queries, topk: int = 10, **kwargs):
        self._require_serving("query")
        return super().query_arrays(queries, topk, **kwargs)

    def insert(self, batch, batch_size: int = 2048) -> "DurableLSHService":
        self._require_serving("insert")
        batch = np.asarray(batch)      # one materialization: log + apply
        self._commit_overlapped(
            "insert", batch,
            lambda: LSHService.insert(self, batch, batch_size=batch_size))
        self._maybe_snapshot()
        return self

    def delete(self, ids) -> int:
        self._require_serving("delete")
        ids = np.asarray(ids)
        out = []
        self._commit_overlapped(
            "delete", ids,
            lambda: out.append(LSHService.delete(self, ids)))
        self._maybe_snapshot()
        return out[0]

    def apply_swap(self, pending) -> "DurableLSHService":
        """Publish a prepared swap with an epoch marker ahead of the flip.
        The marker commits only after the same staleness check the flip
        itself enforces, so a record is never logged for a swap that then
        refuses to publish."""
        if pending is None:
            return self
        self._require_serving("apply_swap")
        store = self._mutable_index().store
        if (store is not pending.source
                or store.generation != pending.generation):
            return super().apply_swap(pending)   # the standard stale error
        self._commit(pending.kind, None)
        self.injector.fire("pre_apply_swap")
        super().apply_swap(pending)
        self._maybe_snapshot()
        return self

    # -- recovery ------------------------------------------------------------

    def recover(self) -> "DurableLSHService":
        """Restore the latest complete snapshot + replay the WAL suffix.

        Replays through the plain ``LSHService`` mutation path (no
        re-logging); the log's own torn tail, if any, is truncated before
        the WAL reopens for appends. On any failure the service lands in
        ``"degraded"`` and the error propagates — it never half-serves.
        """
        t0 = time.perf_counter()
        self.health = "recovering"
        self._close_log()
        try:
            lsn = latest_snapshot(self.directory)
            if lsn is None:
                raise RecoveryError(
                    f"no complete snapshot under {self.directory!r}; "
                    "nothing to recover from")
            segs, state = load_snapshot(self.directory, lsn,
                                        _service_config(self))
            self._install(segs, state)
            records, tail = read_wal(self.directory)
            expect = lsn
            for rec_lsn, kind, tree in records:
                if rec_lsn < lsn:
                    continue
                if rec_lsn != expect:
                    raise RecoveryError(
                        f"WAL gap: snapshot covers lsn {lsn}, expected "
                        f"record {expect} next but found {rec_lsn}")
                self._replay(kind, tree)
                expect += 1
            if tail is not None:
                path, valid_end = tail         # reopen past the last whole
                self._log = MutationLog(self.directory, next_lsn=expect,
                                        path=path, append_at=valid_end,
                                        injector=self.injector)
            else:
                self._log = MutationLog(self.directory, next_lsn=expect,
                                        injector=self.injector)
            self._cover = lsn
        except BaseException:
            self.health = "degraded"
            raise
        self.stats.recoveries += 1
        self.stats.recovery_ms += (time.perf_counter() - t0) * 1e3
        self.health = "serving"
        return self

    def _install(self, segs, state) -> None:
        index = self._mutable_index()
        index._reset_mutation_state()
        if isinstance(index, ShardedLSHIndex):
            from repro.distributed import index_sharding
            index.mesh, index.mesh_axis = index_sharding.resolve_mesh(
                int(index.shards))
            if index.mesh is not None:
                segs = [index._place_segment(s) for s in segs]
            index.store = SegmentStore.restore(segs, state,
                                               place=index._place())
            index._corpus = None
        else:
            index.store = SegmentStore.restore(segs, state)
        self.stats.reset_mutations()
        self._track_shards()

    def _replay(self, kind: str, tree) -> None:
        # Explicitly the base-class methods: replay must apply, not re-log.
        if kind == "insert":
            LSHService.insert(self, tree)
        elif kind == "delete":
            LSHService.delete(self, tree)
        elif kind == "compact":
            LSHService.apply_swap(self, LSHService.prepare_compact(self))
        elif kind == "rebalance":
            LSHService.apply_swap(self, LSHService.prepare_rebalance(self))
        else:
            raise RecoveryError(f"unknown WAL record kind {kind!r}")
