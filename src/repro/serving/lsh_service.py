"""Batched LSH similarity-search service — the paper's workload as a
deployable serving component.

A corpus of tensors (dense / CP / TT format) is hashed once at build time
with one of the paper's families; queries arrive in batches and run through
the segment-store indexes of ``repro.core.index`` as one jit-compiled
program — batch-native fused hashing (projection -> discretize -> bucket
keys in one program; ``build_service(..., hash_backend=...)`` picks the
XLA einsum path or the Pallas kernels, 'auto' = pallas on TPU), vmapped
``searchsorted`` bucket probes over every segment's sorted key tables,
tombstone filtering, and exact in-format re-rank — never leaving the
accelerator until the final top-k.

The corpus is mutable in place: ``insert(batch)`` appends a sorted delta
segment (served immediately, no rebuild), ``delete(ids)`` tombstones items
by their current effective ids, and ``compact()`` folds deltas and
tombstones back into one base segment (also triggered automatically past
the index's ``max_deltas``). ``ServiceStats`` tracks the mutation traffic
next to the query traffic, with automatic compaction time split out of
``insert_ms`` (``auto_compact_ms``/``auto_compactions``) so ingest
throughput numbers never silently absorb fold cost.

Mutations never stall serving: ``prepare_compact()``/``prepare_rebalance()``
build the full replacement store off the query path (every array
materialized and placed — the second buffer of a double-buffered swap) and
``apply_swap()`` publishes it as a single pointer flip. Queries dispatched
before the flip finish on the store they pinned, bit-identical to its
answers; the synchronous ``compact()``/``rebalance()`` endpoints are the
same prepare+flip pair run back-to-back. ``repro.serving.scheduler`` runs
the prepare step on its ingest lane so the query lane never waits.

``LSHService(..., shards=S)`` serves through the mesh-sharded
``ShardedLSHIndex``, whose mutation plane is shard-native: the base
segment is partitioned into S per-shard sorted tables (placed over a mesh
axis when one is available, see ``repro.distributed.index_sharding``),
``insert`` routes each batch to the least-loaded shards as one sharded
delta slab (no replication), ``compact()`` is shard-local, and the
explicit ``rebalance()`` endpoint re-partitions the live corpus when
occupancy skews (``ServiceStats.shard_occupancy`` / ``rebalances`` track
it). Queries fan out to every shard, probe base + delta slabs per shard,
and merge globally. Effective-id bookkeeping is automatic — callers
always see ids into the current live corpus regardless of shard or
segment count.

``LSHService(..., device=False)`` serves through ``HostLSHIndex`` (the
dict-of-buckets build kept as the membership reference); queries run
through the same shared planner, mutations are rebuild-only.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.index import (DeviceLSHIndex, HostLSHIndex, ShardedLSHIndex,
                              _SegmentedIndex)
from repro.core.lsh import LSHFamily, make_family
from repro.core.probing import QUERY_MODES


@dataclasses.dataclass
class ServiceStats:
    queries: int = 0
    batches: int = 0
    total_ms: float = 0.0
    total_candidates: int = 0
    build_s: float = 0.0
    # per-mode query counters (topk + uniform + weighted == queries)
    topk_queries: int = 0
    uniform_queries: int = 0
    weighted_queries: int = 0
    # mutation counters
    inserted: int = 0          # items appended via insert()
    insert_batches: int = 0
    insert_ms: float = 0.0     # insert wall time, auto-compaction excluded
    deleted: int = 0           # items tombstoned via delete()
    delete_batches: int = 0
    compactions: int = 0       # explicit compact()/apply_swap publications
    compact_ms: float = 0.0    # explicit compact build wall time only
    auto_compactions: int = 0  # max_deltas-triggered folds inside insert()
    auto_compact_ms: float = 0.0
    rebalances: int = 0        # explicit cross-shard re-partitions
    rebalance_ms: float = 0.0
    rejected: int = 0          # requests refused by a tenant quota
                               # (set by the serving scheduler)
    shard_occupancy: tuple[int, ...] = ()  # live items per shard (sharded
                                           # index only; updated per mutation)
    # robustness / durability counters
    errors: int = 0            # failed ingest-lane mutations (scheduler)
    last_error: str = ""       # "<Type>: <message>" of the newest failure
    retries: int = 0           # ingest retries after transient IO failures
    timeouts: int = 0          # requests expired past the scheduler deadline
    unavailable: int = 0       # requests shed while degraded/recovering
    recoveries: int = 0        # successful snapshot+replay recoveries
    recovery_ms: float = 0.0   # restore + replay wall time
    wal_appends: int = 0       # committed WAL records
    wal_ms: float = 0.0        # fsync-inclusive WAL append wall time
    snapshots: int = 0         # atomic snapshots written
    snapshot_ms: float = 0.0

    @property
    def occupancy_skew(self) -> float:
        """max/mean live items per shard (1.0 = perfectly balanced)."""
        occ = self.shard_occupancy
        if not occ or not sum(occ):
            return 1.0
        return max(occ) * len(occ) / sum(occ)

    @property
    def mean_latency_ms(self):
        return self.total_ms / max(self.queries, 1)

    @property
    def mean_candidates(self):
        return self.total_candidates / max(self.queries, 1)

    @property
    def qps(self):
        return self.queries / max(self.total_ms / 1e3, 1e-9)

    @property
    def insert_items_per_s(self):
        return self.inserted / max(self.insert_ms / 1e3, 1e-9)

    def reset(self):
        """Zero the query counters (e.g. after jit warmup); keeps build_s
        and the mutation counters."""
        self.queries = self.batches = 0
        self.topk_queries = self.uniform_queries = self.weighted_queries = 0
        self.total_ms = 0.0
        self.total_candidates = 0

    def reset_mutations(self):
        """Zero the mutation counters — ``build()`` calls this on every
        (re)build so the stats always describe the live index, never a
        previous corpus's mutation history."""
        self.inserted = self.insert_batches = 0
        self.deleted = self.delete_batches = 0
        self.compactions = self.auto_compactions = self.rebalances = 0
        self.insert_ms = self.compact_ms = 0.0
        self.auto_compact_ms = self.rebalance_ms = 0.0
        self.rejected = 0
        self.shard_occupancy = ()
        self.errors = self.retries = self.timeouts = self.unavailable = 0
        self.last_error = ""
        self.recoveries = self.wal_appends = self.snapshots = 0
        self.recovery_ms = self.wal_ms = self.snapshot_ms = 0.0


class LSHService:
    """build() once, then serve query batches and streaming mutations."""

    def __init__(self, family: LSHFamily, metric: str = "euclidean",
                 device: bool = True, bucket_cap: int | None = None,
                 shards: int | None = None, max_deltas: int = 8,
                 probes: int = 1, query_mode: str = "topk",
                 probe_backend: str = "auto"):
        if int(probes) < 1:
            raise ValueError(f"probes must be >= 1, got {probes}")
        if query_mode not in QUERY_MODES:
            raise ValueError(f"unknown query_mode {query_mode!r}; expected "
                             f"one of {QUERY_MODES}")
        self.probes = int(probes)
        self.query_mode = query_mode
        if shards is not None:
            if not device:
                raise ValueError(
                    "shards requires the device index (pass device=True); "
                    "the host-dict path has no sharded layout")
            self.index = ShardedLSHIndex(family, metric=metric, shards=shards,
                                         bucket_cap=bucket_cap,
                                         max_deltas=max_deltas,
                                         probe_backend=probe_backend)
        elif device:
            self.index = DeviceLSHIndex(family, metric=metric,
                                        bucket_cap=bucket_cap,
                                        max_deltas=max_deltas,
                                        probe_backend=probe_backend)
        else:
            if bucket_cap is not None:
                raise ValueError(
                    "bucket_cap applies to the device index only; the host "
                    "index always probes full buckets (pass device=True)")
            self.index = HostLSHIndex(family, metric=metric,
                                      probe_backend=probe_backend)
        self.stats = ServiceStats()
        self.health = "serving"  # namespace health; the durable subclass
                                 # moves through cold/recovering/degraded

    @property
    def probe_path(self) -> str:
        """The resolved probe backend ('xla' | 'pallas') the underlying
        index serves queries through (see ``core.index.*.probe_path``)."""
        return self.index.probe_path

    def build(self, corpus, batch_size: int = 2048) -> "LSHService":
        t0 = time.perf_counter()
        self.index.build(corpus, batch_size=batch_size)
        self.stats.build_s = time.perf_counter() - t0
        self.stats.reset_mutations()   # stats describe the live index only
        self._track_shards()
        return self

    # -- queries ------------------------------------------------------------

    def query_arrays(self, queries, topk: int = 10, *,
                     probes: int | None = None, mode: str | None = None,
                     seed: int | None = None, stat_rows: int | None = None):
        """Batched raw results: (ids (B, topk), scores (B, topk), n_cand (B,)).

        ids are effective (live-corpus) ids, -1-filled where a row has fewer
        than topk candidates. One jit-compiled call through the shared
        segment planner for every index deployment.

        ``probes``/``mode`` override the service defaults per request —
        validated here with the constructor's contract, so a bad override
        raises the same ``ValueError`` instead of flowing into the jit
        program. The sampling modes (``"uniform"``/``"weighted"``) draw
        ``topk`` distinct members from the probed bucket union and require
        an explicit per-request ``seed`` (the PRNG key is derived from it
        and nothing else — the same seed on the same index state replays
        the exact draw; the service keeps no hidden sampling state).

        ``stat_rows`` caps the row count attributed to the query counters —
        the micro-batch scheduler pads coalesced batches to stable program
        shapes and passes the real request count so pad rows never inflate
        per-tenant stats.
        """
        probes = self.probes if probes is None else int(probes)
        if probes < 1:
            raise ValueError(f"probes must be >= 1, got {probes}")
        if int(topk) < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        mode = self.query_mode if mode is None else mode
        if mode not in QUERY_MODES:
            raise ValueError(f"unknown query mode {mode!r}; expected one "
                             f"of {QUERY_MODES}")
        rng = None
        if mode in ("uniform", "weighted"):
            if seed is None:
                raise ValueError(
                    f"mode={mode!r} needs an explicit per-request seed "
                    "(sampling draws are seeded, never implicit)")
            rng = jax.random.PRNGKey(int(seed))
        elif seed is not None:
            raise ValueError("seed applies to the sampling modes only; "
                             "mode='topk' is deterministic")
        n = jax.tree.leaves(queries)[0].shape[0]
        if stat_rows is not None:
            n = min(n, int(stat_rows))
        t0 = time.perf_counter()
        ids, scores, n_cand = jax.block_until_ready(
            self.index.query_batch(queries, topk=topk, probes=probes,
                                   mode=mode, rng=rng))
        ids, scores, n_cand = (np.asarray(ids), np.asarray(scores),
                               np.asarray(n_cand))
        dt = (time.perf_counter() - t0) * 1e3
        self.stats.queries += n
        setattr(self.stats, f"{mode}_queries",
                getattr(self.stats, f"{mode}_queries") + n)
        self.stats.batches += 1
        self.stats.total_ms += dt
        self.stats.total_candidates += int(n_cand.sum())
        return ids, scores, n_cand

    def query_batch(self, queries, topk: int = 10, *,
                    probes: int | None = None, mode: str | None = None,
                    seed: int | None = None) -> list[dict[str, Any]]:
        """Per-query result dicts (ids/scores trimmed of -1 fill)."""
        ids, scores, n_cand = self.query_arrays(queries, topk=topk,
                                                probes=probes, mode=mode,
                                                seed=seed)
        out = []
        for row_ids, row_scores, nc in zip(ids, scores, n_cand):
            mask = row_ids >= 0
            out.append({"ids": row_ids[mask], "scores": row_scores[mask],
                        "candidates": int(nc)})
        return out

    # -- mutations ----------------------------------------------------------

    def _mutable_index(self) -> _SegmentedIndex:
        if not isinstance(self.index, _SegmentedIndex):
            raise TypeError(
                "the host index is rebuild-only; streaming mutations need "
                "the device or sharded index (device=True)")
        return self.index

    def _track_shards(self) -> None:
        if isinstance(self.index, ShardedLSHIndex):
            self.stats.shard_occupancy = tuple(
                int(c) for c in self.index.occupancy())

    def _sync_mutation_stats(self) -> None:
        """Mirror the index's mutation counters into the stats, splitting
        max_deltas-triggered automatic folds from explicit publications."""
        index = self.index
        self.stats.auto_compactions = index.auto_compactions
        self.stats.auto_compact_ms = index.auto_compact_s * 1e3
        self.stats.compactions = index.compactions - index.auto_compactions
        self.stats.rebalances = getattr(index, "rebalances", 0)

    def insert(self, batch, batch_size: int = 2048) -> "LSHService":
        """Append a batch of items (one delta segment — a routed sharded
        slab on the sharded index — served immediately). A max_deltas
        auto-compaction triggered here is timed into ``auto_compact_ms``,
        never ``insert_ms`` — ``insert_items_per_s`` measures ingest, not
        fold cost."""
        index = self._mutable_index()
        n = jax.tree.leaves(batch)[0].shape[0]
        auto_s0 = index.auto_compact_s
        t0 = time.perf_counter()
        index.insert(batch, batch_size=batch_size)
        jax.block_until_ready(
            [seg.sorted_keys for seg in
             [index.store.base] + index.store.deltas])
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.stats.insert_ms += dt_ms - (index.auto_compact_s - auto_s0) * 1e3
        self.stats.inserted += n
        self.stats.insert_batches += 1
        self._sync_mutation_stats()
        self._track_shards()
        return self

    def delete(self, ids) -> int:
        """Tombstone items by their current effective ids; returns count."""
        n = self._mutable_index().delete(ids)
        self.stats.deleted += n
        self.stats.delete_batches += 1
        self._track_shards()
        return n

    def prepare_compact(self):
        """Build the compacted replacement store OFF the query path and
        return the pending swap (None when there is nothing to fold).
        Queries keep serving the live store while this runs; publish the
        result with ``apply_swap``. The build wall time lands in
        ``compact_ms``."""
        index = self._mutable_index()
        t0 = time.perf_counter()
        pending = index.prepare_compact()
        self.stats.compact_ms += (time.perf_counter() - t0) * 1e3
        return pending

    def prepare_rebalance(self):
        """Build the globally re-partitioned replacement store off the
        query path (sharded index only); publish with ``apply_swap``. The
        build wall time lands in ``rebalance_ms``."""
        index = self._mutable_index()
        if not isinstance(index, ShardedLSHIndex):
            raise TypeError("rebalance applies to the sharded index only "
                            "(pass shards=S)")
        t0 = time.perf_counter()
        pending = index.prepare_rebalance()
        self.stats.rebalance_ms += (time.perf_counter() - t0) * 1e3
        return pending

    def apply_swap(self, pending) -> "LSHService":
        """Publish a prepared store: one pointer flip, no device work.
        Raises RuntimeError if the index mutated since the prepare (the
        shadow would drop those mutations) — serialize mutations with the
        prepare/apply pair, as the scheduler's ingest lane does."""
        self._mutable_index().apply_swap(pending)
        self._sync_mutation_stats()
        self._track_shards()
        return self

    def compact(self) -> "LSHService":
        """Fold deltas + tombstones back into the base (shard-local on the
        sharded index — shards keep their item mix, see ``rebalance``).
        Synchronous prepare + flip; single-threaded callers see exactly
        the old behavior."""
        return self.apply_swap(self.prepare_compact())

    def rebalance(self) -> "LSHService":
        """Re-partition the live corpus into contiguous, evenly-sized
        shards (the explicit cross-shard move; sharded index only)."""
        return self.apply_swap(self.prepare_rebalance())


def build_service(key, kind: str, dims: Sequence[int], corpus, *,
                  metric: str | None = None, num_codes: int = 8,
                  num_tables: int = 8, rank: int = 4,
                  bucket_width: float = 4.0, device: bool = True,
                  bucket_cap: int | None = None,
                  shards: int | None = None,
                  max_deltas: int = 8,
                  hash_backend: str = "auto",
                  probe_backend: str = "auto",
                  probes: int = 1,
                  query_mode: str = "topk") -> LSHService:
    metric = metric or ("cosine" if kind.endswith("srp") else "euclidean")
    fam = make_family(key, kind, dims, num_codes=num_codes,
                      num_tables=num_tables, rank=rank,
                      bucket_width=bucket_width, hash_backend=hash_backend)
    return LSHService(fam, metric=metric, device=device,
                      bucket_cap=bucket_cap, shards=shards,
                      max_deltas=max_deltas, probes=probes,
                      query_mode=query_mode,
                      probe_backend=probe_backend).build(corpus)
