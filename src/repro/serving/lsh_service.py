"""Batched LSH similarity-search service — the paper's workload as a
deployable serving component.

A corpus of tensors (dense / CP / TT format) is hashed once at build time
with one of the paper's families; queries arrive in batches, are hashed on
the accelerator (batched CP/TT Gram einsums -> the Pallas kernels on TPU),
bucketed on the host, and re-ranked with exact in-format distances.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.index import LSHIndex, _tree_index
from repro.core.lsh import LSHFamily, make_family


@dataclasses.dataclass
class ServiceStats:
    queries: int = 0
    total_ms: float = 0.0
    total_candidates: int = 0

    @property
    def mean_latency_ms(self):
        return self.total_ms / max(self.queries, 1)

    @property
    def mean_candidates(self):
        return self.total_candidates / max(self.queries, 1)


class LSHService:
    """build() once, then serve query batches."""

    def __init__(self, family: LSHFamily, metric: str = "euclidean"):
        self.index = LSHIndex(family, metric=metric)
        self.stats = ServiceStats()

    def build(self, corpus, batch_size: int = 2048) -> "LSHService":
        self.index.build(corpus, batch_size=batch_size)
        return self

    def query_batch(self, queries, topk: int = 10) -> list[dict[str, Any]]:
        n = jax.tree.leaves(queries)[0].shape[0]
        t0 = time.perf_counter()
        # hash the whole query batch on-device in one shot
        codes = np.asarray(self.index.family.hash_batch(queries))
        out = []
        for i in range(n):
            q = _tree_index(queries, i)
            ids, scores, n_cand = self.index.query(q, topk=topk)
            out.append({"ids": ids, "scores": scores,
                        "candidates": n_cand})
            self.stats.total_candidates += n_cand
        dt = (time.perf_counter() - t0) * 1e3
        self.stats.queries += n
        self.stats.total_ms += dt
        return out


def build_service(key, kind: str, dims: Sequence[int], corpus, *,
                  metric: str | None = None, num_codes: int = 8,
                  num_tables: int = 8, rank: int = 4,
                  bucket_width: float = 4.0) -> LSHService:
    metric = metric or ("cosine" if kind.endswith("srp") else "euclidean")
    fam = make_family(key, kind, dims, num_codes=num_codes,
                      num_tables=num_tables, rank=rank,
                      bucket_width=bucket_width)
    return LSHService(fam, metric=metric).build(corpus)
