"""Batched LSH similarity-search service — the paper's workload as a
deployable serving component.

A corpus of tensors (dense / CP / TT format) is hashed once at build time
with one of the paper's families; queries arrive in batches and run through
the device-resident ``DeviceLSHIndex`` as one jit-compiled program — batched
hashing (batched CP/TT Gram einsums -> the Pallas kernels on TPU), vmapped
``searchsorted`` bucket probes over the sorted key tables, and exact
in-format re-rank — never leaving the accelerator until the final top-k.

``LSHService(..., shards=S)`` serves through the mesh-sharded
``ShardedLSHIndex``: the corpus is partitioned into S per-shard sorted
tables (placed over a mesh axis when one is available, see
``repro.distributed.index_sharding``), queries fan out to every shard and
the per-shard top-k results merge globally. Global-id bookkeeping is
automatic — each shard ranks local ids and offsets them into the corpus
numbering before the merge, so callers always see corpus-global ids
regardless of the shard count.

``LSHService(..., device=False)`` falls back to the host-dict
``HostLSHIndex`` path (per-query Python bucketing) for A/B comparison.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.index import (DeviceLSHIndex, HostLSHIndex, ShardedLSHIndex,
                              _tree_index)
from repro.core.lsh import LSHFamily, make_family


@dataclasses.dataclass
class ServiceStats:
    queries: int = 0
    batches: int = 0
    total_ms: float = 0.0
    total_candidates: int = 0
    build_s: float = 0.0

    @property
    def mean_latency_ms(self):
        return self.total_ms / max(self.queries, 1)

    @property
    def mean_candidates(self):
        return self.total_candidates / max(self.queries, 1)

    @property
    def qps(self):
        return self.queries / max(self.total_ms / 1e3, 1e-9)

    def reset(self):
        """Zero the query counters (e.g. after jit warmup); keeps build_s."""
        self.queries = self.batches = 0
        self.total_ms = 0.0
        self.total_candidates = 0


class LSHService:
    """build() once, then serve query batches."""

    def __init__(self, family: LSHFamily, metric: str = "euclidean",
                 device: bool = True, bucket_cap: int | None = None,
                 shards: int | None = None):
        if shards is not None:
            if not device:
                raise ValueError(
                    "shards requires the device index (pass device=True); "
                    "the host-dict path has no sharded layout")
            self.index = ShardedLSHIndex(family, metric=metric, shards=shards,
                                         bucket_cap=bucket_cap)
        elif device:
            self.index = DeviceLSHIndex(family, metric=metric,
                                        bucket_cap=bucket_cap)
        else:
            if bucket_cap is not None:
                raise ValueError(
                    "bucket_cap applies to the device index only; the host "
                    "index always probes full buckets (pass device=True)")
            self.index = HostLSHIndex(family, metric=metric)
        self.stats = ServiceStats()

    def build(self, corpus, batch_size: int = 2048) -> "LSHService":
        t0 = time.perf_counter()
        self.index.build(corpus, batch_size=batch_size)
        self.stats.build_s = time.perf_counter() - t0
        return self

    def query_arrays(self, queries, topk: int = 10):
        """Batched raw results: (ids (B, topk), scores (B, topk), n_cand (B,)).

        ids are -1-filled where a row has fewer than topk candidates.
        Device path: one jit-compiled call; host path: per-query loop.
        """
        n = jax.tree.leaves(queries)[0].shape[0]
        t0 = time.perf_counter()
        if isinstance(self.index, (DeviceLSHIndex, ShardedLSHIndex)):
            ids, scores, n_cand = jax.block_until_ready(
                self.index.query_batch(queries, topk=topk))
            ids, scores, n_cand = (np.asarray(ids), np.asarray(scores),
                                   np.asarray(n_cand))
        else:
            bad = np.inf if self.index.metric == "euclidean" else -np.inf
            ids = np.full((n, topk), -1, np.int64)
            scores = np.full((n, topk), bad, np.float32)
            n_cand = np.zeros((n,), np.int64)
            for i in range(n):
                got, sc, nc = self.index.query(_tree_index(queries, i), topk)
                ids[i, :got.size], scores[i, :sc.size] = got, sc
                n_cand[i] = nc
        dt = (time.perf_counter() - t0) * 1e3
        self.stats.queries += n
        self.stats.batches += 1
        self.stats.total_ms += dt
        self.stats.total_candidates += int(n_cand.sum())
        return ids, scores, n_cand

    def query_batch(self, queries, topk: int = 10) -> list[dict[str, Any]]:
        """Per-query result dicts (ids/scores trimmed of -1 fill)."""
        ids, scores, n_cand = self.query_arrays(queries, topk=topk)
        out = []
        for row_ids, row_scores, nc in zip(ids, scores, n_cand):
            mask = row_ids >= 0
            out.append({"ids": row_ids[mask], "scores": row_scores[mask],
                        "candidates": int(nc)})
        return out


def build_service(key, kind: str, dims: Sequence[int], corpus, *,
                  metric: str | None = None, num_codes: int = 8,
                  num_tables: int = 8, rank: int = 4,
                  bucket_width: float = 4.0, device: bool = True,
                  bucket_cap: int | None = None,
                  shards: int | None = None) -> LSHService:
    metric = metric or ("cosine" if kind.endswith("srp") else "euclidean")
    fam = make_family(key, kind, dims, num_codes=num_codes,
                      num_tables=num_tables, rank=rank,
                      bucket_width=bucket_width)
    return LSHService(fam, metric=metric, device=device,
                      bucket_cap=bucket_cap, shards=shards).build(corpus)
