"""Two-lane micro-batch request scheduler + multi-tenant namespaces for
``LSHService`` — the serving plane where mutations never stall queries.

Single queries are the worst case for the jit query program: a B=1 dispatch
pays the same program overhead as B=1024 and none of the batch economics
(``benchmarks/index_serving`` measures the gap at two orders of magnitude
of per-query cost). The scheduler closes it by *coalescing*: the query lane
accumulates compatible single-query requests into one micro-batch and
flushes on whichever comes first — the latency deadline (``deadline_ms``,
measured from the oldest queued request) or the size cap (``max_batch``).
Batches are padded to the next power of two by repeating a row, so the jit
cache holds log2(max_batch) program shapes instead of one per batch size;
pad rows are sliced off before results resolve and never touch the stats
(``stat_rows``). Requests coalesce only within a group key
(tenant, topk, probes, mode) — different knobs are different programs —
and sampling-mode requests never coalesce (each carries its own seed, i.e.
its own draw).

Two lanes, one rule: the *query lane* only reads published stores, the
*ingest lane* owns every mutation. ``insert``/``delete`` run on the ingest
lane directly; ``compact``/``rebalance`` run there as the double-buffered
pair — ``prepare_*`` builds the replacement store (the slow part, off the
query path) and ``apply_swap`` publishes it as a pointer flip. Because the
ingest lane serializes all mutations, the swap's generation guard never
fires in normal operation; the query lane keeps dispatching throughout and
each query is bit-identical to the store generation it pinned.

*Namespaces* multiplex many logical indexes (one ``LSHService`` each —
tenants share the mesh through the same ``resolve_mesh`` rules) behind one
scheduler and one pair of lanes. ``TenantQuota`` bounds each tenant at
admission: ``max_items`` caps the live corpus (oversized inserts are
rejected before they queue), ``max_pending`` caps queued requests
(backpressure). Rejections raise ``QuotaExceeded`` at submission and count
into that tenant's ``ServiceStats.rejected``; per-tenant traffic counters
are the tenant's own ``ServiceStats``.

Every submission returns a ``concurrent.futures.Future``; exceptions (bad
overrides, quota-free service errors) resolve through it. ``flush()``
drains both lanes (and raises ``TimeoutError`` rather than letting a
stalled lane read as drained); the scheduler is a context manager
(``close()`` stops the lanes).

*Robustness*: the ingest lane retries transient WAL/IO failures
(``durability.TransientIOError``) with bounded exponential backoff and
records terminal failures on both the scheduler's and the tenant's stats
(``errors``/``last_error``) — a dropped future never silently swallows a
failed mutation. Exhausted retries or an injected crash mark the
namespace ``"degraded"``; degraded/recovering namespaces shed every
request with a typed ``ServiceUnavailable`` at submission instead of
hanging, until ``recover_namespace()`` replays the tenant's durable
state back to ``"serving"``. ``request_timeout_ms`` expires requests that
sat queued too long with a ``RequestTimeout``.
"""

from __future__ import annotations

import dataclasses
import queue as queue_lib
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Callable

import jax
import numpy as np

from repro.core import segments
from repro.serving.durability import (InjectedCrash, ServiceUnavailable,
                                      TransientIOError)
from repro.serving.lsh_service import LSHService


class QuotaExceeded(RuntimeError):
    """A tenant quota refused this request at admission."""


class RequestTimeout(TimeoutError):
    """The request sat queued past ``request_timeout_ms``; its future
    resolves with this instead of running against state the caller has
    long stopped waiting for."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission limits for one namespace (None = unlimited).

    ``max_items`` caps the tenant's live corpus: an insert that would grow
    past it is rejected at submission. ``max_pending`` caps the tenant's
    queued-but-unserved requests across both lanes — the backpressure
    valve that keeps one tenant from monopolizing the lanes."""

    max_items: int | None = None
    max_pending: int | None = None


@dataclasses.dataclass
class SchedulerStats:
    """Query-lane coalescing counters (per scheduler, across tenants)."""

    requests: int = 0          # single-query submissions served
    batches: int = 0           # jit dispatches on the query lane
    size_flushes: int = 0      # batches flushed by the max_batch cap
    deadline_flushes: int = 0  # batches flushed by the latency deadline
    errors: int = 0            # ingest-lane mutations that failed for good
    last_error: str = ""       # "<Type>: <message>" of the newest failure
    retries: int = 0           # ingest re-runs after transient IO failures
    timeouts: int = 0          # requests expired past request_timeout_ms
    shed: int = 0              # requests refused on a non-serving namespace

    @property
    def mean_batch(self) -> float:
        """Mean coalesced batch size (1.0 = no coalescing happened)."""
        return self.requests / max(self.batches, 1)

    def reset(self) -> None:
        """Zero the counters (e.g. after a warm-up/calibration burst)."""
        self.requests = self.batches = 0
        self.size_flushes = self.deadline_flushes = 0
        self.errors = self.retries = self.timeouts = self.shed = 0
        self.last_error = ""


@dataclasses.dataclass
class _Namespace:
    name: str
    service: LSHService
    quota: TenantQuota
    pending: int = 0           # admitted, not yet completed requests


@dataclasses.dataclass
class _QueryReq:
    ns: _Namespace
    x: Any                     # one item (no batch dim), pytree
    topk: int
    probes: int | None
    mode: str | None
    seed: int | None
    future: Future
    t_submit: float

    @property
    def group_key(self):
        # sampling modes carry per-request seeds (independent draws) and
        # never coalesce; id(self) makes the key unique
        mode = self.mode
        if mode in ("uniform", "weighted"):
            return (id(self),)
        return (self.ns.name, self.topk, self.probes, mode)


@dataclasses.dataclass
class _IngestReq:
    ns: _Namespace
    fn: Callable
    future: Future
    t_submit: float


_STOP = object()


class ServingScheduler:
    """Serve one or many ``LSHService`` namespaces through two lanes.

    ``services``: a single service (namespace ``"default"``) or a
    ``{name: service}`` dict. ``quotas``: optional ``{name: TenantQuota}``.
    ``max_batch``: query-lane size flush (coalesced batch cap).
    ``deadline_ms``: query-lane latency deadline — the oldest queued
    request waits at most this long before its batch dispatches.
    ``request_timeout_ms``: requests still queued past this age resolve
    with ``RequestTimeout`` instead of running (None = never expire).
    ``ingest_retries`` / ``retry_backoff_ms``: the ingest lane re-runs a
    mutation that failed with a *transient* IO error
    (``durability.TransientIOError``) up to ``ingest_retries`` times with
    exponential backoff (capped at 1 s); exhausting the retries — or an
    ``InjectedCrash`` — marks the namespace ``"degraded"``, after which
    requests shed with ``ServiceUnavailable`` until
    ``recover_namespace()`` brings it back.
    """

    def __init__(self, services, *, max_batch: int = 64,
                 deadline_ms: float = 2.0,
                 quotas: dict[str, TenantQuota] | None = None,
                 request_timeout_ms: float | None = None,
                 ingest_retries: int = 3,
                 retry_backoff_ms: float = 10.0):
        if int(max_batch) < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if float(deadline_ms) < 0:
            raise ValueError(f"deadline_ms must be >= 0, got {deadline_ms}")
        if int(ingest_retries) < 0:
            raise ValueError(
                f"ingest_retries must be >= 0, got {ingest_retries}")
        if isinstance(services, LSHService):
            services = {"default": services}
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_ms) / 1e3
        self.timeout_s = (None if request_timeout_ms is None
                          else float(request_timeout_ms) / 1e3)
        self.ingest_retries = int(ingest_retries)
        self.backoff_s = float(retry_backoff_ms) / 1e3
        self.stats = SchedulerStats()
        self._namespaces: dict[str, _Namespace] = {}
        self._lock = threading.Lock()
        quotas = quotas or {}
        for name, svc in services.items():
            self.add_namespace(name, svc, quota=quotas.get(name))
        self._query_q: queue_lib.Queue = queue_lib.Queue()
        self._ingest_q: queue_lib.Queue = queue_lib.Queue()
        self._queries_inflight = 0   # submitted, future not yet resolved
        self._closed = False
        self._query_thread = threading.Thread(
            target=self._query_loop, name="lsh-query-lane", daemon=True)
        self._ingest_thread = threading.Thread(
            target=self._ingest_loop, name="lsh-ingest-lane", daemon=True)
        self._query_thread.start()
        self._ingest_thread.start()

    # -- namespaces ---------------------------------------------------------

    def add_namespace(self, name: str, service: LSHService,
                      quota: TenantQuota | None = None) -> None:
        """Register a logical index under ``name`` (tenants share the mesh
        through the services' own placement rules)."""
        if name in self._namespaces:
            raise ValueError(f"namespace {name!r} already registered")
        self._namespaces[name] = _Namespace(
            name=name, service=service, quota=quota or TenantQuota())

    def namespaces(self) -> tuple[str, ...]:
        return tuple(self._namespaces)

    def service(self, tenant: str = "default") -> LSHService:
        return self._ns(tenant).service

    def tenant_stats(self, tenant: str = "default"):
        """The tenant's ``ServiceStats`` (its per-tenant counters)."""
        return self._ns(tenant).service.stats

    def _ns(self, tenant: str) -> _Namespace:
        ns = self._namespaces.get(tenant)
        if ns is None:
            raise KeyError(
                f"unknown namespace {tenant!r}; registered: "
                f"{sorted(self._namespaces)}")
        return ns

    def _admit(self, ns: _Namespace, new_items: int = 0) -> None:
        with self._lock:
            q = ns.quota
            if q.max_pending is not None and ns.pending >= q.max_pending:
                ns.service.stats.rejected += 1
                raise QuotaExceeded(
                    f"tenant {ns.name!r} has {ns.pending} pending requests "
                    f"(max_pending={q.max_pending})")
            if (new_items and q.max_items is not None
                    and ns.service.index.size + new_items > q.max_items):
                ns.service.stats.rejected += 1
                raise QuotaExceeded(
                    f"insert of {new_items} items would grow tenant "
                    f"{ns.name!r} past max_items={q.max_items} "
                    f"(live={ns.service.index.size})")
            ns.pending += 1

    def _done(self, ns: _Namespace, future: Future) -> Future:
        def _dec(_):
            with self._lock:
                ns.pending -= 1
        future.add_done_callback(_dec)
        return future

    # -- health -------------------------------------------------------------

    def _shed_unless_serving(self, ns: _Namespace) -> None:
        """Degraded-mode serving: a non-serving namespace sheds at
        submission with a typed error instead of queueing work that would
        hang or run against an inconsistent store."""
        health = getattr(ns.service, "health", "serving")
        if health != "serving":
            ns.service.stats.unavailable += 1
            self.stats.shed += 1
            raise ServiceUnavailable(
                f"namespace {ns.name!r} is {health!r}; request shed "
                "(recover_namespace() restores it)")

    def _set_health(self, ns: _Namespace, health: str) -> None:
        ns.service.health = health

    def _record_error(self, ns: _Namespace, exc: BaseException) -> None:
        msg = f"{type(exc).__name__}: {exc}"
        self.stats.errors += 1
        self.stats.last_error = msg
        ns.service.stats.errors += 1
        ns.service.stats.last_error = msg

    def recover_namespace(self, tenant: str = "default") -> Future:
        """Queue a snapshot+replay recovery of a degraded durable tenant
        on the ingest lane (bypasses health shedding — this is the one
        request a non-serving namespace must accept). Resolves to the
        service once it is back to ``"serving"``."""
        ns = self._ns(tenant)
        self._check_open()
        recover = getattr(ns.service, "recover", None)
        if recover is None:
            raise TypeError(
                f"namespace {ns.name!r} serves a non-durable service; "
                "recovery needs a DurableLSHService")
        self._admit(ns)
        return self._submit_ingest(ns, recover)

    # -- submission API -----------------------------------------------------

    def query(self, x, *, tenant: str = "default", topk: int = 10,
              probes: int | None = None, mode: str | None = None,
              seed: int | None = None) -> Future:
        """Submit ONE query (no batch dim) for coalescing; the future
        resolves to (ids (topk,), scores (topk,), n_candidates) with -1
        fill, exactly one row of ``LSHService.query_arrays``."""
        ns = self._ns(tenant)
        self._check_open()
        self._shed_unless_serving(ns)
        self._admit(ns)
        req = _QueryReq(ns=ns, x=x, topk=int(topk), probes=probes,
                        mode=mode, seed=seed, future=Future(),
                        t_submit=time.perf_counter())
        with self._lock:
            self._queries_inflight += 1
        req.future.add_done_callback(self._query_resolved)
        self._query_q.put(req)
        return self._done(ns, req.future)

    def _query_resolved(self, _future) -> None:
        with self._lock:
            self._queries_inflight -= 1

    def _queries_waiting(self) -> bool:
        """Any query submitted but not yet resolved — the ingest lane's
        cue to cede the core between build programs."""
        return self._queries_inflight > 0

    def insert(self, batch, *, tenant: str = "default") -> Future:
        """Submit an insert to the ingest lane; resolves to the service."""
        ns = self._ns(tenant)
        self._check_open()
        self._shed_unless_serving(ns)
        n = jax.tree.leaves(batch)[0].shape[0]
        self._admit(ns, new_items=n)
        return self._submit_ingest(ns, lambda: ns.service.insert(batch))

    def delete(self, ids, *, tenant: str = "default") -> Future:
        """Submit a delete to the ingest lane; resolves to the count."""
        ns = self._ns(tenant)
        self._check_open()
        self._shed_unless_serving(ns)
        self._admit(ns)
        return self._submit_ingest(ns, lambda: ns.service.delete(ids))

    def compact(self, tenant: str = "default") -> Future:
        """Queue a compaction on the ingest lane: the replacement store is
        built there (off the query path) and published as a pointer flip —
        queries keep flowing the whole time."""
        ns = self._ns(tenant)
        self._check_open()
        self._shed_unless_serving(ns)
        self._admit(ns)
        return self._submit_ingest(
            ns, lambda: ns.service.apply_swap(ns.service.prepare_compact()))

    def rebalance(self, tenant: str = "default") -> Future:
        """Queue a rebalance (sharded tenants) — same prepare/flip split."""
        ns = self._ns(tenant)
        self._check_open()
        self._shed_unless_serving(ns)
        self._admit(ns)
        return self._submit_ingest(
            ns,
            lambda: ns.service.apply_swap(ns.service.prepare_rebalance()))

    def _submit_ingest(self, ns: _Namespace, fn) -> Future:
        req = _IngestReq(ns=ns, fn=fn, future=Future(),
                         t_submit=time.perf_counter())
        self._ingest_q.put(req)
        return self._done(ns, req.future)

    def flush(self, timeout: float | None = None) -> None:
        """Block until everything submitted so far has executed. Raises
        ``TimeoutError`` when the lanes have not drained within
        ``timeout`` seconds (one shared deadline across both) — a stalled
        lane must never read as a drained one."""
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        barriers = []
        for q in (self._query_q, self._ingest_q):
            f: Future = Future()
            q.put((lambda: None, f))
            barriers.append(f)
        for f in barriers:
            left = (None if deadline is None
                    else max(deadline - time.perf_counter(), 0.0))
            try:
                f.result(timeout=left)
            except _FutureTimeout:
                raise TimeoutError(
                    f"flush timed out after {timeout}s with work still "
                    "queued on the lanes") from None

    def close(self) -> None:
        """Drain both lanes and stop their threads."""
        if self._closed:
            return
        self._closed = True
        self._query_q.put(_STOP)
        self._ingest_q.put(_STOP)
        self._query_thread.join()
        self._ingest_thread.join()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("scheduler is closed")

    def __enter__(self) -> "ServingScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- lanes --------------------------------------------------------------

    def _query_loop(self) -> None:
        stop = False
        while not stop:
            item = self._query_q.get()
            if item is _STOP:
                return
            if isinstance(item, tuple):     # flush barrier
                item[1].set_result(None)
                continue
            batch, deferred = [item], []
            deadline = item.t_submit + self.deadline_s
            flush_kind = "deadline"
            while len(batch) < self.max_batch:
                try:
                    # drain whatever is already queued without waiting —
                    # when the lane falls behind, the backlog coalesces
                    # into one batch even though the oldest request's
                    # deadline has long passed
                    nxt = self._query_q.get_nowait()
                except queue_lib.Empty:
                    timeout = deadline - time.perf_counter()
                    if timeout <= 0:
                        break
                    try:
                        nxt = self._query_q.get(timeout=timeout)
                    except queue_lib.Empty:
                        break
                if nxt is _STOP:
                    stop = True
                    break
                if isinstance(nxt, tuple):  # barrier: resolve after batch
                    deferred.append(nxt[1])
                    continue
                batch.append(nxt)
            else:
                flush_kind = "size"
            self._run_batch(batch, flush_kind)
            for f in deferred:
                f.set_result(None)

    def _run_batch(self, batch: list[_QueryReq], flush_kind: str) -> None:
        groups: dict[Any, list[_QueryReq]] = {}
        for req in batch:
            groups.setdefault(req.group_key, []).append(req)
        self.stats.requests += len(batch)
        self.stats.batches += len(groups)
        if flush_kind == "size":
            self.stats.size_flushes += 1
        else:
            self.stats.deadline_flushes += 1
        for reqs in groups.values():
            self._run_group(reqs)

    def _expire(self, req) -> None:
        self.stats.timeouts += 1
        req.ns.service.stats.timeouts += 1
        req.future.set_exception(RequestTimeout(
            f"request queued for more than "
            f"{self.timeout_s * 1e3:g} ms (request_timeout_ms)"))

    def _run_group(self, reqs: list[_QueryReq]) -> None:
        if self.timeout_s is not None:
            now, live = time.perf_counter(), []
            for req in reqs:
                if now - req.t_submit > self.timeout_s:
                    self._expire(req)
                else:
                    live.append(req)
            reqs = live
            if not reqs:
                return
        head = reqs[0]
        try:
            b = len(reqs)
            padded = 1 << (b - 1).bit_length()  # stable program shapes
            stacked = jax.tree.map(
                lambda *xs: np.stack([np.asarray(x) for x in xs]),
                *[r.x for r in reqs])
            if padded > b:
                stacked = jax.tree.map(
                    lambda a: np.concatenate(
                        [a, np.repeat(a[:1], padded - b, axis=0)]),
                    stacked)
            ids, scores, n_cand = head.ns.service.query_arrays(
                stacked, topk=head.topk, probes=head.probes, mode=head.mode,
                seed=head.seed, stat_rows=b)
            for i, req in enumerate(reqs):
                req.future.set_result(
                    (ids[i], scores[i], int(n_cand[i])))
        except BaseException as exc:  # resolve every waiter, never wedge
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(exc)

    def _ingest_loop(self) -> None:
        while True:
            item = self._ingest_q.get()
            if item is _STOP:
                return
            if isinstance(item, tuple):     # flush barrier
                item[1].set_result(None)
                continue
            self._run_ingest(item)

    def _run_ingest(self, req: _IngestReq) -> None:
        if (self.timeout_s is not None
                and time.perf_counter() - req.t_submit > self.timeout_s):
            self._expire(req)
            return
        attempt = 0
        while True:
            try:
                # mutations on this lane run cooperatively: the throttled
                # store-build loops yield the core between bounded
                # programs — but only while a query is actually in flight
                # — so a pending query-lane batch submits ahead of the
                # next build chunk and runs with most of the core instead
                # of convoying behind the whole build (decisive on
                # few-core hosts, where the lane thread otherwise keeps
                # the CPU after every block)
                with segments.cooperative_build(busy=self._queries_waiting):
                    req.future.set_result(req.fn())
                return
            except TransientIOError as exc:
                # retryable IO on the durability plane: nothing was
                # committed, so re-running the mutation is safe
                if attempt >= self.ingest_retries:
                    self._record_error(req.ns, exc)
                    self._set_health(req.ns, "degraded")
                    req.future.set_exception(exc)
                    return
                attempt += 1
                self.stats.retries += 1
                req.ns.service.stats.retries += 1
                time.sleep(min(self.backoff_s * 2 ** (attempt - 1), 1.0))
            except BaseException as exc:
                # non-retryable: record it on the tenant so a dropped
                # future can't swallow a failed mutation; a simulated
                # crash leaves memory state untrusted -> degrade
                self._record_error(req.ns, exc)
                if isinstance(exc, InjectedCrash):
                    self._set_health(req.ns, "degraded")
                req.future.set_exception(exc)
                return
