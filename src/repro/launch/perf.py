import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (§Perf): run named change-experiments against the
three chosen cells, re-lower, re-derive roofline terms, and log
hypothesis -> before -> after into experiments/perf/<cell>__<name>.json.

    python -m repro.launch.perf --cell mistral_train --exp remat_dots
    python -m repro.launch.perf --list
"""

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.launch.dryrun import lower_cell, roofline_costs
from repro.launch.roofline import analyse


# (arch, shape, config_fn, rule_extra) per experiment; "baseline" = as swept.
def _mistral(**kw):
    return dataclasses.replace(get_config("mistral-large-123b", "full"), **kw)


def _llama4(**kw):
    return dataclasses.replace(get_config("llama4-maverick-400b-a17b", "full"), **kw)


def _phi3long(**kw):
    return dataclasses.replace(get_config("phi3-mini-3.8b", "long"), **kw)


CELLS = {
    "mistral_train": ("mistral-large-123b", "train_4k", _mistral),
    "llama4_train": ("llama4-maverick-400b-a17b", "train_4k", _llama4),
    "phi3_long": ("phi3-mini-3.8b", "long_500k", _phi3long),
}

# experiment name -> (hypothesis, cfg_kwargs, rule_extra)
EXPERIMENTS = {
    "baseline": ("paper-faithful baseline as swept", {}, None),
    # --- remat family (compute term: recompute flops) ---
    "remat_dots": ("saving matmul outputs (dots policy) removes the extra "
                   "remat forward: HLO flops should drop ~25% at the cost of "
                   "saved-dot memory", {"remat_policy": "dots"}, None),
    "remat_none": ("no remat at all: lowest flops, highest activation memory",
                   {"remat_policy": "none"}, None),
    # --- sharding family (collective term) ---
    "no_fsdp": ("replicating params over data (no FSDP) removes per-layer "
                "param all-gathers but blows up memory: collective term "
                "down, HBM up", {}, {"fsdp_embed": None}),
    "no_seqshard": ("keeping saved activations replicated over model (no SP) "
                    "removes per-layer seq all-gathers at activation-memory "
                    "cost", {}, {"act_seq": None}),
    # --- MoE family ---
    "capacity_1.0": ("capacity factor 1.25 -> 1.0 cuts dispatch buffer and "
                     "expert matmul flops ~20% (more drops)",
                     {"capacity_factor": 1.0}, None),
    "capacity_2.0": ("capacity factor 2.0: fewer drops, +60% expert flops",
                     {"capacity_factor": 2.0}, None),
    # --- LSH attention family (the paper's technique) ---
    "cand_1024": ("half the candidate set: gather+attn flops halve, "
                  "recall of attention mass drops (quality lever)",
                  {"lsh_candidates": 1024}, None),
    "cand_4096": ("double candidates: 2x attention flops at 500k",
                  {"lsh_candidates": 4096}, None),
    "hashes_16": ("16 hash bits -> 65536 buckets: sharper buckets, "
                  "same asymptotic cost (code compute x2)",
                  {"lsh_num_hashes": 16}, None),
    # --- dtype/layout ---
    "f32_params": ("f32 params double param/collective bytes (negative "
                   "control)", {"dtype": "float32"}, None),
}


def run_experiment(cell: str, exp: str, out_dir: str) -> dict:
    arch, shape, cfg_fn = CELLS[cell]
    hypothesis, kw, rule_extra = EXPERIMENTS[exp]
    cfg = cfg_fn(**kw)
    rec = lower_cell(arch, shape, False, config_variant=cfg,
                     rule_extra=rule_extra)
    if rec["status"] == "ok":
        rec["cost_true"] = roofline_costs(arch, shape, cfg, False,
                                          rule_extra=rule_extra)
        rec["roofline"] = analyse(rec)
    rec["experiment"] = exp
    rec["hypothesis"] = hypothesis
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{cell}__{exp}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    print(f"[perf] {cell} / {exp}: compute={r.get('compute_s', 0):.3e}s "
          f"memory={r.get('memory_s', 0):.3e}s "
          f"collective={r.get('collective_s', 0):.3e}s "
          f"bottleneck={r.get('bottleneck')} "
          f"hbm={r.get('mem_gib_per_device', 0):.1f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS))
    ap.add_argument("--exp", choices=list(EXPERIMENTS))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    if args.list:
        for c in CELLS:
            print(c, "->", ", ".join(EXPERIMENTS))
        return
    run_experiment(args.cell, args.exp, args.out)


if __name__ == "__main__":
    main()
