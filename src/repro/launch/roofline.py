"""Roofline analysis over the dry-run records.

Per (arch x shape) single-pod cell:
    compute    = HLO_FLOPs_per_chip / 197e12            [s]
    memory     = HLO_bytes_per_chip / 819e9             [s]
    collective = sum_k ring_factor_k * bytes_k / 50e9   [s]
with ring factors: all-reduce 2x (reduce + broadcast ring), all-gather /
reduce-scatter / all-to-all / collective-permute 1x of the recorded result
bytes. MODEL_FLOPS = 6 N D (train) or 2 N D (inference), N = active params.

``kind == "lsh_query"`` records (the sharded ANN index cell from
``dryrun --lsh-index``) share the compute/memory/collective terms but have
no model-FLOPs notion — their MODEL/HLO and MFU columns render as "—".
Each lsh record also embeds AOT profiles of its sub-programs (the
base+delta ``delta_probe``, the T-wide ``multiprobe_program``, the
end-to-end ``fused_query_program`` (hash -> probe -> re-rank -> top-k over
base + delta at T probes), the fused ``hash_program``, and the shard-local
``insert_program`` /
``compact_program`` mutation programs — kind ``lsh_mutation``);
``expand()`` turns them into their own table rows.

Emits the EXPERIMENTS.md §Roofline table + per-cell bottleneck statements.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # TPU v5e bf16 / chip
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s/link ICI
RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def analyse(rec: dict) -> dict:
    ct = rec.get("cost_true")
    flops = ct["flops"] if ct else rec["cost"]["flops_per_device"]
    bytes_ = ct["bytes"] if ct else rec["cost"]["bytes_accessed_per_device"]
    coll_bytes = 0.0
    coll_detail = {}
    for kind, fac in RING_FACTOR.items():
        b = (ct[f"coll.{kind}.bytes"] if ct
             else rec["collectives"][kind]["bytes"])
        coll_detail[kind] = b
        coll_bytes += fac * b
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_ / HBM_BW
    coll_t = coll_bytes / LINK_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    bottleneck = max(terms, key=terms.get)
    step_t = max(terms.values())

    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "bottleneck": bottleneck,
        "model_flops_per_chip": None,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": None,
        "roofline_mfu": None,
        "mem_gib_per_device": rec["memory"]["peak_per_device_bytes"] / 2**30,
        "collective_bytes": coll_detail,
        "fallbacks": rec.get("sharding_fallbacks", []),
    }
    if rec["kind"] in ("lsh_query", "lsh_mutation"):
        # ANN index query / shard-local mutation program: roofline terms
        # apply, model FLOPs do not.
        return out

    n_chips = rec["n_chips"]
    n_active = rec["n_active_params"]
    if rec["kind"] == "train":
        tokens = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        model_flops = 6.0 * n_active * tokens
    elif rec["kind"] == "prefill":
        tokens = 32 * 32768
        model_flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence in the batch
        tokens = {"decode_32k": 128, "long_500k": 1}.get(rec["shape"], 0)
        model_flops = 2.0 * n_active * tokens
    model_flops_per_chip = model_flops / n_chips
    out["model_flops_per_chip"] = model_flops_per_chip
    out["useful_flops_ratio"] = model_flops_per_chip / max(flops, 1.0)
    out["roofline_mfu"] = ((model_flops_per_chip / step_t) / PEAK_FLOPS
                           if step_t > 0 else 0.0)
    return out


def fmt_cell(v, spec: str, scale: float = 1.0, suffix: str = "") -> str:
    """Table cell: em-dash when the field doesn't apply to the record kind."""
    return "—" if v is None else f"{v * scale:{spec}}{suffix}"


# Sub-programs an lsh_query record embeds: (key, kind of the synthetic row)
LSH_SUBPROGRAMS = (("delta_probe", "lsh_query"),
                   ("multiprobe_program", "lsh_query"),
                   ("fused_query_program", "lsh_query"),
                   ("hash_program", "lsh_query"),
                   ("insert_program", "lsh_mutation"),
                   ("compact_program", "lsh_mutation"),
                   ("swap_build_program", "lsh_mutation"))


def expand(rec: dict) -> list[dict]:
    """A dry-run record plus synthetic records for its embedded LSH
    sub-programs, so every AOT-profiled program gets its own roofline row.
    Non-LSH records pass through unchanged."""
    out = [rec]
    if rec.get("kind") != "lsh_query":
        return out
    for name, kind in LSH_SUBPROGRAMS:
        sub = rec.get(name)
        if not isinstance(sub, dict) or "cost" not in sub:
            continue
        out.append({
            "arch": f"{rec['arch']}:{name}",
            "shape": rec["shape"],
            "mesh": rec["mesh"],
            "n_chips": rec.get("n_chips"),
            "kind": kind,
            "compile_seconds": sub.get("compile_seconds"),
            "memory": sub["memory"],
            "cost": sub["cost"],
            "collectives": sub["collectives"],
            "sharding_fallbacks": rec.get("sharding_fallbacks", []),
        })
    return out


def load_records(directory: str, mesh: str = "16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def table(directory: str, mesh: str = "16x16") -> str:
    rows = [analyse(r) for rec in load_records(directory, mesh)
            for r in expand(rec)]
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| MODEL/HLO flops | roofline MFU | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['bottleneck']}** "
            f"| {fmt_cell(r['useful_flops_ratio'], '.2f')} "
            f"| {fmt_cell(r['roofline_mfu'], '.1f', 100, '%')} "
            f"| {r['mem_gib_per_device']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        rows = [analyse(r) for rec in load_records(args.dir, args.mesh)
                for r in expand(rec)]
        print(json.dumps(rows, indent=1))
    else:
        print(table(args.dir, args.mesh))


if __name__ == "__main__":
    main()
