"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch mamba2-130m --smoke --steps 200 --batch 8 --seq 256 \
        --ckpt-dir /tmp/run1 [--resume] [--grad-accum 2] [--compress]

Single-process: uses whatever devices exist (a 1x1 mesh on this CPU
container; the production mesh path is exercised by launch/dryrun.py).
Fault tolerance: atomic async checkpoints + auto-resume + deterministic
skip-ahead data (see training/fault_tolerance.py).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig, batch_at
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_local_mesh
from repro.training import optimizer as opt_lib
from repro.training.compression import CompressionConfig
from repro.training.fault_tolerance import (FailureInjector, StepWatchdog,
                                            run_training)
from repro.training.train_loop import TrainConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="tensorized-sketch gradient compression (the paper)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT testing)")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, "smoke" if args.smoke else "full")
    tc = TrainConfig(
        adamw=opt_lib.AdamWConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                                  decay_steps=max(args.steps, 10)),
        grad_accum=args.grad_accum,
        compression=CompressionConfig(min_size=4096) if args.compress else None,
    )
    dc = DataConfig(batch_size=args.batch, seq_len=args.seq, seed=args.seed)
    mesh = make_local_mesh()

    with axis_rules(mesh):
        state, sketch = init_state(cfg, tc, jax.random.PRNGKey(args.seed))
        step_fn = jax.jit(make_train_step(cfg, tc, sketch=sketch),
                          donate_argnums=0)
        watchdog = StepWatchdog()
        injector = FailureInjector(fail_at_step=args.fail_at)
        state, history = run_training(
            train_step=step_fn,
            init_state_fn=lambda: state,
            batch_fn=lambda step: batch_at(dc, cfg, step),
            num_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            injector=injector,
            watchdog=watchdog)

    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({len(watchdog.straggler_steps)} straggler steps)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    return history


if __name__ == "__main__":
    main()
