"""Elastic scaling: reshard a training state between mesh sizes.

When a pod shrinks (lost slice) or grows, the controller rebuilds the mesh
and calls `reshard`: every leaf is re-placed under the NEW mesh's
NamedSharding resolved from the same logical axes — jax moves the shards
(device_put handles arbitrary resharding, including across different axis
factorizations). The divisibility fallback in the rule resolver means a
param that can no longer shard evenly on the smaller mesh degrades to
replication instead of failing, so scale-down always succeeds.

Checkpoint-based elasticity (restore a 512-chip checkpoint onto 256 chips)
follows the same path: checkpoints are stored unsharded per-leaf (see
training/checkpoint.py), so restore + reshard = elastic restart.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import axis_rules, tree_shardings


def reshard(tree, axes_tree, new_mesh, overrides=None):
    """Re-place every leaf of `tree` under `new_mesh` using logical axes."""
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with axis_rules(new_mesh, overrides):
        shardings = tree_shardings(axes_tree, sds)
    return jax.tree.map(jax.device_put, tree, shardings)


def elastic_restore(ckpt_dir: str, like, axes_tree, new_mesh, overrides=None):
    """Restore the latest checkpoint and shard it for the (new) mesh."""
    from repro.training import checkpoint as ckpt_lib
    tree, step, meta = ckpt_lib.restore_latest(ckpt_dir, like)
    if tree is None:
        return None, None
    return reshard(tree, axes_tree, new_mesh, overrides), step
