"""Production meshes. Functions, not module constants — importing this
module never touches jax device state."""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` (and
    jax.sharding.AxisType) only exist on newer jax; older versions build
    the same Auto-mode mesh without the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips (pod, data, model); the pod axis carries pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return _mesh((data, model), ("data", "model"))
