import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell
against the production mesh with ShapeDtypeStruct inputs (no allocation),
then record memory analysis, FLOP/byte cost analysis and the collective
schedule for the roofline report.

The two XLA_FLAGS lines above MUST run before any other import (jax locks
the device count at first init); do not set this flag globally.

Usage:
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS
from repro.distributed.sharding import axis_rules, named_sharding, tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, batch_specs, cache_specs,
                                config_for_cell, rule_overrides)
from repro.models import params as params_lib
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.train_loop import (TrainConfig, abstract_state,
                                       dryrun_train_config, make_train_step,
                                       state_axes)

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _buffer_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum collective operand bytes from the compiled (per-partition) HLO.

    Each collective instruction line carries its result shape; for
    all-gather the moved bytes ~= result size ((n-1)/n of it crosses links),
    for all-reduce ~= 2x operand size (ring reduce+broadcast), for
    reduce-scatter ~= operand (= result x n) size. We record raw result
    bytes per kind and apply the ring factors in the roofline step.
    """
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?)([a-z0-9]+)\[([\d,]*)\]", stripped)
        if not m:
            continue
        kind = next((k for k in _COLLECTIVES if f" {k}(" in stripped
                     or f"{k}-start(" in stripped or f"{k}-done(" in stripped), None)
        if kind is None:
            continue
        if f"{kind}-done(" in stripped:
            continue  # counted at -start
        # sum every buffer in the (possibly tuple) result
        total = 0
        for dt, dims in _SHAPE_RE.findall(stripped.split(" = ", 1)[1].split("(", 1)[0] + "("):
            total += _buffer_bytes(dt, dims)
        if total == 0:
            for dt, dims in _SHAPE_RE.findall(stripped):
                total += _buffer_bytes(dt, dims)
                break
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += total
    return stats


def _n_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def _constrain(tree, axes_tree):
    def leaf_is_axes(a):
        return isinstance(a, tuple) and all(isinstance(e, (str, type(None)))
                                            for e in a)
    return jax.tree.map(
        lambda a, x: jax.lax.with_sharding_constraint(
            x, named_sharding(a, x.shape)),
        axes_tree, tree, is_leaf=leaf_is_axes)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               config_variant=None, rule_extra=None) -> dict:
    """Lower + compile one cell; returns the roofline-facing record."""
    cell = SHAPES[shape_name]
    cfg = config_variant or config_for_cell(arch, shape_name)
    if cfg is None:
        return {"status": "skipped",
                "reason": "pure full-attention arch at 500k (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = _n_chips(mesh)
    t0 = time.time()

    overrides = rule_overrides(cfg, mesh)
    if rule_extra:
        overrides.update(rule_extra)
    with axis_rules(mesh, overrides) as ctx:
        if cell.kind == "train":
            tc = dryrun_train_config(cfg)
            state_sds = abstract_state(cfg, tc)
            st_axes = state_axes(cfg)
            state_sh = tree_shardings(st_axes, state_sds)
            b_sds, b_axes = batch_specs(cfg, cell, with_labels=True)
            b_sh = tree_shardings(b_axes, b_sds)
            inner = make_train_step(cfg, tc)

            def step(state, batch):
                new_state, metrics = inner(state, batch)
                return _constrain(new_state, st_axes), metrics

            jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                             donate_argnums=0)
            lowered = jitted.lower(state_sds, b_sds)
        elif cell.kind == "prefill":
            p_sds = params_lib.abstract_params(cfg)
            p_axes = params_lib.param_axes(cfg)
            p_sh = tree_shardings(p_axes, p_sds)
            b_sds, b_axes = batch_specs(cfg, cell, with_labels=False)
            b_sh = tree_shardings(b_axes, b_sds)
            from repro.models import transformer as T
            c_axes = T.cache_axes(cfg)
            inner = make_prefill_step(cfg, max_len=cell.seq)

            def step(params, batch):
                logits, cache = inner(params, batch)
                return logits, _constrain(cache, c_axes)

            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_sds, b_sds)
        else:  # decode
            p_sds = params_lib.abstract_params(cfg)
            p_axes = params_lib.param_axes(cfg)
            p_sh = tree_shardings(p_axes, p_sds)
            c_sds, c_axes = cache_specs(cfg, cell)
            c_sh = tree_shardings(c_axes, c_sds)
            tok_sds = jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32)
            tok_sh = named_sharding(("batch", None), tok_sds.shape)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            pos_sh = named_sharding((), ())
            inner = make_serve_step(cfg)

            def step(params, cache, token, cur_pos):
                logits, new_cache = inner(params, cache, token, cur_pos)
                return logits, _constrain(new_cache, c_axes)

            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                             donate_argnums=1)
            lowered = jitted.lower(p_sds, c_sds, tok_sds, pos_sds)

        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        colls = collective_stats(hlo)
        fallbacks = sorted({(f[0], f[1], "/".join(f[2])) for f in ctx.fallbacks})

    return {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": cell.kind,
        "config_name": cfg.name,
        "n_params": params_lib.count_params(cfg),
        "n_active_params": params_lib.count_active_params(cfg),
        "compile_seconds": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        },
        "collectives": colls,
        "sharding_fallbacks": fallbacks,
    }


def _analyze(compiled, t0) -> dict:
    """memory / FLOP / collective record of one AOT-compiled program."""
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    return {
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        },
        "collectives": collective_stats(compiled.as_text()),
    }


def lower_lsh_index_cell(multi_pod: bool = False, *, corpus_n: int = 1 << 18,
                         dims: tuple = (8, 8, 8), batch: int = 1024,
                         topk: int = 10, num_codes: int = 4,
                         num_tables: int = 8, bucket_cap: int = 64,
                         delta_n: int = 4096, delta_cap: int = 64,
                         probes: int = 8) -> dict:
    """AOT-lower + compile the sharded LSH index query + mutation programs.

    One corpus shard per device along the mesh's data axis (the
    ``lsh_shard`` rule), segment-store arrays (sorted keys, permutations,
    liveness/effective-id lookups, corpus slices) sharded with the same
    NamedSharding machinery as the model cells, queries replicated —
    records the memory / FLOP / collective profile of serving one query
    batch so the roofline report can account the ANN workload next to the
    model workloads. Four programs are compiled: the compacted store (base
    segment only), the post-insert store (base + one sharded
    ``delta_n``-item delta slab probed inside the same shard_map body —
    ``delta_probe``), the query-directed multi-probe query at T=``probes``
    candidate buckets per table (``multiprobe_program`` — prices the key
    expansion + the T-times-wider probe windows of the (L, T) trade-off),
    the fused query-to-candidates program over base + delta at T=``probes``
    (``fused_query_program`` — the end-to-end hash -> probe -> re-rank ->
    top-k program production serves post-insert), the fused hash pipeline
    (``hash_program``, with the resolved block_b/block_t grid tiling), the
    two shard-local
    mutation programs — the routed slab scatter + sort behind ``insert``
    (``insert_program``, hash included) and the per-shard survivor fold
    behind ``compact()`` (``compact_program``) — and the double-buffered
    swap's shadow build (``swap_build_program``): the global sequence-order
    gather + contiguous re-partition + per-shard re-sort behind
    ``prepare_rebalance()``, the one mutation program that pays cross-shard
    collectives (it runs off the query path; the ``apply_swap`` flip itself
    compiles nothing).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import segments
    from repro.core.lsh import make_family
    from repro.distributed import index_sharding

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = _n_chips(mesh)
    t0 = time.time()
    with axis_rules(mesh) as ctx:
        shards = ctx.axis_size(ctx.rules["lsh_shard"])
        shard_mesh, shard_axis = index_sharding.resolve_mesh(shards)
        assert shard_axis is not None, "lsh_shard rule must resolve here"
        n_s = -(-corpus_n // shards)
        d_ns = max(-(-delta_n // shards), 1)
        l, k = num_tables, num_codes
        fam_sds = jax.eval_shape(
            lambda key: make_family(key, "cp-e2lsh", dims, num_codes=k,
                                    num_tables=l, rank=4),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        sds = jax.ShapeDtypeStruct

        def seg_sds(s, m):
            """(corpus, sorted_keys, perm, live, eff, win) SDS tuple of one
            sharded segment. This cell prices an explicit-bucket_cap store,
            and those keep live-window lookups (live_rank, live_pos) and
            run the live-window probe — profile the program that actually
            ships, lookups included."""
            return (sds((s, m) + tuple(dims), jnp.float32),
                    sds((s, l, m), jnp.uint32),
                    sds((s, l, m), jnp.int32),
                    sds((s, m + 1), jnp.bool_),
                    sds((s, m), jnp.int32),
                    (sds((s, l, m + 1), jnp.int32),
                     sds((s, l, m), jnp.int32)))

        base_sds = seg_sds(shards, n_s)
        delta_sds = seg_sds(shards, d_ns)   # routed slab: sharded like base
        mults_sds = sds((k,), jnp.uint32)
        q_sds = sds((batch,) + tuple(dims), jnp.float32)

        shard_of = lambda s: named_sharding(
            ("lsh_shard",) + (None,) * (len(s.shape) - 1), s.shape)
        rep = NamedSharding(mesh, P())
        fam_sh = jax.tree.map(lambda _: rep, fam_sds)
        seg_sh = lambda t: jax.tree.map(shard_of, t)

        def compile_one(deltas_sds, delta_caps, t=1):
            def step(fam, base, deltas, mults, queries):
                return index_sharding.shard_map_query(
                    fam, base, deltas, mults, queries,
                    metric="euclidean", topk=topk, cap=bucket_cap,
                    delta_caps=delta_caps, mesh=shard_mesh, axis=shard_axis,
                    probes=t)

            deltas_sh = tuple(seg_sh(d) for d in deltas_sds)
            jitted = jax.jit(step, in_shardings=(
                fam_sh, seg_sh(base_sds), deltas_sh, rep, rep))
            return jitted.lower(fam_sds, base_sds, deltas_sds, mults_sds,
                                q_sds).compile()

        base_rec = _analyze(compile_one((), ()), t0)
        t1 = time.time()
        delta_rec = _analyze(
            compile_one((delta_sds,), (min(delta_cap, d_ns),)), t1)

        # the multi-probe query on the compacted store: the T-wide key
        # expansion (repro.core.probing) + T probe windows per table
        t_mp = time.time()
        multiprobe_rec = _analyze(compile_one((), (), t=probes), t_mp)

        # the fused query-to-candidates program: hash -> multi-probe key
        # expansion -> probe windows -> exact re-rank -> packed top-k over
        # base + one delta slab at T=probes — the end-to-end program
        # production serves between an insert and the next compaction
        t_fq = time.time()
        fused_query_rec = _analyze(
            compile_one((delta_sds,), (min(delta_cap, d_ns),), t=probes),
            t_fq)

        # the fused hash program (projection -> discretize -> bucket keys,
        # one jit program; the build/insert/query-hash hot path) profiled
        # alongside the probe programs
        t2 = time.time()
        hash_jit = jax.jit(lambda fam, mults, batch:
                           fam.hash_keys(batch, mults),
                           in_shardings=(fam_sh, rep, rep))
        hash_rec = _analyze(
            hash_jit.lower(fam_sds, mults_sds, q_sds).compile(), t2)
        from repro.kernels import ops as _kops
        hash_block_b, hash_block_t = _kops.hash_blocks("cp", batch, l)

        # the shard-local mutation programs: insert = fused batch hash +
        # routed slab scatter + per-shard sort; compact = per-shard
        # survivor gather + re-sort over base + one delta slab (stored
        # keys only — compaction never re-hashes)
        t3 = time.time()
        ins_batch_sds = sds((delta_n,) + tuple(dims), jnp.float32)
        ins_idx_sds = sds((shards * d_ns,), jnp.int32)
        counts_sds = sds((shards,), jnp.int32)

        def insert_step(fam, mults, ins_batch, idx, counts):
            keys = fam.hash_keys(ins_batch, mults)
            return segments._slab_scatter_sort(
                keys, ins_batch, idx, counts, shards=shards,
                shard_size=d_ns)

        insert_rec = _analyze(
            jax.jit(insert_step, in_shardings=(fam_sh, rep, rep, rep, rep))
            .lower(fam_sds, mults_sds, ins_batch_sds, ins_idx_sds,
                   counts_sds).compile(), t3)

        t4 = time.time()
        w = n_s + d_ns                      # base + one delta slab folded
        keys_cat_sds = sds((shards, w, l), jnp.uint32)
        corpus_cat_sds = sds((shards, w) + tuple(dims), jnp.float32)
        fold_idx_sds = sds((shards, w), jnp.int32)

        def compact_step(keys_cat, corpus_cat, idx, counts):
            return segments._slab_gather_sort(keys_cat, corpus_cat, idx,
                                              counts, shard_size=w)

        compact_rec = _analyze(
            jax.jit(compact_step,
                    in_shardings=(shard_of(keys_cat_sds),
                                  shard_of(corpus_cat_sds),
                                  shard_of(fold_idx_sds), rep))
            .lower(keys_cat_sds, corpus_cat_sds, fold_idx_sds,
                   counts_sds).compile(), t4)

        # the double-buffered swap's shadow build (the rebalance prepare):
        # gather every live item from the sharded base + delta slabs in
        # sequence order — the one deliberately global gather in the
        # mutation plane, so this program carries the cross-shard
        # collectives compact_program deliberately avoids — then
        # re-partition contiguously and re-sort each new shard. Runs off
        # the query path while the live store keeps serving; apply_swap
        # afterwards is a host pointer flip with no program at all.
        t5 = time.time()
        live_n = corpus_n + delta_n
        new_ns = -(-live_n // shards)
        swap_idx_sds = sds((shards * new_ns,), jnp.int32)

        def swap_build_step(keys_cat, corpus_cat, flat_idx):
            s, w_, l_ = keys_cat.shape
            keys_pad = jnp.concatenate(
                [keys_cat.reshape(s * w_, l_),
                 jnp.zeros((1, l_), jnp.uint32)])
            keys_g = keys_pad[flat_idx].reshape(shards, new_ns, l_)
            corpus_g = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a.reshape((s * w_,) + a.shape[2:]),
                     jnp.zeros((1,) + a.shape[2:], a.dtype)])[flat_idx]
                .reshape((shards, new_ns) + a.shape[2:]), corpus_cat)
            perm, sorted_keys, max_run = segments._sort_tables(
                keys_g.transpose(0, 2, 1))
            return keys_g, sorted_keys, perm, corpus_g, max_run

        swap_rec = _analyze(
            jax.jit(swap_build_step,
                    in_shardings=(shard_of(keys_cat_sds),
                                  shard_of(corpus_cat_sds), rep))
            .lower(keys_cat_sds, corpus_cat_sds, swap_idx_sds).compile(), t5)
        fallbacks = sorted({(f[0], f[1], "/".join(f[2]))
                            for f in ctx.fallbacks})

    return {
        "status": "ok",
        "arch": "lsh-index",
        "shape": f"n{corpus_n}_b{batch}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": "lsh_query",
        "shards": shards,
        "shard_axis": shard_axis,
        "corpus_n": corpus_n,
        "batch": batch,
        "bucket_cap": bucket_cap,
        **base_rec,
        "delta_probe": {"delta_n": delta_n, "delta_cap": delta_cap,
                        **delta_rec},
        "multiprobe_program": {"probes": probes, **multiprobe_rec},
        "fused_query_program": {"batch": batch, "probes": probes,
                                "delta_n": delta_n,
                                "probe_backend":
                                    segments.resolved_probe_backend("auto"),
                                **fused_query_rec},
        # the backend that actually executes for this cell's (dense) corpus:
        # CP/TT projections over dense inputs have no kernel, so the pallas
        # backend serves them through XLA — report the executed path, not
        # the resolved knob (they differ under REPRO_HASH_BACKEND=pallas)
        "hash_program": {"batch": batch,
                         "backend": ("pallas" if fam_sds._use_pallas(q_sds)
                                     else "xla"),
                         # grid tiling the pallas backend would run with at
                         # this batch (kernels/ops.hash_blocks resolution of
                         # the documented per-format-pair defaults)
                         "block_b": hash_block_b, "block_t": hash_block_t,
                         **hash_rec},
        "insert_program": {"insert_n": delta_n, "slab_size": d_ns,
                           **insert_rec},
        "compact_program": {"folded_slots_per_shard": w, **compact_rec},
        "swap_build_program": {"live_n": corpus_n + delta_n,
                               "new_shard_size":
                                   -(-(corpus_n + delta_n) // shards),
                               **swap_rec},
        "sharding_fallbacks": fallbacks,
    }


# ---------------------------------------------------------------------------
# Roofline-exact costs ("scan calculus")
#
# XLA's cost analysis counts a while-loop body ONCE regardless of trip count,
# so the scan-over-layers compile under-reports FLOPs/collectives. We recover
# exact per-step numbers from small *unrolled* auxiliary compiles:
#     total(L) = outer + L * body            (homogeneous stacks)
# with body = cost(L=2) - cost(L=1) from fully-unrolled variants (each layer
# appears literally in the HLO). Whisper (enc+dec scans) and the hybrid arch
# (nested group scan) get the analogous 3-variant linear solves. Memory is
# taken from the full-depth scan compile (buffer assignment is exact there).
# ---------------------------------------------------------------------------

import dataclasses as _dc


def _metric_vec(rec: dict) -> dict[str, float]:
    v = {"flops": rec["cost"]["flops_per_device"],
         "bytes": rec["cost"]["bytes_accessed_per_device"]}
    for k, st in rec["collectives"].items():
        v[f"coll.{k}.bytes"] = float(st["bytes"])
        v[f"coll.{k}.count"] = float(st["count"])
    return v


def _lin(*terms) -> dict[str, float]:
    """terms: (coef, vec) pairs -> coef-weighted sum, floored at 0."""
    keys = terms[0][1].keys()
    return {k: max(0.0, sum(c * v[k] for c, v in terms)) for k in keys}


def roofline_costs(arch: str, shape_name: str, cfg, multi_pod: bool,
                   rule_extra=None) -> dict:
    """Exact per-step cost vector via unrolled aux compiles."""
    rep = lambda **kw: _dc.replace(cfg, scan_unroll=True, **kw)
    if cfg.block == "hybrid":
        # total = outer + G*(P*mamba + shared)
        va = rep(n_layers=1, shared_attn_period=1)   # outer + m + s
        vb = rep(n_layers=2, shared_attn_period=2)   # outer + 2m + s
        vc = rep(n_layers=2, shared_attn_period=1)   # outer + 2m + 2s
        a, b, c = (_metric_vec(lower_cell(arch, shape_name, multi_pod,
                                          config_variant=v,
                                          rule_extra=rule_extra))
                   for v in (va, vb, vc))
        m = _lin((1, b), (-1, a))
        s = _lin((1, c), (-1, b))
        outer = _lin((1, a), (-1, m), (-1, s))
        g = cfg.n_layers // cfg.shared_attn_period
        p = cfg.shared_attn_period
        return _lin((1, outer), (g * p, m), (g, s))
    if cfg.encoder_decoder:
        va = rep(n_layers=1, n_encoder_layers=1)
        vb = rep(n_layers=1, n_encoder_layers=2)
        vc = rep(n_layers=2, n_encoder_layers=1)
        a, b, c = (_metric_vec(lower_cell(arch, shape_name, multi_pod,
                                          config_variant=v,
                                          rule_extra=rule_extra))
                   for v in (va, vb, vc))
        enc = _lin((1, b), (-1, a))
        dec = _lin((1, c), (-1, a))
        outer = _lin((1, a), (-1, enc), (-1, dec))
        return _lin((1, outer), (cfg.n_encoder_layers, enc),
                    (cfg.n_layers, dec))
    if getattr(cfg, "moe_every", 1) == 2:
        # alternating dense/MoE pairs: vary the PAIR count (2 and 4 layers)
        va, vb = rep(n_layers=2), rep(n_layers=4)
        a, b = (_metric_vec(lower_cell(arch, shape_name, multi_pod,
                                       config_variant=v,
                                       rule_extra=rule_extra))
                for v in (va, vb))
        pair = _lin((1, b), (-1, a))
        outer = _lin((1, a), (-1, pair))
        return _lin((1, outer), (cfg.n_layers // 2, pair))
    va, vb = rep(n_layers=1), rep(n_layers=2)
    a, b = (_metric_vec(lower_cell(arch, shape_name, multi_pod,
                                   config_variant=v, rule_extra=rule_extra))
            for v in (va, vb))
    body = _lin((1, b), (-1, a))
    outer = _lin((1, a), (-1, body))
    return _lin((1, outer), (cfg.n_layers, body))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-aux", action="store_true",
                    help="skip the unrolled roofline-exact aux compiles")
    ap.add_argument("--lsh-index", action="store_true",
                    help="lower the sharded LSH index query cell instead of "
                         "the model cells")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.lsh_index:
        failures = 0
        for mp in ((False, True) if args.both_meshes else (args.multi_pod,)):
            mesh_tag = "2x16x16" if mp else "16x16"
            path = os.path.join(args.out, f"lsh_index__{mesh_tag}.json")
            print(f"[dryrun] compile lsh-index x {mesh_tag} ...", flush=True)
            try:
                rec = lower_lsh_index_cell(mp)
                print(f"[dryrun] ok      lsh-index x {mesh_tag}: "
                      f"{rec['shards']} shards over '{rec['shard_axis']}', "
                      f"{rec['cost']['flops_per_device']:.3e} flops/dev, "
                      f"+1 delta: "
                      f"{rec['delta_probe']['cost']['flops_per_device']:.3e}, "
                      f"hash ({rec['hash_program']['backend']}): "
                      f"{rec['hash_program']['cost']['flops_per_device']:.3e}"
                      f", insert: "
                      f"{rec['insert_program']['cost']['flops_per_device']:.3e}"
                      f", compact: "
                      f"{rec['compact_program']['cost']['flops_per_device']:.3e}"
                      f", swap build: "
                      f"{rec['swap_build_program']['cost']['flops_per_device']:.3e}")
            except Exception as e:
                failures += 1
                rec = {"status": "failed", "arch": "lsh-index",
                       "mesh": mesh_tag, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[dryrun] FAILED  lsh-index x {mesh_tag}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        print(f"[dryrun] done, {failures} failures")
        return 1 if failures else 0
    if args.all:
        jobs = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                for mp in ((False, True) if args.both_meshes
                           else (args.multi_pod,))]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        jobs = [(args.arch, args.shape, m)
                for m in ((False, True) if args.both_meshes
                          else (args.multi_pod,))]

    failures = 0
    for arch, shape, mp in jobs:
        mesh_tag = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_tag}.json")
        if os.path.exists(path) and not args.force:
            print(f"[dryrun] cached  {arch} x {shape} x {mesh_tag}")
            continue
        print(f"[dryrun] compile {arch} x {shape} x {mesh_tag} ...",
              flush=True)
        try:
            rec = lower_cell(arch, shape, mp)
            # roofline-exact costs: single-pod only (the roofline table is
            # single-pod per EXPERIMENTS.md; multi-pod proves compilation)
            if rec["status"] == "ok" and not mp and not args.no_aux:
                cfg = config_for_cell(arch, shape)
                rec["cost_true"] = roofline_costs(arch, shape, cfg, mp)
        except Exception as e:  # a failure here is a bug in the system
            failures += 1
            rec = {"status": "failed", "arch": arch, "shape": shape,
                   "mesh": mesh_tag, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[dryrun] FAILED  {arch} x {shape} x {mesh_tag}: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            mem_gb = rec["memory"]["peak_per_device_bytes"] / 2**30
            print(f"[dryrun] ok      {arch} x {shape} x {mesh_tag}: "
                  f"{rec['cost']['flops_per_device']:.3e} flops/dev, "
                  f"{mem_gb:.2f} GiB/dev, {rec['compile_seconds']}s")
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
