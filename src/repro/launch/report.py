"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run records. (Prose/analysis lives in EXPERIMENTS.md itself.)

    python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.roofline import analyse, expand, fmt_cell


def dryrun_table(directory: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        rec = json.load(open(path))
        name = os.path.basename(path)[:-5]
        if rec.get("status") == "skipped":
            arch, shape, mesh = name.split("__")
            rows.append(f"| {arch} | {shape} | {mesh} | skipped (see "
                        f"DESIGN.md §Arch-applicability) | — | — | — |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {name} | FAILED | | | | | |")
            continue
        for r in expand(rec):
            m = r["memory"]
            coll = r["collectives"]
            coll_s = " ".join(
                f"{k.split('-')[-1] if k != 'all-to-all' else 'a2a'}:"
                f"{v['count']}" for k, v in coll.items() if v["count"])
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"({r['compile_seconds']}s) "
                f"| {m['peak_per_device_bytes'] / 2**30:.2f} "
                f"| {r['cost']['flops_per_device']:.2e} | {coll_s} |")
    hdr = ("| arch | shape | mesh | compile | HBM GiB/chip | HLO flops/chip"
           " (scan body x1) | collective schedule (op:count) |\n"
           "|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(directory: str, mesh: str = "16x16") -> str:
    rows = []
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        recs.extend(expand(rec))
    for r in recs:
        a = analyse(r)
        dom = a["bottleneck"]
        if a["kind"] == "lsh_query":
            move = {
                "compute": "fewer probe FLOPs: smaller bucket_cap / fewer "
                           "tables, lower-rank hash family",
                "memory": "fewer probe bytes: smaller bucket_cap / fewer "
                          "tables, compact() delta segments",
                "collective": "fewer merge bytes: smaller topk / query "
                              "batch, narrower lsh_shard axis",
            }[dom]
        elif a["kind"] == "lsh_mutation":
            move = {
                "compute": "fewer mutation FLOPs: smaller insert batches / "
                           "fewer tables (sort cost is per table)",
                "memory": "fewer mutation bytes: smaller slabs, compact "
                          "more often so folds stay small",
                "collective": "mutation programs should be shard-local — a "
                              "collective here is a partitioning bug",
            }[dom]
        else:
            move = {
                "compute": "fewer FLOPs: lighter remat policy / skip-chunk "
                           "causal attention / lower capacity factor",
                "memory": "fewer HBM bytes: larger fused blocks (Pallas), "
                          "bf16 master/moment dtypes, wider per-chip tiles",
                "collective": "fewer link bytes: reduce-scatter grads, "
                              "collective-matmul overlap, wider TP tiles",
            }[dom]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.2e} "
            f"| {a['memory_s']:.2e} | {a['collective_s']:.2e} | **{dom}** "
            f"| {fmt_cell(a['useful_flops_ratio'], '.2f')} "
            f"| {fmt_cell(a['roofline_mfu'], '.1f', 100, '%')} "
            f"| {a['mem_gib_per_device']:.1f} | {move} |")
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline-MFU | HBM GiB | what moves the dominant"
           " term |\n|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_table(directory: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        r = json.load(open(path))
        if r.get("status") != "ok":
            continue
        a = r["roofline"]
        cell = os.path.basename(path)[:-5].split("__")[0]
        rows.append(
            f"| {cell} | {r['experiment']} | {a['compute_s']:.2e} "
            f"| {a['memory_s']:.2e} | {a['collective_s']:.2e} "
            f"| {a['bottleneck']} | {fmt_cell(a['roofline_mfu'], '.1f', 100, '%')} "
            f"| {a['mem_gib_per_device']:.1f} |")
    hdr = ("| cell | experiment | compute s | memory s | collective s "
           "| dominant | roofline-MFU | HBM GiB |\n"
           "|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--perf-dir", default="experiments/perf")
    ap.add_argument("--section", choices=["dryrun", "roofline", "perf", "all"],
                    default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("### Dry-run records\n")
        print(dryrun_table(args.dir))
    if args.section in ("roofline", "all"):
        print("\n### Roofline (single-pod 16x16)\n")
        print(roofline_table(args.dir))
    if args.section in ("perf", "all") and os.path.isdir(args.perf_dir):
        print("\n### Perf iterations\n")
        print(perf_table(args.perf_dir))


if __name__ == "__main__":
    main()
