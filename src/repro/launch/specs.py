"""Input ShapeDtypeStructs + logical axes for every (arch x shape) cell.

The four assigned shape cells:
    train_4k     seq 4,096  global_batch 256   -> train_step
    prefill_32k  seq 32,768 global_batch 32    -> prefill_step
    decode_32k   seq 32,768 global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1    -> serve_step, sub-quadratic
                                                 archs only (DESIGN.md)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, supports_long_context
from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str      # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def config_for_cell(arch: str, shape: str) -> ModelConfig | None:
    """None => the cell is skipped (pure full-attention arch at 500k)."""
    if shape == "long_500k":
        if not supports_long_context(arch):
            return None
        return get_config(arch, "long")
    return get_config(arch, "full")


def _i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, *, with_labels: bool):
    """(sds_tree, axes_tree) for the model inputs of a train/prefill step."""
    b, s = cell.batch, cell.seq
    dt = jnp.dtype(cfg.dtype)
    sds = {"tokens": _i32((b, s))}
    axes = {"tokens": ("batch", None)}
    if with_labels:
        sds["labels"] = _i32((b, s))
        axes["labels"] = ("batch", None)
    if cfg.vision_tokens:
        sds["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), dt)
        axes["vision_embeds"] = ("batch", None, "embed")
    if cfg.encoder_decoder:
        sds["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
        axes["frames"] = ("batch", "frames", "embed")
    return sds, axes


def cache_specs(cfg: ModelConfig, cell: ShapeCell):
    """(sds_tree, axes_tree) for the decode cache at this cell's length."""
    sds = jax.eval_shape(lambda: T.init_cache(cfg, cell.batch, cell.seq))
    axes = T.cache_axes(cfg)
    return sds, axes


def rule_overrides(cfg: ModelConfig, mesh) -> dict:
    """Per-arch sharding-rule adjustments.

    * saved activations are sequence-sharded over "model" (Megatron-SP
      style) so scan+remat residuals fit HBM on the big dense models;
    * when kv_heads doesn't divide the model axis (GQA kv=8 on 16-wide TP),
      decode caches shard their sequence dim over "model" instead.
    """
    ov: dict = {"act_seq": "model"}
    model_size = mesh.shape.get("model", 1)
    if cfg.n_kv_heads % model_size != 0:
        ov["kv_seq"] = "model"
    return ov
