"""Deterministic synthetic data pipeline.

The stream is a *pure function of (seed, step)* — `batch_at(step)` — which
gives the framework exact skip-ahead semantics: a restarted or resharded
job resumes at step N with bit-identical data, and a straggler-mitigation
redispatch can recompute any shard of any batch independently (no state to
replay). This is the property production pipelines buy with checkpointed
readers; a counter-based PRNG gives it for free.

Sequences are learnable: tokens follow a fixed affine bigram rule
t_{k+1} = (a * t_k + c) mod V with a small noise probability, so next-token
CE drops far below ln(V) within tens of steps (the model only has to learn
a deterministic bigram function) — used by the convergence/e2e tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 256
    seed: int = 0
    mult: int = 5      # bigram rule t' = (mult * t + add) % V
    add: int = 7
    noise_prob: float = 0.02


def bigram_next(dc: DataConfig, cfg: ModelConfig, tok):
    return (dc.mult * tok + dc.add) % cfg.vocab_size


def batch_at(dc: DataConfig, cfg: ModelConfig, step: int | jax.Array):
    """-> {"tokens": (B, S) int32, "labels": (B, S) int32, [frontend stubs]}."""
    key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
    km, kn, kmask, kv, kf = jax.random.split(key, 5)
    b, s = dc.batch_size, dc.seq_len
    vocab = cfg.vocab_size
    start = jax.random.randint(km, (b,), 0, vocab)

    def gen(tok, _):
        nxt = (dc.mult * tok + dc.add) % vocab
        return nxt, nxt

    _, seq = jax.lax.scan(gen, start, None, length=s - 1)
    tokens = jnp.concatenate([start[:, None], seq.T], axis=1)
    noise = jax.random.randint(kn, (b, s), 0, vocab)
    mask = jax.random.bernoulli(kmask, dc.noise_prob, (b, s))
    tokens = jnp.where(mask, noise, tokens).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            kv, (b, cfg.vision_tokens, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
        batch["labels"] = batch["labels"].at[:, :cfg.vision_tokens].set(-1)
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(
            kf, (b, cfg.encoder_seq, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return batch
