"""Mixture-of-Experts FFN: capacity-bounded slot dispatch.

Design (production pattern, XLA-SPMD friendly):
  * router top-k, softmax over the selected logits (mixtral-style);
  * every (token, choice) assignment gets a rank within its expert via a
    one-hot cumsum; assignments past the expert capacity C are dropped;
  * tokens are scattered into an (E, C, D) dispatch buffer — a real
    scatter, NOT a one-hot einsum, so HLO FLOPs stay honest for roofline;
  * expert FFNs run as batched matmuls (E, C, D) x (E, D, F);
  * results gather back by slot and combine weighted by the gates.

Sharding: the dispatch buffer and expert weights carry the "expert" logical
axis (-> "model" mesh axis). For archs where E divides the model axis
(llama4: 128 % 16 == 0) this is expert parallelism; where it does not
(mixtral: 8 experts on 16 chips) the divisibility fallback replicates E and
shards the FFN hidden dim instead — tensor-parallel experts. Both modes come
out of the same code path + rules.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import activation, norm


def moe_block(cfg: ModelConfig, lp: dict, x: jax.Array):
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    h = norm(cfg, x, lp["mlp_ln"])
    ht = h.reshape(b * s, d)
    t = b * s

    logits = jnp.einsum("td,de->te", ht, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, top_idx = jax.lax.top_k(logits, k)          # (T, k)
    gates = jax.nn.softmax(top_logits, axis=-1).astype(x.dtype)

    # load-balance aux loss (Switch/Mixtral): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(
        (jax.nn.one_hot(top_idx, e, dtype=jnp.float32).sum(axis=1)), axis=0)
    aux_loss = e * jnp.sum(me * ce)

    capacity = int(math.ceil(t * k / e * cfg.capacity_factor))
    capacity = max(capacity, 4)

    flat_e = top_idx.reshape(t * k)                         # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # (T*k, E)
    ranks = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=-1) - 1
    keep = ranks < capacity
    # dropped assignments get out-of-bounds slots -> scatter mode="drop"
    # (no +1 overflow row: E*C+1 would be unshardable)
    slot = jnp.where(keep, flat_e * capacity + ranks,
                     jnp.iinfo(jnp.int32).max)

    # dispatch scatter. Sharding note: the (T*k, D) source and (E*C, D)
    # buffer are sharded on the FEATURE dim, never the row dim — SPMD
    # partitioning of a row-indexed scatter whose row dim is sharded
    # materializes u32 per-element index tensors + all-gathers them
    # (a 48 GiB/chip catastrophe on mixtral; EXPERIMENTS.md §Dry-run).
    # Feature-sharded, every chip scatters full rows of its D-slice locally.
    tok_of = jnp.repeat(jnp.arange(t), k)
    # feature-shard ht BEFORE the row gather: a gather whose operand rows
    # are batch-sharded replicates a (T*k, D) f32 copy on every chip
    ht_d = shard(ht, None, "moe_d")
    src = shard(ht_d[tok_of], None, "moe_d")
    buf = jnp.zeros((e * capacity, d), x.dtype)
    buf = shard(buf.at[slot].set(src, mode="drop", unique_indices=False),
                None, "moe_d")
    # "expert" -> EP over the model axis when E divides it (llama4);
    # otherwise (mixtral, 8e on 16-way TP) E is replicated, the capacity dim
    # shards over DP and the FFN hidden dim over TP — both from one rule set.
    # keep the feature dim sharded through the reshape: resharding D -> C
    # here costs a full all-gather of the (E, C, D) buffer on the multi-pod
    # mesh (a 60 GiB/chip copy); contraction over the sharded D is a psum.
    xe = buf.reshape(e, capacity, d)
    xe = shard(xe, "expert", "capacity", "moe_d")

    # expert FFN (batched matmuls; MXU-friendly)
    g = jnp.einsum("ecd,edf->ecf", xe, lp["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, lp["we_up"])
    a = activation(cfg, g, u)
    a = shard(a, "expert", "capacity", "mlp")
    ye = jnp.einsum("ecf,efd->ecd", a, lp["we_down"])
    ye = shard(ye, "expert", "capacity", "moe_d")

    # combine: gather by slot, weight by gate, sum over the k choices
    # (feature-sharded for the same scatter-transpose reason as dispatch)
    yflat = shard(ye.reshape(e * capacity, d), None, "moe_d")
    safe_slot = jnp.minimum(slot, e * capacity - 1)
    per_choice = yflat[safe_slot] * (gates.reshape(t * k, 1)
                                     * keep[:, None].astype(ye.dtype))
    per_choice = shard(per_choice, None, "moe_d")
    out = per_choice.reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        sg = jnp.einsum("td,df->tf", ht, lp["ws_gate"])
        su = jnp.einsum("td,df->tf", ht, lp["ws_up"])
        out = out + jnp.einsum("tf,fd->td", activation(cfg, sg, su),
                               lp["ws_down"])
    return out.reshape(b, s, d), aux_loss


def moe_block_dense_reference(cfg: ModelConfig, lp: dict, x: jax.Array):
    """O(E x tokens) reference: every expert on every token, masked combine.
    Used only in tests to validate the dispatch path (no capacity drops)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    h = norm(cfg, x, lp["mlp_ln"])
    ht = h.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", ht, lp["router"]).astype(jnp.float32)
    top_logits, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_logits, axis=-1)
    g = jnp.einsum("td,edf->etf", ht, lp["we_gate"])
    u = jnp.einsum("td,edf->etf", ht, lp["we_up"])
    ye = jnp.einsum("etf,efd->etd", activation(cfg, g, u), lp["we_down"])
    weights = jnp.zeros((b * s, e), jnp.float32)
    weights = jax.vmap(lambda w, i, gv: w.at[i].add(gv))(weights, top_idx, gates)
    out = jnp.einsum("te,etd->td", weights.astype(ye.dtype), ye)
    if cfg.n_shared_experts:
        sg = jnp.einsum("td,df->tf", ht, lp["ws_gate"])
        su = jnp.einsum("td,df->tf", ht, lp["ws_up"])
        out = out + jnp.einsum("tf,fd->td", activation(cfg, sg, su),
                               lp["ws_down"])
    return out.reshape(b, s, d)
