"""LSH attention: the paper's CP-SRP (Definition 12) applied to long context.

Each head vector in R^{hd} is viewed as a 2-mode tensor (hd = m1 x m2) and
hashed with K CP-Rademacher projection tensors of rank R (Definition 6):
code bit k = sign(<P_k, reshape(x)>), bucket id = packed K bits. Queries and
keys that share a bucket are likely to have high cosine similarity (Theorem
8), so attention is restricted to bucket-mates:

  * prefill: sort tokens by (bucket, position) per head, attend within
    consecutive chunks + one look-back chunk (Reformer-style), causal on
    the ORIGINAL positions; unsort. O(S * chunk) instead of O(S^2).
  * decode: O(S) integer code-match against the cache + top-C candidate
    selection (forced recency window), then exact attention over C keys.

This is the bridge between the paper and the LM substrate: the projection
runs through the exact math of core/projections (batched CP Gram einsums),
with factors sign()-ed to Rademacher per Definition 6.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import norm
from repro.models.attention import qkv_proj, NEG_INF


class LSHKVCache(NamedTuple):
    k: jax.Array      # (B, W, KV, hd)
    v: jax.Array      # (B, W, KV, hd)
    codes: jax.Array  # (B, W, KV) int32 bucket ids of cached keys


def srp_bucket_codes(x: jax.Array, f1: jax.Array, f2: jax.Array) -> jax.Array:
    """x (..., hd) -> int32 bucket ids via CP-SRP (Defs 6, 12).

    f1 (K, m1, R), f2 (K, m2, R): Gaussian params sign()-ed to Rademacher.
    value_k = (1/sqrt(R)) sum_{i,j} x[i,j] sum_r f1[k,i,r] f2[k,j,r].
    """
    k, m1, r = f1.shape
    m2 = f2.shape[1]
    a1 = jnp.sign(f1.astype(jnp.float32))
    a2 = jnp.sign(f2.astype(jnp.float32))
    x2 = x.astype(jnp.float32).reshape(x.shape[:-1] + (m1, m2))
    t = jnp.einsum("...ij,kjr->...kir", x2, a2)
    vals = jnp.einsum("...kir,kir->...k", t, a1) / math.sqrt(r)
    bits = (vals > 0).astype(jnp.int32)
    weights = (1 << jnp.arange(k, dtype=jnp.int32))
    return jnp.sum(bits * weights, axis=-1)


def _sort_by(perm: jax.Array, x: jax.Array) -> jax.Array:
    """take_along_axis over the S axis; x (B,H,S,...), perm (B,H,S)."""
    idx = perm.reshape(perm.shape + (1,) * (x.ndim - perm.ndim))
    return jnp.take_along_axis(x, idx, axis=2)


def lsh_attention_prefill(cfg: ModelConfig, proj: dict, q, k, v, positions):
    """q (B,S,H,hd), k/v (B,S,KV,hd) -> out (B,S,H,hd). O(S * lsh_chunk)."""
    b, s_orig, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    c = min(cfg.lsh_chunk, s_orig)
    scale = 1.0 / math.sqrt(hd)

    # pad S to a multiple of the chunk; padded tokens get positions beyond
    # the sequence (causally invisible to real queries) and max bucket codes
    # (sort to the end); padded query rows are sliced off after unsorting.
    pad = (-s_orig) % c
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=jnp.iinfo(jnp.int32).max // 2)
    s = s_orig + pad

    # bucket codes; keys hashed per kv head then repeated over the group
    qc = srp_bucket_codes(q, proj["f1"], proj["f2"])              # (B,S,H)
    kc = jnp.repeat(srp_bucket_codes(k, proj["f1"], proj["f2"]),
                    g, axis=2)                                     # (B,S,H)
    if pad:
        pad_mask = jnp.arange(s) >= s_orig
        qc = jnp.where(pad_mask[None, :, None], 1 << 30, qc)
        kc = jnp.where(pad_mask[None, :, None], 1 << 30, kc)

    # head-major layout
    qh = jnp.moveaxis(q, 2, 1)                                     # (B,H,S,hd)
    kh = jnp.moveaxis(jnp.repeat(k, g, axis=2), 2, 1)
    vh = jnp.moveaxis(jnp.repeat(v, g, axis=2), 2, 1)
    qch = jnp.moveaxis(qc, 2, 1)                                   # (B,H,S)
    kch = jnp.moveaxis(kc, 2, 1)
    pos_b = jnp.broadcast_to(positions[:, None, :], (b, h, s))

    # stable sort by (bucket, position) — lexsort avoids int32 overflow
    qperm = jnp.lexsort((pos_b, qch), axis=-1)
    kperm = jnp.lexsort((pos_b, kch), axis=-1)
    qs = _sort_by(qperm, qh).astype(jnp.float32) * scale
    ks = _sort_by(kperm, kh).astype(jnp.float32)
    vs = _sort_by(kperm, vh).astype(jnp.float32)
    qpos = jnp.take_along_axis(pos_b, qperm, axis=-1)
    kpos = jnp.take_along_axis(pos_b, kperm, axis=-1)

    nc = s // c
    qs = qs.reshape(b, h, nc, c, hd)
    ks = ks.reshape(b, h, nc, c, hd)
    vs = vs.reshape(b, h, nc, c, hd)
    qpos_c = qpos.reshape(b, h, nc, c)
    kpos_c = kpos.reshape(b, h, nc, c)

    # each q chunk sees its own + the previous k chunk (wrap masked causally)
    k2 = jnp.concatenate([jnp.roll(ks, 1, axis=2), ks], axis=3)    # (B,H,nc,2c,hd)
    v2 = jnp.concatenate([jnp.roll(vs, 1, axis=2), vs], axis=3)
    kp2 = jnp.concatenate([jnp.roll(kpos_c, 1, axis=2), kpos_c], axis=3)

    sc = jnp.einsum("bhnqd,bhnkd->bhnqk", qs, k2)
    causal = kp2[:, :, :, None, :] <= qpos_c[..., None]
    sc = jnp.where(causal, sc, NEG_INF)
    # a token always sees at least itself (same bucket, same chunk)
    p = jax.nn.softmax(sc, axis=-1)
    out_s = jnp.einsum("bhnqk,bhnkd->bhnqd", p, v2).reshape(b, h, s, hd)

    # unsort, drop padding rows
    inv = jnp.argsort(qperm, axis=-1)
    out = _sort_by(inv, out_s)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)[:, :s_orig]     # (B,S,H,hd)


def lsh_attention_decode(cfg: ModelConfig, proj: dict, q, cache: LSHKVCache,
                         cache_pos, cur_pos):
    """q (B,1,H,hd) over a full-length hashed cache. O(S) match + O(C) attn."""
    b, _, h, hd = q.shape
    w, kvh = cache.k.shape[1], cache.k.shape[2]
    g = h // kvh
    cand = min(cfg.lsh_candidates, w)
    scale = 1.0 / math.sqrt(hd)

    qc = srp_bucket_codes(q, proj["f1"], proj["f2"])[:, 0]         # (B,H)
    kc = jnp.repeat(cache.codes, g, axis=2)                        # (B,W,H)

    valid = (cache_pos >= 0) & (cache_pos <= cur_pos)              # (W,)
    match = (kc == qc[:, None, :]) & valid[None, :, None]
    recent = ((cur_pos - cache_pos) < cfg.lsh_recent) & valid      # (W,)

    # selection score: recency dominates, then bucket match, newer first
    sel = (recent[None, :, None].astype(jnp.float32) * 4e9
           + match.astype(jnp.float32) * 2e9
           + cache_pos[None, :, None].astype(jnp.float32))
    sel = jnp.where(valid[None, :, None], sel, -1.0)
    sel_h = jnp.moveaxis(sel, 1, 2)                                # (B,H,W)
    _, idx = jax.lax.top_k(sel_h, cand)                            # (B,H,C)

    # Gather the C candidates straight from the cache without materializing
    # the group-repeated (B, W, H, hd) copy (2x 13 GiB/chip at 500k). The
    # gather must index ONLY the W axis: q heads are contiguous per kv head,
    # so idx regroups to (B, KV, g*C) and take_along_axis runs along W with
    # the sharded KV dim as a batch dim — a flat (slot*KV+head) index would
    # gather ACROSS the sharded dim and all-gather the whole cache (§Perf).
    idx_kv = idx.reshape(b, kvh, g * cand)                         # (B,KV,g*C)
    k_t = jnp.swapaxes(cache.k, 1, 2)                              # (B,KV,W,hd)
    v_t = jnp.swapaxes(cache.v, 1, 2)
    kg = jnp.take_along_axis(k_t, idx_kv[..., None], axis=2)
    vg = jnp.take_along_axis(v_t, idx_kv[..., None], axis=2)
    kg = kg.reshape(b, h, cand, hd)
    vg = vg.reshape(b, h, cand, hd)
    attendable = jnp.take_along_axis(
        jnp.moveaxis(match | recent[None, :, None], 1, 2), idx, axis=2)

    qf = q[:, 0].astype(jnp.float32) * scale                       # (B,H,hd)
    sc = jnp.einsum("bhd,bhcd->bhc", qf, kg.astype(jnp.float32))
    sc = jnp.where(attendable, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhc,bhcd->bhd", p, vg.astype(jnp.float32))
    return out[:, None].astype(q.dtype)                            # (B,1,H,hd)


def lsh_attention_block(cfg: ModelConfig, lp: dict, proj: dict, x, positions,
                        *, cache: LSHKVCache | None = None, cache_pos=None,
                        cur_pos=None):
    """Drop-in attention sub-block using CP-SRP bucketing. Returns
    (residual_delta, new_cache)."""
    h = norm(cfg, x, lp["ln"])
    q, k, v = qkv_proj(cfg, lp, h, positions)
    if cache is None:
        out = lsh_attention_prefill(cfg, proj, q, k, v, positions)
        codes = srp_bucket_codes(k, proj["f1"], proj["f2"])
        new_cache = LSHKVCache(
            k=shard(k, "batch", "kv_seq", "kv_heads", None),
            v=shard(v, "batch", "kv_seq", "kv_heads", None),
            codes=shard(codes, "batch", "kv_seq", "kv_heads"))
    else:
        out = lsh_attention_decode(cfg, proj, q, cache, cache_pos, cur_pos)
        codes = srp_bucket_codes(k, proj["f1"], proj["f2"])
        slot = cur_pos  # full-length cache, no ring
        new_cache = LSHKVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1),
            codes=jax.lax.dynamic_update_slice_in_dim(cache.codes, codes,
                                                      slot, axis=1),
        )
    b, s = out.shape[0], out.shape[1]
    y = jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), lp["wo"])
    return y, new_cache
