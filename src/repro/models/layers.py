"""Shared layers: norms, RoPE, activations, MLPs, embedding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layernorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def norm(cfg: ModelConfig, x: jax.Array, scale: jax.Array) -> jax.Array:
    return rmsnorm(x, scale) if cfg.norm == "rmsnorm" else layernorm(x, scale)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation(cfg: ModelConfig, gate: jax.Array, up: jax.Array | None) -> jax.Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.gelu(gate, approximate=True)


def mlp(cfg: ModelConfig, lp: dict, x: jax.Array) -> jax.Array:
    """Dense FFN with pre-norm. x: (B, S, D)."""
    h = norm(cfg, x, lp["mlp_ln"])
    if cfg.act in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", h, lp["wi_gate"])
        up = jnp.einsum("bsd,df->bsf", h, lp["wi_up"])
        a = activation(cfg, gate, up)
    else:
        a = activation(cfg, jnp.einsum("bsd,df->bsf", h, lp["wi"]), None)
    a = shard(a, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", a, lp["mlp_wo"])


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"]["tokens"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def lm_logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = norm(cfg, x, params["final_norm"])
    head = (params["embed"]["tokens"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab")
