"""Parameter specs: the single source of truth for shapes, logical sharding
axes and init of every architecture's parameters.

`param_specs(cfg)` returns a nested dict of ParamSpec; `init_params` /
`abstract_params` / `param_axes` are derived views, so shapes, shardings and
initialization can never drift apart. Per-layer weights carry a leading
`n_layers` dim ("layers", never sharded) and are consumed by lax.scan.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"      # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02


def _attn_specs(cfg: ModelConfig, layers: int | None, cross: bool = False) -> dict:
    """Attention weights; leading layers dim if `layers` given."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    pre = "x" if cross else ""
    return {
        f"{pre}ln": ParamSpec(L + (d,), lax_ + ("embed",), init="ones"),
        f"{pre}wq": ParamSpec(L + (d, h * hd), lax_ + ("fsdp_embed", "heads")),
        f"{pre}wk": ParamSpec(L + (d, kv * hd), lax_ + ("fsdp_embed", "kv_heads")),
        f"{pre}wv": ParamSpec(L + (d, kv * hd), lax_ + ("fsdp_embed", "kv_heads")),
        f"{pre}wo": ParamSpec(L + (h * hd, d), lax_ + ("heads", "fsdp_embed"),
                              scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _mlp_specs(cfg: ModelConfig, layers: int | None, d_ff: int = 0) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    L = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    out = {"mlp_ln": ParamSpec(L + (d,), lax_ + ("embed",), init="ones")}
    if cfg.act in ("swiglu", "geglu"):
        out["wi_gate"] = ParamSpec(L + (d, f), lax_ + ("fsdp_embed", "mlp"))
        out["wi_up"] = ParamSpec(L + (d, f), lax_ + ("fsdp_embed", "mlp"))
    else:
        out["wi"] = ParamSpec(L + (d, f), lax_ + ("fsdp_embed", "mlp"))
    out["mlp_wo"] = ParamSpec(L + (f, d), lax_ + ("mlp", "fsdp_embed"),
                              scale=0.02 / math.sqrt(2 * cfg.n_layers))
    return out


def _moe_specs(cfg: ModelConfig, layers: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    L, lax_ = (layers,), ("layers",)
    del layers
    out = {
        "mlp_ln": ParamSpec(L + (d,), lax_ + ("embed",), init="ones"),
        "router": ParamSpec(L + (d, e), lax_ + ("embed", None)),
        "we_gate": ParamSpec(L + (e, d, f), lax_ + ("expert", "fsdp_embed", "mlp")),
        "we_up": ParamSpec(L + (e, d, f), lax_ + ("expert", "fsdp_embed", "mlp")),
        "we_down": ParamSpec(L + (e, f, d), lax_ + ("expert", "mlp", "fsdp_embed"),
                             scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        out["ws_gate"] = ParamSpec(L + (d, fs), lax_ + ("fsdp_embed", "mlp"))
        out["ws_up"] = ParamSpec(L + (d, fs), lax_ + ("fsdp_embed", "mlp"))
        out["ws_down"] = ParamSpec(L + (fs, d), lax_ + ("mlp", "fsdp_embed"),
                                   scale=0.02 / math.sqrt(2 * cfg.n_layers))
    return out


def _ssm_specs(cfg: ModelConfig, layers: int) -> dict:
    d, din, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    gs = cfg.ssm_groups * cfg.ssm_state
    L, lax_ = (layers,), ("layers",)
    return {
        "ssm_ln": ParamSpec(L + (d,), lax_ + ("embed",), init="ones"),
        "w_xBC": ParamSpec(L + (d, din + 2 * gs), lax_ + ("fsdp_embed", "ssm_inner")),
        "w_z": ParamSpec(L + (d, din), lax_ + ("fsdp_embed", "ssm_inner")),
        "w_dt": ParamSpec(L + (d, h), lax_ + ("fsdp_embed", "ssm_heads")),
        "conv_w": ParamSpec(L + (cfg.conv_width, din + 2 * gs),
                            lax_ + ("conv", "ssm_inner"), scale=0.2),
        "A_log": ParamSpec(L + (h,), lax_ + ("ssm_heads",), init="ssm_a"),
        "ssm_D": ParamSpec(L + (h,), lax_ + ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec(L + (h,), lax_ + ("ssm_heads",), init="ssm_dt"),
        "norm_z": ParamSpec(L + (din,), lax_ + ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec(L + (din, d), lax_ + ("ssm_inner", "fsdp_embed"),
                              scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    specs: dict = {
        "embed": {"tokens": ParamSpec((v, d), ("vocab", "fsdp_embed"))},
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("fsdp_embed", "vocab"))

    L = cfg.n_layers
    if cfg.block == "attn_dense":
        specs["blocks"] = {**_attn_specs(cfg, L), **_mlp_specs(cfg, L)}
    elif cfg.block == "attn_moe":
        lm = L // cfg.moe_every
        specs["blocks"] = {**_attn_specs(cfg, lm), **_moe_specs(cfg, lm)}
        if cfg.moe_every == 2:
            specs["dense_blocks"] = {
                **_attn_specs(cfg, lm),
                **_mlp_specs(cfg, lm, d_ff=cfg.d_ff_dense)}
    elif cfg.block == "ssm":
        specs["blocks"] = _ssm_specs(cfg, L)
    elif cfg.block == "hybrid":
        specs["blocks"] = _ssm_specs(cfg, L)
        specs["shared"] = {**_attn_specs(cfg, None), **_mlp_specs(cfg, None)}
    else:
        raise ValueError(cfg.block)

    if cfg.lsh_attention:
        # CP-SRP projection tensors over the (hd1, hd2)-matricized head dim
        # (paper Definition 6/12): two stacked factor matrices, K = num_hashes.
        m1, m2 = _factor_head_dim(cfg.hd)
        specs["lsh_proj"] = {
            "f1": ParamSpec((cfg.lsh_num_hashes, m1, cfg.lsh_rank),
                            ("lsh_hash", None, "lsh_rank"), scale=1.0),
            "f2": ParamSpec((cfg.lsh_num_hashes, m2, cfg.lsh_rank),
                            ("lsh_hash", None, "lsh_rank"), scale=1.0),
        }

    if cfg.encoder_decoder:
        specs["encoder"] = {
            "pos": ParamSpec((cfg.encoder_seq, d), ("frames", "embed"), scale=0.02),
            "blocks": {**_attn_specs(cfg, cfg.n_encoder_layers),
                       **_mlp_specs(cfg, cfg.n_encoder_layers)},
            "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        }
        # decoder blocks gain cross-attention
        specs["blocks"].update(_attn_specs(cfg, L, cross=True))
        specs["dec_pos"] = ParamSpec((8192, d), (None, "embed"), scale=0.02)
    return specs


def _factor_head_dim(hd: int) -> tuple[int, int]:
    """Split head_dim into two near-square mode dims for the CP projection."""
    m1 = int(math.sqrt(hd))
    while hd % m1:
        m1 -= 1
    return m1, hd // m1


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # A in [1, 16), stored as log: standard mamba2 init
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt":
        # dt bias s.t. softplus(bias) in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    return (spec.scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array):
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)
    arrs = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree (no allocation) for AOT lowering."""
    specs = param_specs(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=_is_spec)


def param_axes(cfg: ModelConfig):
    """Tree of logical-axis tuples matching the params tree."""
    return jax.tree.map(lambda s: s.axes, param_specs(cfg), is_leaf=_is_spec)


def count_params(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def count_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: routed top_k + shared experts only)."""
    if not cfg.n_experts:
        return count_params(cfg)
    total = count_params(cfg)
    specs = param_specs(cfg)["blocks"]
    expert_leaves = [v for k, v in specs.items() if k.startswith("we_")]
    expert_total = sum(int(np.prod(s.shape)) for s in expert_leaves)
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - expert_total * (1.0 - active_frac))
