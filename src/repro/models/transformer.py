"""Model assembly: decoder-only LM, hybrid Mamba2+shared-attention, and the
Whisper-style encoder-decoder — one scan-over-layers engine for all 10 archs.

Entry points (all pure functions of (cfg, params, ...)):
  forward(cfg, params, batch)                  -> logits          (train/prefill)
  loss_fn(cfg, params, batch)                  -> (loss, metrics) (train)
  init_cache(cfg, batch, max_len)              -> cache pytree    (decode)
  prefill(cfg, params, batch, max_len)         -> (logits, cache)
  decode_step(cfg, params, token, cache, pos)  -> (logits, cache) (serving)

Layer heterogeneity is handled structurally: homogeneous archs scan stacked
params; the hybrid arch scans groups of `shared_attn_period` Mamba2 layers
followed by one weight-shared attention+MLP block (zamba2).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import params as params_lib
from repro.models.attention import (KVCache, attention_block, cache_window,
                                    cross_attention_block, encode_kv)
from repro.models.layers import embed_tokens, lm_logits, mlp, norm
from repro.models.lsh_attention import (LSHKVCache, lsh_attention_block)
from repro.models.moe import moe_block
from repro.models.ssm import SSMCache, init_ssm_cache, ssm_block


# ---------------------------------------------------------------------------
# Single decoder layer (all block kinds)
# ---------------------------------------------------------------------------


def decoder_layer(cfg: ModelConfig, lp: dict, x, positions, *,
                  layer_cache=None, cache_pos=None, cur_pos=None,
                  enc_kv=None, enc_pos=None, lsh_proj=None):
    """Returns (x, new_layer_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.block in ("ssm", "hybrid"):
        delta, new_cache = ssm_block(cfg, lp, x, cache=layer_cache)
        return shard(x + delta, "batch", "act_seq", "embed"), new_cache, aux

    if cfg.lsh_attention:
        delta, new_cache = lsh_attention_block(
            cfg, lp, lsh_proj, x, positions, cache=layer_cache,
            cache_pos=cache_pos, cur_pos=cur_pos)
    else:
        delta, new_cache = attention_block(
            cfg, lp, x, positions, causal=True, window=cfg.sliding_window,
            cache=layer_cache, cache_pos=cache_pos, cur_pos=cur_pos)
    x = x + delta
    x = shard(x, "batch", "act_seq", "embed")

    if cfg.encoder_decoder:
        assert enc_kv is not None
        x = x + cross_attention_block(cfg, lp, x, enc_kv[0], enc_kv[1], enc_pos)

    if cfg.block == "attn_moe":
        delta, aux = moe_block(cfg, lp, x)
    else:
        delta = mlp(cfg, lp, x)
    x = x + delta
    return shard(x, "batch", "act_seq", "embed"), new_cache, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "nothing": save only inputs (full remat)


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _scan_blocks(cfg: ModelConfig, blocks, x, positions, *, caches=None,
                 cache_pos=None, cur_pos=None, enc_kv=None, enc_pos=None,
                 lsh_proj=None, collect_kv=False):
    """Homogeneous layer scan. caches/new caches are stacked over layers.
    Returns (x, new_caches | collected kv, aux_sum)."""

    def body(carry, per_layer):
        h, aux_sum = carry
        lp, lc, lenc = per_layer
        h, new_cache, aux = decoder_layer(
            cfg, lp, h, positions, layer_cache=lc, cache_pos=cache_pos,
            cur_pos=cur_pos, enc_kv=lenc, enc_pos=enc_pos, lsh_proj=lsh_proj)
        out = new_cache if (collect_kv or lc is not None) else None
        return (h, aux_sum + aux), out

    body = _remat(cfg, body)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks, caches, enc_kv),
        unroll=True if cfg.scan_unroll else 1)
    return x, new_caches, aux


@functools.lru_cache(maxsize=None)
def _dense_view(cfg: ModelConfig) -> ModelConfig:
    """cfg for the interleaved dense layers of a moe_every=2 arch."""
    return dataclasses.replace(cfg, block="attn_dense", d_ff=cfg.d_ff_dense)


def _alt_blocks(cfg: ModelConfig, params, x, positions, *, caches=None,
                cache_pos=None, cur_pos=None, collect_kv=False):
    """llama4-style alternation: scan over (dense layer, MoE layer) pairs.
    Caches come in/out as a single (L, ...) stack; internally (L/2, 2, ...)."""
    dense_cfg = _dense_view(cfg)
    lm = cfg.n_layers // 2
    pair_caches = None
    if caches is not None:
        pair_caches = jax.tree.map(
            lambda a: a.reshape((lm, 2) + a.shape[1:]), caches)

    def body(carry, per):
        h, aux_sum = carry
        lpd, lpm, lc = per
        lcd = lcm = None
        if lc is not None:
            lcd = jax.tree.map(lambda a: a[0], lc)
            lcm = jax.tree.map(lambda a: a[1], lc)
        h, ncd, a1 = decoder_layer(dense_cfg, lpd, h, positions,
                                   layer_cache=lcd, cache_pos=cache_pos,
                                   cur_pos=cur_pos)
        h, ncm, a2 = decoder_layer(cfg, lpm, h, positions,
                                   layer_cache=lcm, cache_pos=cache_pos,
                                   cur_pos=cur_pos)
        out = None
        if collect_kv or lc is not None:
            out = jax.tree.map(lambda a, b: jnp.stack([a, b]), ncd, ncm)
        return (h, aux_sum + a1 + a2), out

    body = _remat(cfg, body)
    (x, aux), new_pairs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["dense_blocks"], params["blocks"], pair_caches),
        unroll=True if cfg.scan_unroll else 1)
    new_caches = None
    if new_pairs is not None:
        new_caches = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_pairs)
    return x, new_caches, aux


def _hybrid_blocks(cfg: ModelConfig, params, x, positions, *, caches=None,
                   cache_pos=None, cur_pos=None, collect_kv=False):
    """zamba2: groups of `period` Mamba2 layers + one shared attn/MLP block.

    Mamba params stacked (L, ...) -> (G, P, ...); the shared block's cache is
    stacked (G, ...) since each application attends over its own K/V.
    """
    period = cfg.shared_attn_period
    groups = cfg.n_layers // period
    blocks = jax.tree.map(
        lambda a: a.reshape((groups, period) + a.shape[1:]), params["blocks"])
    shared = params["shared"]
    m_caches, s_caches = (caches if caches is not None else (None, None))

    def group_body(carry, per_group):
        h, aux_sum = carry
        gblocks, gmcache, gscache = per_group

        def inner(c, per_layer):
            hh, aux_in = c
            lp, lc = per_layer
            hh, nc, aux = decoder_layer(cfg, lp, hh, positions,
                                        layer_cache=lc, cur_pos=cur_pos)
            return (hh, aux_in + aux), nc

        (h, aux_sum), new_m = jax.lax.scan(
            _remat(cfg, inner), (h, aux_sum), (gblocks, gmcache),
            unroll=True if cfg.scan_unroll else 1)
        # weight-shared attention + MLP block
        delta, new_s = attention_block(
            cfg, shared, h, positions, causal=True,
            window=cfg.sliding_window, cache=gscache,
            cache_pos=cache_pos, cur_pos=cur_pos)
        h = h + delta
        h = h + mlp(cfg, shared, h)
        out_s = new_s if (collect_kv or gscache is not None) else None
        return (h, aux_sum), (new_m, out_s)

    (x, aux), (new_m, new_s) = jax.lax.scan(
        _remat(cfg, group_body), (x, jnp.zeros((), jnp.float32)),
        (blocks, m_caches, s_caches),
        unroll=True if cfg.scan_unroll else 1)
    return x, (new_m, new_s), aux


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def run_encoder(cfg: ModelConfig, params, frames):
    """frames (B, T, D) precomputed embeddings (stubbed conv frontend)."""
    enc = params["encoder"]
    b, t, _ = frames.shape
    x = frames + enc["pos"][None, :t]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(carry, lp):
        h, _ = carry
        delta, _ = attention_block(cfg, lp, h, pos, causal=False)
        h = h + delta
        h = h + mlp(cfg, lp, h)
        return (h, jnp.zeros((), jnp.float32)), None

    (x, _), _ = jax.lax.scan(_remat(cfg, body),
                             (x, jnp.zeros((), jnp.float32)), enc["blocks"],
                             unroll=True if cfg.scan_unroll else 1)
    return norm(cfg, x, enc["final_norm"]), pos


def _dec_enc_kv(cfg: ModelConfig, params, enc_out):
    """Per-decoder-layer cross K/V, stacked (L, B, T, KV, hd)."""
    def per_layer(lp):
        return encode_kv(cfg, lp, enc_out)
    return jax.vmap(per_layer, in_axes=0)(  # vmap over stacked layer params
        params["blocks"])


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def _prepare_inputs(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.vision_tokens:
        p = cfg.vision_tokens
        vis = batch["vision_embeds"].astype(x.dtype)  # (B, P, D)
        mask = (jnp.arange(s) < p)[None, :, None]
        vis_full = jnp.pad(vis, ((0, 0), (0, s - p), (0, 0)))
        x = jnp.where(mask, vis_full, x)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.encoder_decoder:
        n_pos = params["dec_pos"].shape[0]
        x = x + params["dec_pos"][jnp.arange(s) % n_pos][None]
    return x, positions


def forward(cfg: ModelConfig, params, batch, *, collect_kv=False):
    """Full-sequence pass. Returns (logits, kv_stacks | None, aux)."""
    x, positions = _prepare_inputs(cfg, params, batch)
    enc_kv = enc_pos = None
    if cfg.encoder_decoder:
        enc_out, enc_pos = run_encoder(cfg, params, batch["frames"])
        enc_kv = _dec_enc_kv(cfg, params, enc_out)
    if cfg.block == "hybrid":
        x, kv, aux = _hybrid_blocks(cfg, params, x, positions,
                                    collect_kv=collect_kv)
    elif cfg.block == "attn_moe" and cfg.moe_every == 2:
        x, kv, aux = _alt_blocks(cfg, params, x, positions,
                                 collect_kv=collect_kv)
    else:
        x, kv, aux = _scan_blocks(cfg, params["blocks"], x, positions,
                                  enc_kv=enc_kv, enc_pos=enc_pos,
                                  lsh_proj=params.get("lsh_proj"),
                                  collect_kv=collect_kv)
    logits = lm_logits(cfg, params, x)
    return logits, kv, aux


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token CE (labels < 0 are masked) + MoE aux loss."""
    logits, _, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = (logz - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / denom
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    pos: jax.Array            # (W,) int32 positions of cache slots, -1 empty
    layers: Any               # stacked per-layer caches (see init_cache)
    shared: Any = None        # hybrid: (G, ...) KVCache for the shared block
    enc_kv: Any = None        # enc-dec: (L, B, T, KV, hd) cross K/V
    enc_pos: Any = None       # (B, T) encoder positions


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> DecodeCache:
    dt = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    w = cache_window(cfg, max_len)
    l = cfg.n_layers
    pos = jnp.full((w,), -1, jnp.int32)

    def kv_stack(lead, width):
        return KVCache(
            k=jnp.zeros(lead + (batch, width, kv, hd), dt),
            v=jnp.zeros(lead + (batch, width, kv, hd), dt))

    shared = None
    if cfg.block in ("ssm", "hybrid"):
        per = init_ssm_cache(cfg, batch)
        layers = jax.tree.map(
            lambda a: jnp.zeros((l,) + a.shape, a.dtype), per)
        if cfg.block == "hybrid":
            g = cfg.n_layers // cfg.shared_attn_period
            layers = jax.tree.map(
                lambda a: a.reshape((g, cfg.shared_attn_period) + a.shape[1:]),
                layers)
            shared = kv_stack((g,), w)
    elif cfg.lsh_attention:
        layers = LSHKVCache(
            k=jnp.zeros((l, batch, w, kv, hd), dt),
            v=jnp.zeros((l, batch, w, kv, hd), dt),
            codes=jnp.zeros((l, batch, w, kv), jnp.int32))
    else:
        layers = kv_stack((l,), w)

    enc_kv = enc_pos = None
    if cfg.encoder_decoder:
        t = cfg.encoder_seq
        enc_kv = (jnp.zeros((l, batch, t, kv, hd), dt),
                  jnp.zeros((l, batch, t, kv, hd), dt))
        enc_pos = jnp.zeros((batch, t), jnp.int32)
    return DecodeCache(pos=pos, layers=layers, shared=shared,
                       enc_kv=enc_kv, enc_pos=enc_pos)


def cache_axes(cfg: ModelConfig) -> DecodeCache:
    """Logical sharding axes matching init_cache's structure."""
    kvc = KVCache(k=(None, "batch", "kv_seq", "kv_heads", None),
                  v=(None, "batch", "kv_seq", "kv_heads", None))
    shared = None
    if cfg.block in ("ssm", "hybrid"):
        layers = SSMCache(
            state=(None, "batch", "ssm_heads", None, None),
            conv=(None, "batch", None, "ssm_inner"))
        if cfg.block == "hybrid":
            layers = SSMCache(state=(None,) + layers.state,
                              conv=(None,) + layers.conv)
            shared = kvc
    elif cfg.lsh_attention:
        layers = LSHKVCache(k=kvc.k, v=kvc.v,
                            codes=(None, "batch", "kv_seq", "kv_heads"))
    else:
        layers = kvc
    enc_kv = enc_pos = None
    if cfg.encoder_decoder:
        enc_kv = ((None, "batch", "frames", "kv_heads", None),) * 2
        enc_pos = ("batch", "frames")
    return DecodeCache(pos=(None,), layers=layers, shared=shared,
                       enc_kv=enc_kv, enc_pos=enc_pos)


# ---------------------------------------------------------------------------
# Prefill & decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Run the full prompt, return (last-position logits, filled cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    logits, kv, _ = forward(cfg, params, batch, collect_kv=True)
    cache = init_cache(cfg, b, max_len)
    w = cache.pos.shape[0]

    def ring_place(stack, width):
        """Last `width` positions of (L,B,S,...) -> ring-aligned (L,B,W,...).

        slot = pos %% w over the trailing positions is a pure rotation, so
        this is pad+roll — static ops only. A fancy-index scatter here would
        hit the sharded-indexed-dim SPMD pathology on seq-sharded caches
        (u32 index blow-up; EXPERIMENTS.md §Perf iteration 12).
        """
        take = min(s, width)
        vals = stack[:, :, -take:]
        if take < width:
            pad_widths = [(0, 0)] * vals.ndim
            pad_widths[2] = (0, width - take)
            vals = jnp.pad(vals, pad_widths)
        return jnp.roll(vals, (s - take) % width, axis=2)

    def fill_kv(c: KVCache, new: KVCache) -> KVCache:
        return KVCache(k=ring_place(new.k, w), v=ring_place(new.v, w))

    layers = cache.layers
    shared = cache.shared
    if cfg.block in ("ssm", "hybrid"):
        m_kv, s_kv = (kv if cfg.block == "hybrid" else (kv, None))
        layers = m_kv  # SSMCache stacks: final states from prefill
        if cfg.block == "hybrid":
            shared = fill_kv(cache.shared, s_kv)
    elif cfg.lsh_attention:
        layers = LSHKVCache(k=ring_place(kv.k, w), v=ring_place(kv.v, w),
                            codes=ring_place(kv.codes, w))
    else:
        layers = fill_kv(cache.layers, kv)

    take = min(s, w)
    pos_arr = cache.pos.at[jnp.arange(s - take, s) % w].set(
        jnp.arange(s - take, s, dtype=jnp.int32))
    enc_kv = enc_pos = None
    if cfg.encoder_decoder:
        enc_out, enc_pos = run_encoder(cfg, params, batch["frames"])
        enc_kv = _dec_enc_kv(cfg, params, enc_out)
    cache = DecodeCache(pos=pos_arr, layers=layers, shared=shared,
                        enc_kv=enc_kv, enc_pos=enc_pos)
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, token, cache: DecodeCache,
                cur_pos):
    """One decode step. token (B, 1) int32; cur_pos scalar int32.
    Returns (logits (B, V), new cache)."""
    b = token.shape[0]
    x = embed_tokens(cfg, params, token)
    if cfg.encoder_decoder:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], cur_pos % params["dec_pos"].shape[0], 1)[None]
    positions = jnp.full((b, 1), cur_pos, jnp.int32)
    lsh_proj = params.get("lsh_proj")

    if cfg.block == "hybrid":
        period = cfg.shared_attn_period
        groups = cfg.n_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((groups, period) + a.shape[1:]),
            params["blocks"])
        shared = params["shared"]

        def group_body(h, per_group):
            gblocks, gmcache, gscache = per_group

            def inner(hh, per_layer):
                lp, lc = per_layer
                hh, nc, _ = decoder_layer(cfg, lp, hh, positions,
                                          layer_cache=lc, cur_pos=cur_pos)
                return hh, nc

            h, new_m = jax.lax.scan(inner, h, (gblocks, gmcache),
                                    unroll=True if cfg.scan_unroll else 1)
            delta, new_s = attention_block(
                cfg, shared, h, positions, causal=True,
                window=cfg.sliding_window, cache=gscache,
                cache_pos=cache.pos, cur_pos=cur_pos)
            h = h + delta + mlp(cfg, shared, h + delta)
            return h, (new_m, new_s)

        x, new_layers = jax.lax.scan(
            group_body, x, (blocks, cache.layers, cache.shared),
            unroll=True if cfg.scan_unroll else 1)
        new_cache_layers, new_shared = new_layers
    elif cfg.block == "attn_moe" and cfg.moe_every == 2:
        # in-place pair loop: cache updated in the carry (no ys double-buffer)
        dense_cfg = _dense_view(cfg)
        lm = cfg.n_layers // 2

        def pair_body(i, carry):
            h, lay = carry
            idx = lambda t, j: jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, j, keepdims=False), t)
            upd = lambda t, n_, j: jax.tree.map(
                lambda s, v: jax.lax.dynamic_update_index_in_dim(s, v, j, 0),
                t, n_)
            h, ncd, _ = decoder_layer(dense_cfg, idx(params["dense_blocks"], i),
                                      h, positions, layer_cache=idx(lay, 2 * i),
                                      cache_pos=cache.pos, cur_pos=cur_pos)
            lay = upd(lay, ncd, 2 * i)
            h, ncm, _ = decoder_layer(cfg, idx(params["blocks"], i), h,
                                      positions, layer_cache=idx(lay, 2 * i + 1),
                                      cache_pos=cache.pos, cur_pos=cur_pos)
            lay = upd(lay, ncm, 2 * i + 1)
            return (h, lay)

        x, new_cache_layers = jax.lax.fori_loop(
            0, lm, pair_body, (x, cache.layers),
            unroll=cfg.n_layers // 2 if cfg.scan_unroll else 1)
        new_shared = cache.shared
    else:
        # in-place layer loop: the cache is updated inside the while-loop
        # carry (dynamic_update_index), so XLA aliases one cache buffer
        # instead of the xs+ys pair a scan would double-buffer — halves
        # decode HBM on the KV-dominated cells (see EXPERIMENTS.md §Perf).
        blocks = params["blocks"]

        def body(i, carry):
            h, lay = carry
            idx = lambda t: jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), t)
            lp = idx(blocks)
            lc = idx(lay)
            lenc = idx(cache.enc_kv) if cache.enc_kv is not None else None
            h, nc, _ = decoder_layer(
                cfg, lp, h, positions, layer_cache=lc, cache_pos=cache.pos,
                cur_pos=cur_pos, enc_kv=lenc, enc_pos=cache.enc_pos,
                lsh_proj=lsh_proj)
            lay = jax.tree.map(
                lambda s, v: jax.lax.dynamic_update_index_in_dim(s, v, i, 0),
                lay, nc)
            return (h, lay)

        x, new_cache_layers = jax.lax.fori_loop(
            0, cfg.n_layers, body, (x, cache.layers),
            unroll=cfg.n_layers if cfg.scan_unroll else 1)
        new_shared = cache.shared

    logits = lm_logits(cfg, params, x)[:, 0]
    w = cache.pos.shape[0]
    new_pos = cache.pos.at[cur_pos % w].set(cur_pos)
    new_cache = DecodeCache(pos=new_pos, layers=new_cache_layers,
                            shared=new_shared, enc_kv=cache.enc_kv,
                            enc_pos=cache.enc_pos)
    return logits, new_cache
