"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) blocks.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like
intra-chunk term + a linear inter-chunk state recurrence (lax.scan over
chunks). Decode is the O(1) recurrent update on a (H, P, N) state. A
property test checks the chunked path equals the naive recurrence.

Layout: d_inner = expand * d_model, H = d_inner / head_dim heads, state dim
N per head, G groups for B/C (G=1 here). The conv is a causal depthwise
width-4 conv over the concatenated [x, B, C] streams, as in Mamba2.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import norm, rmsnorm


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N) f32
    conv: jax.Array   # (B, W-1, CH) — last conv_width-1 pre-activation inputs


def _head_or_chunk_axes(n_heads: int) -> tuple[str | None, str | None]:
    """(chunk_dim_name, head_dim_name) for sharding the SSD intra-chunk
    tensors: prefer sharding heads over the model axis; when the head count
    doesn't divide it, shard the chunk-index dim instead."""
    from repro.distributed import sharding as sh
    ctx = sh.current()
    if ctx is None:
        return None, None
    axes = ctx.rules.get("ssm_heads")
    if axes and n_heads % ctx.axis_size(axes) == 0:
        return None, "ssm_heads"
    return "chunks", None


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    din = cfg.d_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    x = xbc[..., :din]
    bmat = xbc[..., din:din + gs]
    cmat = xbc[..., din + gs:]
    sh = xbc.shape[:-1]
    x = x.reshape(sh + (cfg.ssm_heads, cfg.ssm_head_dim))
    bmat = bmat.reshape(sh + (cfg.ssm_groups, cfg.ssm_state))
    cmat = cmat.reshape(sh + (cfg.ssm_groups, cfg.ssm_state))
    return x, bmat, cmat


def _rep_groups(cfg: ModelConfig, m: jax.Array) -> jax.Array:
    """(..., G, N) -> (..., H, N) by repeating each group over its heads."""
    rep = cfg.ssm_heads // cfg.ssm_groups
    return jnp.repeat(m, rep, axis=-2)


def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,CH), w (W,CH) -> (B,S,CH)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4: cheap static unroll
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int, init_state=None):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) [post-softplus], a (H,) [negative],
    bmat/cmat (B,S,H,N) [already group-repeated]. Returns (y (B,S,H,P),
    final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    dtype = x.dtype
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    pad = (-s) % chunk
    if pad:  # zero dt => exp(0)=1 decay, zero input: padding is exact
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, h, n)
    cc = cmat.reshape(b, nc, chunk, h, n)
    # shard the big intra-chunk tensors: heads over "model" where divisible
    # (zamba2 H=112), else the chunk-index dim (mamba2-130m H=24 -> nc)
    hax = _head_or_chunk_axes(h)
    xc = shard(xc, "batch", hax[0], None, hax[1], None)
    dtc = shard(dtc, "batch", hax[0], None, hax[1])
    bc = shard(bc, "batch", hax[0], None, hax[1], None)
    cc = shard(cc, "batch", hax[0], None, hax[1], None)

    da = dtc * a  # (b, nc, q, h), negative
    cs = jnp.cumsum(da, axis=2)  # inclusive cumulative decay within chunk

    # ---- intra-chunk (masked attention-like term) ----
    # M[i, j] = (C_i . B_j) * exp(cs_i - cs_j) * dt_j   for i >= j
    scores = jnp.einsum("bcihn,bcjhn->bchij", cc, bc)
    scores = shard(scores, "batch", hax[0], hax[1], None, None)
    li = cs.transpose(0, 1, 3, 2)  # (b, nc, h, q)
    ldiff = li[..., :, None] - li[..., None, :]  # cs_i - cs_j
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask, jnp.exp(ldiff), 0.0)
    decay = shard(decay, "batch", hax[0], hax[1], None, None)
    m = scores * decay * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", m, xc)

    # ---- chunk states ----
    # S_c = sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j  -> (b, nc, h, p, n)
    w = jnp.exp(cs[:, :, -1:, :] - cs) * dtc  # (b, nc, q, h)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, bc, xc)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (b, nc, h)

    def scan_fn(carry, inp):
        st_c, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st_c
        return new, carry  # emit the state *entering* the chunk

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, nc, h, p, n)

    # ---- inter-chunk contribution ----
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         cc * jnp.exp(cs)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)[:, :s]
    return y.astype(dtype), final_state


def ssd_decode_step(state, x, dt, a, bmat, cmat):
    """O(1) recurrent update. state (B,H,P,N); x (B,H,P); dt (B,H);
    bmat/cmat (B,H,N). Returns (y (B,H,P), new_state)."""
    da = jnp.exp(dt * a)  # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bmat, x)
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", cmat, new_state)
    return y, new_state


def ssm_block(cfg: ModelConfig, lp: dict, x: jax.Array, *,
              cache: SSMCache | None = None):
    """Mamba2 block. Train/prefill: cache None, x (B,S,D).
    Decode: cache given, x (B,1,D). Returns (y, new_cache)."""
    b, s, d = x.shape
    h = norm(cfg, x, lp["ssm_ln"])
    xbc = jnp.einsum("bsd,dc->bsc", h, lp["w_xBC"])
    xbc = shard(xbc, "batch", "seq", "ssm_inner")
    z = jnp.einsum("bsd,dc->bsc", h, lp["w_z"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, lp["w_dt"])
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))

    new_cache = None
    if cache is None:
        conv_out = causal_conv(xbc, lp["conv_w"])
        xbc_act = jax.nn.silu(conv_out)
        xs, bm, cm = _split_xbc(cfg, xbc_act)
        bm = _rep_groups(cfg, bm)
        cm = _rep_groups(cfg, cm)
        y, final_state = ssd_chunked(xs, dt, a, bm, cm, cfg.ssm_chunk)
        wminus1 = cfg.conv_width - 1
        tail = xbc[:, -wminus1:, :] if s >= wminus1 else jnp.pad(
            xbc, ((0, 0), (wminus1 - s, 0), (0, 0)))
        new_cache = SSMCache(state=final_state, conv=tail)
        y = y + lp["ssm_D"].astype(jnp.float32)[None, None, :, None] * \
            xs.astype(jnp.float32)
    else:
        window = jnp.concatenate([cache.conv, xbc], axis=1)  # (B, W, CH)
        conv_out = jnp.einsum("bwc,wc->bc", window, lp["conv_w"])[:, None, :]
        xbc_act = jax.nn.silu(conv_out)
        xs, bm, cm = _split_xbc(cfg, xbc_act)
        bm = _rep_groups(cfg, bm)[:, 0]
        cm = _rep_groups(cfg, cm)[:, 0]
        x1 = xs[:, 0]
        y1, new_state = ssd_decode_step(
            cache.state, x1.astype(jnp.float32), dt[:, 0], a,
            bm.astype(jnp.float32), cm.astype(jnp.float32))
        y1 = y1 + lp["ssm_D"].astype(jnp.float32)[None, :, None] * \
            x1.astype(jnp.float32)
        y = y1[:, None]
        new_cache = SSMCache(state=new_state, conv=window[:, 1:])
        xs = x1[:, None]

    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), lp["norm_z"])
    out = jnp.einsum("bsc,cd->bsd", y, lp["out_proj"])
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_channels),
                       jnp.dtype(cfg.dtype)),
    )
