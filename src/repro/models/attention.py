"""Attention: GQA/MHA/MQA with RoPE, flash-style chunked softmax for
train/prefill, ring-buffer sliding-window KV caches, and cache decode.

Memory discipline: train/prefill never materializes (S, T) score matrices —
a lax.scan over KV chunks carries the online-softmax state (m, l, acc), so
activation memory is O(S * kv_chunk) per head group. Sliding-window archs
(mixtral) keep only window-sized ring caches, which is what makes their
long_500k decode cell feasible.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import norm, rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-layer decode cache (stacked over layers by the caller)."""
    k: jax.Array  # (B, W, KV, hd)
    v: jax.Array  # (B, W, KV, hd)


def qkv_proj(cfg: ModelConfig, lp: dict, x: jax.Array, positions, pre: str = ""):
    """x (B,S,D) -> q (B,S,H,hd), k,v (B,S,KV,hd), roped."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dq->bsq", x, lp[pre + "wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dq->bsq", x, lp[pre + "wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dq->bsq", x, lp[pre + "wv"]).reshape(b, s, kv, hd)
    if cfg.use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                      window: int = 0, kv_chunk: int = 512) -> jax.Array:
    """Flash-style attention. q (B,S,H,hd); k,v (B,T,KV,hd);
    q_pos (B,S) / k_pos (B,T) int32, padded k positions = -1.

    GQA layout note: the query head dim is kept INTACT (never reshaped to
    (kv, group)) so the TP sharding on H survives; KV heads are repeated to
    H per chunk instead — an (B, C, H, hd) chunk-sized copy, H-sharded,
    versus an unshardable (H -> kv x g) reshape that would replicate the
    (B, S, H, C) score tensor on every chip (a 64 GiB/step mistake on
    mistral-large; see EXPERIMENTS.md §Perf).
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qf = shard(q.astype(jnp.float32) * scale, "batch", None, "heads", None)

    pad = (-t) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = k.shape[1] // kv_chunk
    kc = jnp.moveaxis(k.reshape(b, n_chunks, kv_chunk, kvh, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, kv_chunk, kvh, hd), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, n_chunks, kv_chunk), 1, 0)

    def _rep(x):  # (B, C, KV, hd) -> (B, C, H, hd), H-sharded
        if g > 1:
            x = jnp.repeat(x, g, axis=2)
        return shard(x, "batch", None, "heads", None)

    @jax.checkpoint  # backward recomputes sc/p per chunk: the stacked
    # (chunks, B, S, H, C) f32 probability saves otherwise dominate
    # big-dense train memory (6+ GiB/chip on mistral-large; §Perf)
    def body(carry, chunk):
        m, l, acc = carry
        kcj, vcj, kpj = chunk
        kr = _rep(kcj.astype(jnp.float32))
        vr = _rep(vcj.astype(jnp.float32))
        sc = jnp.einsum("bshd,bchd->bshc", qf, kr)
        sc = shard(sc, "batch", None, "heads", None)
        valid = kpj[:, None, :] >= 0                      # (B, 1, C)
        if causal:
            valid &= kpj[:, None, :] <= q_pos[:, :, None]
        if window:
            valid &= (q_pos[:, :, None] - kpj[:, None, :]) < window
        sc = jnp.where(valid[:, :, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bshc,bchd->bshd", p, vr)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, h), jnp.float32)
    acc0 = jnp.zeros((b, s, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, cache_k, cache_v, cache_pos, cur_pos, *,
                     window: int = 0) -> jax.Array:
    """One-token attention over a (ring) cache.
    q (B,1,H,hd); cache_k/v (B,W,KV,hd); cache_pos (W,) int32 (-1 = empty)."""
    b, _, h, hd = q.shape
    w, kvh = cache_k.shape[1], cache_k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32) * scale
    sc = jnp.einsum("bkgh,bwkh->bkgw", qg, cache_k.astype(jnp.float32))
    valid = (cache_pos >= 0) & (cache_pos <= cur_pos)
    if window:
        valid &= (cur_pos - cache_pos) < window
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgw,bwkh->bkgh", p, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def cache_window(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def write_cache(cache: KVCache, k, v, cur_pos) -> KVCache:
    """Write one decoded token's k/v at slot cur_pos % W (ring buffer)."""
    w = cache.k.shape[1]
    slot = cur_pos % w
    return KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1),
    )


def attention_block(cfg: ModelConfig, lp: dict, x, positions, *,
                    causal: bool = True, window: int = 0,
                    cache: KVCache | None = None, cache_pos=None,
                    cur_pos=None, pre: str = ""):
    """Pre-norm attention sub-block. Returns (residual_delta, new_cache).

    Train/prefill: cache is None -> chunked flash attention over the batch.
    Decode: cache given, x is (B, 1, D) -> ring-buffer decode.
    """
    h = norm(cfg, x, lp[pre + "ln"])
    q, k, v = qkv_proj(cfg, lp, h, positions, pre=pre)
    if cache is None:
        out = chunked_attention(q, k, v, positions, positions,
                                causal=causal, window=window)
        # caller slices into its cache window; constrain like the cache so
        # prefill's collected (L,B,S,KV,hd) stacks shard (kv_seq rule)
        new_cache = KVCache(shard(k, "batch", "kv_seq", "kv_heads", None),
                            shard(v, "batch", "kv_seq", "kv_heads", None))
    else:
        out = decode_attention(q, cache.k, cache.v, cache_pos, cur_pos,
                               window=window)
        new_cache = write_cache(cache, k, v, cur_pos)
    out = shard(out, "batch", "seq", "heads", None)
    b, s = out.shape[0], out.shape[1]
    y = jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), lp[pre + "wo"])
    return y, new_cache


def cross_attention_block(cfg: ModelConfig, lp: dict, x, enc_k, enc_v,
                          enc_pos):
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    h = norm(cfg, x, lp["xln"])
    b, s, _ = x.shape
    hh, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dq->bsq", h, lp["xwq"]).reshape(b, s, hh, hd)
    out = chunked_attention(q, enc_k, enc_v,
                            jnp.zeros((b, s), jnp.int32), enc_pos,
                            causal=False)
    y = jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), lp["xwo"])
    return y


def encode_kv(cfg: ModelConfig, lp: dict, enc_out: jax.Array):
    """Project encoder output to cross-attention K/V once (cached)."""
    b, t, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = jnp.einsum("bsd,dq->bsq", enc_out, lp["xwk"]).reshape(b, t, kv, hd)
    v = jnp.einsum("bsd,dq->bsq", enc_out, lp["xwv"]).reshape(b, t, kv, hd)
    return k, v
