"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

Perf-hillclimb cell #1 (biggest dense model; FSDP + TP).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768,
    act="swiglu", norm="rmsnorm",
).validate()

SMOKE = ModelConfig(
    name="mistral-large-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab_size=256,
    act="swiglu", norm="rmsnorm", dtype="float32",
).validate()
