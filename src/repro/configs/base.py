"""ModelConfig: one dataclass drives every assigned architecture."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    act: str = "swiglu"               # swiglu | geglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    use_rope: bool = True
    scale_embed: bool = False         # gemma: embeddings * sqrt(d_model)
    tie_embeddings: bool = False

    block: str = "attn_dense"         # attn_dense | attn_moe | ssm | hybrid
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    moe_every: int = 1            # 2 = alternate dense/MoE layers (llama4)
    d_ff_dense: int = 0           # FFN width of the interleaved dense layers
    # SSM / Mamba2 (SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (zamba2): one shared attn+mlp block applied every `period` layers
    shared_attn_period: int = 0
    # attention
    sliding_window: int = 0           # 0 = full attention
    # LSH attention (the paper's CP-SRP applied to long context)
    lsh_attention: bool = False
    lsh_num_hashes: int = 8           # SRP bits -> 2^bits buckets
    lsh_rank: int = 2                 # CP rank R of the projection tensors
    lsh_chunk: int = 512              # bucket-chunk size (prefill)
    lsh_candidates: int = 1024        # candidate set size (decode)
    lsh_recent: int = 128             # always-attended recency window (decode)
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0              # audio frames after the (stubbed) conv frontend
    # multimodal stub (pixtral): precomputed patch embeddings prepended
    vision_tokens: int = 0

    dtype: str = "bfloat16"
    remat_policy: str = "nothing"     # nothing | dots | none  (see transformer._remat)
    scan_unroll: bool = False         # dry-run aux: unroll layer scans so
                                      # cost_analysis counts every layer

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Embedding/LM-head vocab padded to a multiple of 256 so the vocab
        dim always shards over the model axis (whisper's 51865 / mamba2's
        50280 otherwise replicate the (B,S,V) loss tensors — a 13 GiB/chip
        bug caught by the dry-run). Labels never reference padded ids."""
        return -(-self.vocab_size // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_channels(self) -> int:
        # mamba2 convolves the concatenated [x, B, C] streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def is_ssm_block(self) -> bool:
        return self.block in ("ssm", "hybrid")

    @property
    def active_params_per_token_experts(self) -> int:
        """Experts actually touched per token (top_k + shared)."""
        return (self.top_k + self.n_shared_experts) if self.n_experts else 0

    def validate(self) -> "ModelConfig":
        assert self.n_layers > 0 and self.d_model > 0
        if self.block == "attn_moe":
            assert self.n_experts > 0 and self.top_k > 0
            if self.moe_every == 2:
                assert self.n_layers % 2 == 0 and self.d_ff_dense > 0
            else:
                assert self.moe_every == 1
        if self.block in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.block == "hybrid":
            assert self.shared_attn_period > 0
            assert self.n_layers % self.shared_attn_period == 0
        if self.encoder_decoder:
            assert self.n_encoder_layers > 0 and self.encoder_seq > 0
        return self
