"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b; unverified]. LayerNorm + full-head GQA
(kv=32 == MHA). Published model uses partial rotary (25%); we apply full
rotary and record the approximation in DESIGN.md.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    act="swiglu", norm="layernorm",
).validate()

SMOKE = ModelConfig(
    name="stablelm-3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    act="swiglu", norm="layernorm", dtype="float32",
).validate()
