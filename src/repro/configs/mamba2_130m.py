"""mamba2-130m [ssm] — 24L d_model=768 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

Pure Mamba2: d_inner=1536, 24 SSD heads of dim 64, constant-size state ->
long_500k decode is O(1) per token. Attention-LSH is inapplicable
(attention-free; DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,  # unused (attn-free)
    d_ff=0, vocab_size=50280,
    block="ssm", tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1, ssm_chunk=256,
).validate()

SMOKE = ModelConfig(
    name="mamba2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=256,
    block="ssm", tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_groups=1, ssm_chunk=8,
    dtype="float32",
).validate()
