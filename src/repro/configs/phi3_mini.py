"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

`LONG_CONTEXT` is the long_500k variant with the paper's CP-SRP LSH
attention enabled (phi3 is otherwise pure full attention and would skip
that cell — see DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    act="swiglu", norm="rmsnorm",
).validate()

LONG_CONTEXT = ModelConfig(
    name="phi3-mini-3.8b-lsh",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    act="swiglu", norm="rmsnorm",
    lsh_attention=True, lsh_num_hashes=8, lsh_rank=2,
    lsh_chunk=512, lsh_candidates=2048, lsh_recent=128,
).validate()

SMOKE = ModelConfig(
    name="phi3-mini-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    act="swiglu", norm="rmsnorm", dtype="float32",
    lsh_attention=True, lsh_num_hashes=4, lsh_rank=2,
    lsh_chunk=16, lsh_candidates=32, lsh_recent=8,
).validate()
