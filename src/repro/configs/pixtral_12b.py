"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo [hf:mistralai/Pixtral-12B-2409].

The ViT frontend is a STUB per the assignment: input_specs() provides 1024
precomputed patch embeddings that replace the first 1024 token positions
(early fusion); the loss masks image positions.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    act="swiglu", norm="rmsnorm",
    vision_tokens=1024,
).validate()

SMOKE = ModelConfig(
    name="pixtral-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    act="swiglu", norm="rmsnorm",
    vision_tokens=8, dtype="float32",
).validate()
