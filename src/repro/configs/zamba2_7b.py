"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

We model 81 Mamba2 layers with ONE weight-shared attention+MLP block
applied every 9 layers (9 applications); the published model interleaves
two shared blocks with LoRA specialization — same compute pattern, see
DESIGN.md. SSM state is per-arch (64); long_500k runs natively.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    act="swiglu", norm="rmsnorm",
    block="hybrid", shared_attn_period=9,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1, ssm_chunk=256,
).validate()

SMOKE = ModelConfig(
    name="zamba2-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    act="swiglu", norm="rmsnorm",
    block="hybrid", shared_attn_period=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_groups=1, ssm_chunk=8,
    dtype="float32",
).validate()
