"""whisper-tiny [audio] — 4L d_model=384 6H (GQA kv=6) d_ff=1536
vocab=51865 — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

4 encoder + 4 decoder layers, LayerNorm, GELU, learned positions (no RoPE).
The conv frontend is a STUB: input_specs() provides 1500 precomputed frame
embeddings (30 s of audio). Decode cells drive the decoder to the assigned
lengths mechanically (32k decode is not a natural Whisper workload).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    act="gelu", norm="layernorm", use_rope=False,
    encoder_decoder=True, n_encoder_layers=4, encoder_seq=1500,
).validate()

SMOKE = ModelConfig(
    name="whisper-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    act="gelu", norm="layernorm", use_rope=False,
    encoder_decoder=True, n_encoder_layers=2, encoder_seq=32,
    dtype="float32",
).validate()
