"""Architecture registry: --arch <id> -> (full config, smoke config).

All 10 assigned architectures plus the paper's own LSH-service workload.
"""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "gemma-7b": "repro.configs.gemma_7b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini",
    "mistral-large-123b": "repro.configs.mistral_large",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_IDS = tuple(_MODULES)

# archs with a sub-quadratic long-context path (DESIGN.md §Arch-applicability)
LONG_CONTEXT_ARCHS = {
    "zamba2-7b": "ssm-hybrid (constant state + windowed shared-attn KV)",
    "mamba2-130m": "ssm (constant state)",
    "mixtral-8x22b": "sliding-window attention (ring KV cache)",
    "phi3-mini-3.8b": "CP-SRP LSH attention variant (the paper's technique)",
}


def get_config(arch: str, variant: str = "full") -> ModelConfig:
    """variant: 'full' | 'smoke' | 'long' (long_500k-capable variant)."""
    mod = importlib.import_module(_MODULES[arch])
    if variant == "smoke":
        return mod.SMOKE
    if variant == "long":
        if hasattr(mod, "LONG_CONTEXT"):
            return mod.LONG_CONTEXT
        return mod.CONFIG
    return mod.CONFIG


def supports_long_context(arch: str) -> bool:
    return arch in LONG_CONTEXT_ARCHS
