"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf].

Sliding window 4096 (per the assignment's SWA note) -> long_500k runs with a
window-sized ring KV cache. 8 experts on a 16-way model axis do not divide
-> the sharding fallback yields tensor-parallel experts (see models/moe.py).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    act="swiglu", norm="rmsnorm",
    block="attn_moe", n_experts=8, top_k=2, capacity_factor=1.25,
    sliding_window=4096,
).validate()

SMOKE = ModelConfig(
    name="mixtral-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    act="swiglu", norm="rmsnorm",
    block="attn_moe", n_experts=4, top_k=2, capacity_factor=1.5,
    sliding_window=16, dtype="float32",
).validate()
