"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + 1 shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Text backbone only (listed as [moe]); interleaved RoPE/NoPE simplified to
RoPE everywhere (DESIGN.md). MoE layers alternate with dense layers
(d_ff 16384), as in the published model — that is what makes the totals
400B/17B-active work out from d_ff=8192 x 128 experts. 128 experts divide
the 16-way model axis -> true expert parallelism. Perf-hillclimb cell #2
(MoE dispatch collectives).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    act="swiglu", norm="rmsnorm",
    block="attn_moe", n_experts=128, top_k=1, n_shared_experts=1,
    capacity_factor=1.25, moe_every=2, d_ff_dense=16384,
).validate()

SMOKE = ModelConfig(
    name="llama4-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    act="swiglu", norm="rmsnorm",
    block="attn_moe", n_experts=8, top_k=1, n_shared_experts=1,
    capacity_factor=1.5, moe_every=2, d_ff_dense=128, dtype="float32",
).validate()
