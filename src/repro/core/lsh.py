"""The paper's four LSH families (Definitions 10-13) + naive baselines.

  CP-E2LSH (Def. 10):  g(X)  = floor((<P, X> + b) / w),  P ~ CP_Rad(R)
  TT-E2LSH (Def. 11):  g~(X) = floor((<T, X> + b) / w),  T ~ TT_Rad(R)
  CP-SRP   (Def. 12):  h(X)  = sign(<P, X>),             P ~ CP_Rad(R)
  TT-SRP   (Def. 13):  h~(X) = sign(<T, X>),             T ~ TT_Rad(R)

plus the naive baselines the paper compares against:

  E2LSH (Datar et al. [11], Def. 3): dense Gaussian projection + floor
  SRP   (Charikar [6], Def. 2):      dense Gaussian projection + sign

A family carries K x L hash functions (K concatenated codes per table,
L tables — the standard (K, L) LSH amplification).

Hashing is batch-native: ``hash_batch`` maps a (B, ...) input batch to
(B, L, K) integer codes as ONE fused program — batched projection
contractions -> discretization (floor-quantize / sign) — and ``hash_keys``
additionally fuses the uint32 radix code-combine, going straight to the
(B, L) bucket keys the indexes probe with. ``hash(x)`` is the batch-of-1
case. Which backend evaluates the fused program is controlled by the
``hash_backend`` knob:

  * ``"xla"``    — the explicit batched einsum contractions of
                   ``repro.core.projections.project_batch`` plus the jnp
                   discretize/combine tail, fused by jit.
  * ``"pallas"`` — the batch-native Pallas kernels in ``repro.kernels``
                   (CP Gram / TT chain with the discretize + combine
                   epilogues fused in-kernel), for CP-format inputs under
                   CP projections and TT-format inputs under TT
                   projections with equal mode dims; other combinations
                   fall back to the XLA path. Codes are bit-identical
                   across backends (pinned by tests/test_hash_backends.py).
  * ``"auto"``   — the ``REPRO_HASH_BACKEND`` env var if set (read at
                   trace time), else pallas on TPU and xla elsewhere.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projections as proj_lib
from repro.core.projections import (CPProjection, DenseProjection, Projection,
                                    TTProjection)
from repro.core.tensor_formats import CPTensor, TTTensor

E2LSH_KINDS = ("cp-e2lsh", "tt-e2lsh", "e2lsh")
SRP_KINDS = ("cp-srp", "tt-srp", "srp")
ALL_KINDS = E2LSH_KINDS + SRP_KINDS
HASH_BACKENDS = ("auto", "xla", "pallas")


def e2lsh_discretize(values: jax.Array, b: jax.Array, w: float) -> jax.Array:
    """floor((v + b) / w) -> int32 hashcode (paper Eq. 3.3 / 4.1 / 4.20)."""
    return jnp.floor((values + b) / w).astype(jnp.int32)


def srp_discretize(values: jax.Array) -> jax.Array:
    """sign(v) in {0, 1} (paper Eq. 3.1 / 4.34 / 4.61): 1 iff v > 0."""
    return (values > 0).astype(jnp.int32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack {0,1} int codes along the last axis into uint32 words (pad 0)."""
    k = bits.shape[-1]
    pad = (-k) % 32
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (-1, 32)).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_bits, truncated back to K bits."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,))[..., :k].astype(jnp.int32)


def _combine_codes(codes, mults):
    """(..., L, K) int codes -> (..., L) uint32 bucket keys.

    sum_k codes[k] * mults[k] in uint32 arithmetic. Distinct per-position
    multipliers make the key permutation-sensitive; the mod-2^32 wraparound
    is identical between numpy (host tables) and jnp (device tables), and
    int32 codes of any magnitude cast to uint32 without overflow errors.
    """
    xp = jnp if isinstance(codes, jax.Array) else np
    prods = codes.astype(xp.uint32) * xp.asarray(mults).astype(xp.uint32)
    return prods.sum(axis=-1, dtype=xp.uint32)


def make_mults(seed: int, num_codes: int) -> np.ndarray:
    """Per-position odd uint32 multipliers for the universal bucket hash."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 32, size=(num_codes,), dtype=np.uint32) | 1


def _batched_dims(xs) -> tuple[int, ...]:
    """Mode dims of a batched input pytree (leading B axis on every leaf)."""
    if isinstance(xs, CPTensor):
        return tuple(f.shape[-2] for f in xs.factors)
    if isinstance(xs, TTTensor):
        return tuple(c.shape[-2] for c in xs.cores)
    return tuple(xs.shape[1:])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LSHFamily:
    """A (K, L)-amplified LSH family of one of the six kinds.

    The underlying projection holds K*L stacked projection tensors; `offsets`
    (E2LSH only) holds the b ~ U[0, w] per hash function. ``hash_backend``
    picks the fused-hash evaluation path (see the module docstring).
    """

    projection: Projection
    offsets: jax.Array | None            # (L*K,) or None for SRP kinds
    kind: str = dataclasses.field(metadata=dict(static=True))
    num_codes: int = dataclasses.field(metadata=dict(static=True))    # K
    num_tables: int = dataclasses.field(metadata=dict(static=True))   # L
    bucket_width: float = dataclasses.field(default=0.0, metadata=dict(static=True))
    hash_backend: str = dataclasses.field(default="auto",
                                          metadata=dict(static=True))

    # -- backend dispatch ----------------------------------------------------

    def resolved_backend(self) -> str:
        """'xla' or 'pallas': the explicit knob, else the REPRO_HASH_BACKEND
        env var (read at trace time), else pallas on TPU / xla elsewhere."""
        b = self.hash_backend
        if b == "auto":
            b = os.environ.get("REPRO_HASH_BACKEND", "").strip().lower() or "auto"
        if b == "auto":
            from repro.kernels.ops import on_tpu
            b = "pallas" if on_tpu() else "xla"
        if b not in ("xla", "pallas"):
            raise ValueError(
                f"hash_backend must be one of {HASH_BACKENDS}, got {b!r}")
        return b

    def _kernel_supported(self, xs) -> bool:
        """The Pallas kernels cover CP-format inputs under CP projections and
        TT-format inputs under TT projections, with equal mode dims (the
        stacked kernel layout); everything else serves through XLA."""
        p = self.projection
        if not ((isinstance(p, CPProjection) and isinstance(xs, CPTensor)) or
                (isinstance(p, TTProjection) and isinstance(xs, TTTensor))):
            return False
        dims = p.dims
        return len(set(dims)) == 1 and _batched_dims(xs) == tuple(dims)

    def _use_pallas(self, xs) -> bool:
        return self.resolved_backend() == "pallas" and self._kernel_supported(xs)

    # -- fused batch-native hashing ------------------------------------------

    def _discretize(self, values: jax.Array) -> jax.Array:
        """(B, L*K) raw values -> (B, L, K) int32 codes."""
        if self.kind in E2LSH_KINDS:
            codes = e2lsh_discretize(values, self.offsets, self.bucket_width)
        else:
            codes = srp_discretize(values)
        return codes.reshape(values.shape[0], self.num_tables, self.num_codes)

    def raw_projections(self, x) -> jax.Array:
        """(L*K,) raw <P_k, X> values."""
        return proj_lib.project(self.projection, x)

    def hash_batch_aux(self, xs) -> tuple[jax.Array, jax.Array]:
        """(codes (B, L, K) int32, aux (B, L, K) float32) for multi-probe.

        ``aux`` is the per-code perturbation evidence the query-directed
        expansion in ``repro.core.probing`` ranks by: the floor residual
        (v + b) / w - floor((v + b) / w) in [0, 1) for E2LSH kinds, and the
        raw projection value v (sign = the bit, |v| = the margin) for SRP
        kinds. Always evaluated through the XLA projection path — codes are
        pinned bit-identical across hash backends (tests/test_hash_backends
        .py), so the expansion composes with any ``hash_backend``.
        """
        values = proj_lib.project_batch(self.projection, xs)
        codes = self._discretize(values)
        if self.kind in E2LSH_KINDS:
            t = (values + self.offsets) / self.bucket_width
            aux = t.reshape(codes.shape) - codes.astype(values.dtype)
        else:
            aux = values.reshape(codes.shape)
        return codes, aux

    def hash_batch(self, xs) -> jax.Array:
        """(B, L, K) int32 codes for a batch of tensors, as one fused
        projection -> discretize program (no per-example vmap)."""
        if self._use_pallas(xs):
            from repro.kernels import ops
            return ops.fused_hash(xs, self.projection, epilogue="codes",
                                  kind=self.kind, num_tables=self.num_tables,
                                  num_codes=self.num_codes,
                                  offsets=self.offsets, w=self.bucket_width)
        return self._discretize(proj_lib.project_batch(self.projection, xs))

    def hash_keys(self, xs, mults) -> jax.Array:
        """(B, L) uint32 bucket keys: projection -> discretize -> uint32
        radix combine, fused end to end. ``mults`` is the (K,) uint32
        multiplier vector of the universal bucket hash (see make_mults);
        bit-identical to ``_combine_codes(self.hash_batch(xs), mults)``."""
        if self._use_pallas(xs):
            from repro.kernels import ops
            return ops.fused_hash(xs, self.projection, epilogue="keys",
                                  kind=self.kind, num_tables=self.num_tables,
                                  num_codes=self.num_codes,
                                  offsets=self.offsets, w=self.bucket_width,
                                  mults=mults)
        return _combine_codes(self._discretize(
            proj_lib.project_batch(self.projection, xs)), mults)

    def hash_packed_batch(self, xs) -> jax.Array:
        """SRP only: (B, L, ceil(K/32)) uint32 packed signatures (sign +
        bit-pack fused)."""
        if self.kind not in SRP_KINDS:
            raise ValueError("hash_packed is defined for SRP kinds only")
        if self._use_pallas(xs):
            from repro.kernels import ops
            return ops.fused_hash(xs, self.projection, epilogue="packed",
                                  kind=self.kind, num_tables=self.num_tables,
                                  num_codes=self.num_codes)
        return pack_bits(self._discretize(
            proj_lib.project_batch(self.projection, xs)))

    def hash(self, x) -> jax.Array:
        """Integer hashcodes, shape (L, K) — the batch-of-1 case."""
        return self.hash_batch(jax.tree.map(lambda a: a[None], x))[0]

    def hash_packed(self, x) -> jax.Array:
        """SRP only: (L, ceil(K/32)) uint32 packed signatures."""
        return self.hash_packed_batch(jax.tree.map(lambda a: a[None], x))[0]

    def storage_size(self) -> int:
        """Stored scalars for the projection parameters (paper Tables 1-2)."""
        return self.projection.storage_size()


def make_family(key: jax.Array, kind: str, dims: Sequence[int],
                num_codes: int = 8, num_tables: int = 1, rank: int = 4,
                bucket_width: float = 4.0, dist: str = "rademacher",
                hash_backend: str = "auto", dtype=jnp.float32) -> LSHFamily:
    """Construct any of the paper's families or the naive baselines.

    kind: 'cp-e2lsh' | 'tt-e2lsh' | 'cp-srp' | 'tt-srp' | 'e2lsh' | 'srp'.
    The naive kinds ('e2lsh', 'srp') always use Gaussian dense projections
    (Definitions 2-3); the tensorized kinds default to Rademacher entries
    (Definitions 6-7), with dist='gaussian' giving CP_N / TT_N variants.
    hash_backend: 'auto' | 'xla' | 'pallas' (see the module docstring).
    """
    if kind not in ALL_KINDS:
        raise ValueError(f"kind must be one of {ALL_KINDS}, got {kind!r}")
    if hash_backend not in HASH_BACKENDS:
        raise ValueError(
            f"hash_backend must be one of {HASH_BACKENDS}, got {hash_backend!r}")
    total = num_codes * num_tables
    kp, kb = jax.random.split(key)
    if kind.startswith("cp-"):
        p = proj_lib.sample_cp_projection(kp, total, dims, rank, dist=dist, dtype=dtype)
    elif kind.startswith("tt-"):
        p = proj_lib.sample_tt_projection(kp, total, dims, rank, dist=dist, dtype=dtype)
    else:
        p = proj_lib.sample_dense_projection(kp, total, dims, dist="gaussian", dtype=dtype)
    offsets = None
    if kind in E2LSH_KINDS:
        offsets = jax.random.uniform(kb, (total,), dtype, 0.0, bucket_width)
    return LSHFamily(projection=p, offsets=offsets, kind=kind,
                     num_codes=num_codes, num_tables=num_tables,
                     bucket_width=float(bucket_width),
                     hash_backend=hash_backend)


def naive_storage_size(dims: Sequence[int], num_codes: int, num_tables: int) -> int:
    """O(K d^N) scalars the naive method stores (paper Tables 1-2)."""
    return num_codes * num_tables * int(math.prod(dims))
