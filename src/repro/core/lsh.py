"""The paper's four LSH families (Definitions 10-13) + naive baselines.

  CP-E2LSH (Def. 10):  g(X)  = floor((<P, X> + b) / w),  P ~ CP_Rad(R)
  TT-E2LSH (Def. 11):  g~(X) = floor((<T, X> + b) / w),  T ~ TT_Rad(R)
  CP-SRP   (Def. 12):  h(X)  = sign(<P, X>),             P ~ CP_Rad(R)
  TT-SRP   (Def. 13):  h~(X) = sign(<T, X>),             T ~ TT_Rad(R)

plus the naive baselines the paper compares against:

  E2LSH (Datar et al. [11], Def. 3): dense Gaussian projection + floor
  SRP   (Charikar [6], Def. 2):      dense Gaussian projection + sign

A family carries K x L hash functions (K concatenated codes per table,
L tables — the standard (K, L) LSH amplification); `hash()` returns integer
codes of shape (L, K), and `hash_packed()` returns SRP bits packed into uint32
words for space-efficient storage.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import projections as proj_lib
from repro.core.projections import (CPProjection, DenseProjection, Projection,
                                    TTProjection)

E2LSH_KINDS = ("cp-e2lsh", "tt-e2lsh", "e2lsh")
SRP_KINDS = ("cp-srp", "tt-srp", "srp")
ALL_KINDS = E2LSH_KINDS + SRP_KINDS


def e2lsh_discretize(values: jax.Array, b: jax.Array, w: float) -> jax.Array:
    """floor((v + b) / w) -> int32 hashcode (paper Eq. 3.3 / 4.1 / 4.20)."""
    return jnp.floor((values + b) / w).astype(jnp.int32)


def srp_discretize(values: jax.Array) -> jax.Array:
    """sign(v) in {0, 1} (paper Eq. 3.1 / 4.34 / 4.61): 1 iff v > 0."""
    return (values > 0).astype(jnp.int32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack {0,1} int codes along the last axis into uint32 words (pad 0)."""
    k = bits.shape[-1]
    pad = (-k) % 32
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (-1, 32)).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_bits, truncated back to K bits."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,))[..., :k].astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LSHFamily:
    """A (K, L)-amplified LSH family of one of the six kinds.

    The underlying projection holds K*L stacked projection tensors; `offsets`
    (E2LSH only) holds the b ~ U[0, w] per hash function.
    """

    projection: Projection
    offsets: jax.Array | None            # (L*K,) or None for SRP kinds
    kind: str = dataclasses.field(metadata=dict(static=True))
    num_codes: int = dataclasses.field(metadata=dict(static=True))    # K
    num_tables: int = dataclasses.field(metadata=dict(static=True))   # L
    bucket_width: float = dataclasses.field(default=0.0, metadata=dict(static=True))

    def raw_projections(self, x) -> jax.Array:
        """(L*K,) raw <P_k, X> values."""
        return proj_lib.project(self.projection, x)

    def hash(self, x) -> jax.Array:
        """Integer hashcodes, shape (L, K)."""
        v = self.raw_projections(x)
        if self.kind in E2LSH_KINDS:
            codes = e2lsh_discretize(v, self.offsets, self.bucket_width)
        else:
            codes = srp_discretize(v)
        return codes.reshape(self.num_tables, self.num_codes)

    def hash_batch(self, xs) -> jax.Array:
        """(B, L, K) codes for a batch of tensors."""
        return jax.vmap(self.hash)(xs)

    def hash_packed(self, x) -> jax.Array:
        """SRP only: (L, ceil(K/32)) uint32 packed signatures."""
        if self.kind not in SRP_KINDS:
            raise ValueError("hash_packed is defined for SRP kinds only")
        return pack_bits(self.hash(x))

    def storage_size(self) -> int:
        """Stored scalars for the projection parameters (paper Tables 1-2)."""
        return self.projection.storage_size()


def make_family(key: jax.Array, kind: str, dims: Sequence[int],
                num_codes: int = 8, num_tables: int = 1, rank: int = 4,
                bucket_width: float = 4.0, dist: str = "rademacher",
                dtype=jnp.float32) -> LSHFamily:
    """Construct any of the paper's families or the naive baselines.

    kind: 'cp-e2lsh' | 'tt-e2lsh' | 'cp-srp' | 'tt-srp' | 'e2lsh' | 'srp'.
    The naive kinds ('e2lsh', 'srp') always use Gaussian dense projections
    (Definitions 2-3); the tensorized kinds default to Rademacher entries
    (Definitions 6-7), with dist='gaussian' giving CP_N / TT_N variants.
    """
    if kind not in ALL_KINDS:
        raise ValueError(f"kind must be one of {ALL_KINDS}, got {kind!r}")
    total = num_codes * num_tables
    kp, kb = jax.random.split(key)
    if kind.startswith("cp-"):
        p = proj_lib.sample_cp_projection(kp, total, dims, rank, dist=dist, dtype=dtype)
    elif kind.startswith("tt-"):
        p = proj_lib.sample_tt_projection(kp, total, dims, rank, dist=dist, dtype=dtype)
    else:
        p = proj_lib.sample_dense_projection(kp, total, dims, dist="gaussian", dtype=dtype)
    offsets = None
    if kind in E2LSH_KINDS:
        offsets = jax.random.uniform(kb, (total,), dtype, 0.0, bucket_width)
    return LSHFamily(projection=p, offsets=offsets, kind=kind,
                     num_codes=num_codes, num_tables=num_tables,
                     bucket_width=float(bucket_width))


def naive_storage_size(dims: Sequence[int], num_codes: int, num_tables: int) -> int:
    """O(K d^N) scalars the naive method stores (paper Tables 1-2)."""
    return num_codes * num_tables * int(math.prod(dims))
