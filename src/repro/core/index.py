"""Multi-table LSH indexes for approximate nearest-neighbour search.

The classic (K, L) construction on top of the paper's hash families:
L tables, each keyed by the combination of K hashcodes. Two deployments:

``DeviceLSHIndex`` (the default, exported as ``LSHIndex``) keeps the whole
index device-resident: build-time sorts the (L, n) uint32 bucket keys into
per-table sorted key arrays + permutation indices (all ``jax.Array``s), and
query-time is one jit-compiled program over a (B, ...) query batch —
vmapped ``searchsorted`` bucket lookup, bounded candidate gathering with
masking, and exact in-format re-rank via ``contractions``.

``HostLSHIndex`` is the FAISS-style host path (Python dict buckets, one
query at a time), kept for A/B comparison and as the semantics reference.

Layout of the device index (see ROADMAP.md "Device index layout"):

  sorted_keys : (L, n) uint32 — bucket keys of corpus items, sorted per table
  perm        : (L, n) int32  — corpus ids in the same sorted order
  cap         : static int    — max bucket members gathered per probe; the
                default is the largest bucket observed at build time, which
                makes device queries return exactly the host candidate set.
                A smaller explicit ``bucket_cap`` trades recall for speed by
                truncating oversized buckets (deterministically, in corpus
                order — the stable sort preserves insertion order).

Bucket keys are a universal multiply-add hash of the K integer hashcodes in
uint32 arithmetic (natural mod-2^32 wraparound) so the numpy host path and
the jnp device path produce bit-identical keys without requiring x64 mode.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contractions
from repro.core.lsh import LSHFamily


def _make_mults(seed: int, num_codes: int) -> np.ndarray:
    """Per-position odd uint32 multipliers for the universal bucket hash."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 32, size=(num_codes,), dtype=np.uint32) | 1


def _combine_codes(codes, mults):
    """(..., L, K) int codes -> (..., L) uint32 bucket keys.

    sum_k codes[k] * mults[k] in uint32 arithmetic. Distinct per-position
    multipliers make the key permutation-sensitive; the mod-2^32 wraparound
    is identical between numpy (host tables) and jnp (device tables), and
    int32 codes of any magnitude cast to uint32 without overflow errors.
    """
    xp = jnp if isinstance(codes, jax.Array) else np
    prods = codes.astype(xp.uint32) * xp.asarray(mults).astype(xp.uint32)
    return prods.sum(axis=-1, dtype=xp.uint32)


def _tree_index(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def _check_metric(metric: str) -> None:
    if metric not in ("euclidean", "cosine"):
        raise ValueError(metric)


@jax.jit
def _hash_batch(family, xs):
    return family.hash_batch(xs)


def _bucket_keys(family, mults, corpus, batch_size: int) -> jax.Array:
    """(n, L) uint32 bucket keys of the whole corpus, hashed in batches.

    The single source of build-time keys for both indexes — host tables are
    filled from np.asarray of this, keeping host/device keys bit-identical.
    """
    n = jax.tree.leaves(corpus)[0].shape[0]
    mults = jnp.asarray(mults)
    keys = []
    for start in range(0, n, batch_size):
        chunk = _tree_index(corpus, slice(start, min(start + batch_size, n)))
        keys.append(_combine_codes(_hash_batch(family, chunk), mults))
    return jnp.concatenate(keys, axis=0)


def _score_fn(metric: str):
    return (contractions.distance if metric == "euclidean"
            else contractions.cosine_similarity)


# ---------------------------------------------------------------------------
# Host index (reference semantics, kept for A/B)
# ---------------------------------------------------------------------------


@jax.jit
def _hash_one(family, x):
    return family.hash(x)


@dataclasses.dataclass
class HostLSHIndex:
    """Dict-of-buckets index: build once over a (stacked-pytree) corpus.

    corpus: any pytree whose leaves share a leading axis of size n —
    e.g. stacked CPTensor factors (n, d, R), stacked TT cores, or a dense
    (n, d_1, ..., d_N) array. Hashing runs batched on-device; bucket storage
    and probing are host-side Python dicts, one query at a time.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0

    corpus: Any = None
    size: int = 0
    _tables: list[dict[int, list[int]]] | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = _make_mults(self.seed, self.family.num_codes)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "HostLSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        all_keys = np.asarray(
            _bucket_keys(self.family, self._mults, corpus, batch_size))
        self._tables = [dict() for _ in range(self.family.num_tables)]
        for i in range(n):
            for t in range(self.family.num_tables):
                self._tables[t].setdefault(int(all_keys[i, t]), []).append(i)
        return self

    # -- query --------------------------------------------------------------

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over the L tables."""
        codes = np.asarray(_hash_one(self.family, x))[None]  # (1, L, K)
        keys = _combine_codes(codes, self._mults)[0]  # (L,)
        cand: set[int] = set()
        for t in range(self.family.num_tables):
            cand.update(self._tables[t].get(int(keys[t]), ()))
        return np.fromiter(cand, dtype=np.int64, count=len(cand))

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """-> (ids, scores, n_candidates). Exact re-rank of the candidates.

        scores are distances (ascending) for 'euclidean', similarities
        (descending) for 'cosine'.
        """
        cand = self.candidates(x)
        if cand.size == 0:
            return cand, np.empty(0, np.float32), 0
        sub = _tree_index(self.corpus, jnp.asarray(cand))
        scores = np.asarray(_score_batch(self.metric, x, sub))
        order = np.argsort(scores if self.metric == "euclidean" else -scores)
        order = order[:topk]
        return cand[order], scores[order], int(cand.size)


# ---------------------------------------------------------------------------
# Device index (sorted keys + permutation, fully batched queries)
# ---------------------------------------------------------------------------


def _max_run_length(sorted_keys: jax.Array) -> jax.Array:
    """Longest run of equal values along axis 1 of (L, n) sorted keys."""
    n = sorted_keys.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones(sorted_keys.shape[:1] + (1,), bool),
         sorted_keys[:, 1:] != sorted_keys[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(new_run, idx, 0), axis=1)
    return jnp.max(idx - run_start + 1)


def _gather_candidates(family, sorted_keys, perm, mults, queries, cap):
    """-> (cand (B, L*cap) int32 with -1 for invalid, valid (B, L*cap) bool).

    For each query and table: searchsorted into the sorted key array, gather
    the next `cap` positions, keep those still inside the bucket (same key),
    then sort + mask duplicates so each corpus id appears at most once.
    """
    n = sorted_keys.shape[1]
    codes = family.hash_batch(queries)                    # (B, L, K)
    keys = _combine_codes(codes, mults).T                 # (L, B)
    starts = jax.vmap(
        lambda sk, q: jnp.searchsorted(sk, q, side="left"))(sorted_keys, keys)
    pos = starts[:, :, None] + jnp.arange(cap, dtype=starts.dtype)  # (L, B, cap)
    in_range = pos < n
    posc = jnp.minimum(pos, n - 1)
    key_at = jax.vmap(lambda sk, p: sk[p])(sorted_keys, posc)
    hit = in_range & (key_at == keys[:, :, None])
    ids = jax.vmap(lambda pm, p: pm[p])(perm, posc)       # (L, B, cap)
    b = keys.shape[1]
    cand = jnp.where(hit, ids, n).transpose(1, 0, 2).reshape(b, -1)
    cand = jnp.sort(cand, axis=1)                         # invalid (=n) last
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
    valid = (cand < n) & ~dup
    return jnp.where(valid, cand, -1).astype(jnp.int32), valid


@functools.partial(jax.jit, static_argnames=("cap",))
def _device_candidates(family, sorted_keys, perm, mults, queries, *, cap):
    return _gather_candidates(family, sorted_keys, perm, mults, queries, cap)


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap"))
def _device_query(family, corpus, sorted_keys, perm, mults, queries, *,
                  metric, topk, cap):
    """One program from query batch to top-k: hash -> probe -> gather -> rank."""
    cand, valid = _gather_candidates(family, sorted_keys, perm, mults,
                                     queries, cap)
    n_cand = valid.sum(axis=1, dtype=jnp.int32)
    safe = jnp.where(valid, cand, 0)
    sub = _tree_index(corpus, safe)                       # leaves (B, C, ...)
    score = _score_fn(metric)
    scores = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: score(q, y))(ys))(queries, sub)
    bad = jnp.inf if metric == "euclidean" else -jnp.inf
    scores = jnp.where(valid, scores, bad)
    k = min(topk, cand.shape[1])
    _, sel = jax.lax.top_k(-scores if metric == "euclidean" else scores, k)
    ids = jnp.where(jnp.take_along_axis(valid, sel, axis=1),
                    jnp.take_along_axis(cand, sel, axis=1), -1)
    out_scores = jnp.take_along_axis(scores, sel, axis=1)
    if k < topk:
        ids = jnp.pad(ids, ((0, 0), (0, topk - k)), constant_values=-1)
        out_scores = jnp.pad(out_scores, ((0, 0), (0, topk - k)),
                             constant_values=bad)
    return ids, out_scores, n_cand


@dataclasses.dataclass
class DeviceLSHIndex:
    """Device-resident (K, L) index: sorted bucket keys + permutation per
    table, fully batched jit-compiled queries.

    corpus: any pytree whose leaves share a leading axis of size n. Query
    batches are pytrees with a leading batch axis B; `query_batch` returns
    (ids (B, topk) int32 with -1 fill, scores (B, topk), n_candidates (B,)).
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    bucket_cap: int | None = None  # None -> exact (largest build-time bucket)

    corpus: Any = None
    size: int = 0
    sorted_keys: jax.Array | None = None  # (L, n) uint32
    perm: jax.Array | None = None         # (L, n) int32
    cap: int = 0
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = _make_mults(self.seed, self.family.num_codes)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "DeviceLSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        all_keys = _bucket_keys(self.family, self._mults, corpus,
                                batch_size).T             # (L, n)
        self.perm = jnp.argsort(all_keys, axis=1, stable=True).astype(jnp.int32)
        self.sorted_keys = jnp.take_along_axis(all_keys, self.perm, axis=1)
        if self.bucket_cap is None:
            self.cap = int(_max_run_length(self.sorted_keys))
            if self.cap * self.family.num_tables > n:
                warnings.warn(
                    f"DeviceLSHIndex: largest bucket has {self.cap} of {n} "
                    f"items, so the exact default cap gathers up to "
                    f"L*cap={self.cap * self.family.num_tables} candidates "
                    "per query (more than the corpus). The family is too "
                    "coarse for this data; raise num_codes / shrink "
                    "bucket_width, or pass an explicit bucket_cap to bound "
                    "per-query work at some recall cost.")
        else:
            self.cap = min(int(self.bucket_cap), n)
        return self

    # -- query --------------------------------------------------------------

    def candidates_batch(self, queries) -> tuple[jax.Array, jax.Array]:
        """-> (cand (B, L*cap) int32 with -1 fill, valid (B, L*cap) bool)."""
        return _device_candidates(self.family, self.sorted_keys, self.perm,
                                  jnp.asarray(self._mults), queries,
                                  cap=self.cap)

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over the L tables (single query)."""
        cand, valid = self.candidates_batch(_tree_index(x, None))
        cand = np.asarray(cand[0])
        return cand[np.asarray(valid[0])].astype(np.int64)

    def query_batch(self, queries, topk: int = 10):
        """-> (ids (B, topk), scores (B, topk), n_candidates (B,)) jax arrays.

        Rows with fewer than topk candidates are filled with id -1 and
        +inf distance / -inf similarity. One jit-compiled program end-to-end.
        """
        return _device_query(self.family, self.corpus, self.sorted_keys,
                             self.perm, jnp.asarray(self._mults), queries,
                             metric=self.metric, topk=topk, cap=self.cap)

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """Single-query convenience wrapper; same contract as HostLSHIndex."""
        ids, scores, n_cand = self.query_batch(_tree_index(x, None), topk)
        ids = np.asarray(ids[0])
        mask = ids >= 0
        return (ids[mask].astype(np.int64), np.asarray(scores[0])[mask],
                int(n_cand[0]))


LSHIndex = DeviceLSHIndex  # default deployment


# ---------------------------------------------------------------------------
# References / evaluation
# ---------------------------------------------------------------------------


def _score_batch(metric: str, x, ys):
    return jax.vmap(lambda y: _score_fn(metric)(x, y))(ys)


def brute_force(metric: str, x, corpus, topk: int = 10):
    """Exact top-k over the whole corpus (recall reference)."""
    scores = np.asarray(_score_batch(metric, x, corpus))
    order = np.argsort(scores if metric == "euclidean" else -scores)[:topk]
    return order, scores[order]


def recall_at_k(index, queries, topk: int = 10) -> dict[str, float]:
    """Mean recall@k of index.query vs. brute force over a query batch.

    Works for both HostLSHIndex and DeviceLSHIndex (any object with the
    single-query `query` contract plus `metric`/`corpus`/`size`).
    """
    n_q = jax.tree.leaves(queries)[0].shape[0]
    hits, total, cand_total = 0, 0, 0
    for i in range(n_q):
        q = _tree_index(queries, i)
        truth, _ = brute_force(index.metric, q, index.corpus, topk)
        got, _, n_cand = index.query(q, topk)
        hits += len(set(truth.tolist()) & set(got.tolist()))
        total += topk
        cand_total += n_cand
    return {
        "recall": hits / max(total, 1),
        "mean_candidates": cand_total / max(n_q, 1),
        "corpus_size": index.size,
    }
