"""Multi-table LSH index for approximate nearest-neighbour search.

The classic (K, L) construction on top of the paper's hash families:
L tables, each keyed by the concatenation of K hashcodes. Hashing runs
batched in JAX (the paper's contribution); bucket storage is a host-side
table (as in FAISS-style deployments). Candidates are re-ranked with exact
in-format distances/similarities from `contractions`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contractions
from repro.core.lsh import LSHFamily, E2LSH_KINDS

_PRIME = (1 << 61) - 1


def _combine_codes(codes: np.ndarray, mults: np.ndarray) -> np.ndarray:
    """(..., L, K) int codes -> (..., L) uint64 bucket keys (universal hash)."""
    acc = (codes.astype(np.uint64) * mults.astype(np.uint64)).sum(axis=-1)
    return acc % np.uint64(_PRIME)


def _tree_index(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


@dataclasses.dataclass
class LSHIndex:
    """Build once over a (stacked-pytree) corpus, then query.

    corpus: any pytree whose leaves share a leading axis of size n —
    e.g. stacked CPTensor factors (n, d, R), stacked TT cores, or a dense
    (n, d_1, ..., d_N) array.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0

    corpus: Any = None
    size: int = 0
    _tables: list[dict[int, list[int]]] | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        if self.metric not in ("euclidean", "cosine"):
            raise ValueError(self.metric)
        rng = np.random.default_rng(self.seed)
        self._mults = rng.integers(
            1, _PRIME, size=(self.family.num_codes,), dtype=np.int64) | 1

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "LSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        hash_fn = jax.jit(self.family.hash_batch)
        keys = []
        for start in range(0, n, batch_size):
            chunk = _tree_index(corpus, slice(start, min(start + batch_size, n)))
            codes = np.asarray(hash_fn(chunk))  # (b, L, K)
            keys.append(_combine_codes(codes, self._mults))
        all_keys = np.concatenate(keys, axis=0)  # (n, L)
        self._tables = [dict() for _ in range(self.family.num_tables)]
        for i in range(n):
            for t in range(self.family.num_tables):
                self._tables[t].setdefault(int(all_keys[i, t]), []).append(i)
        return self

    # -- query --------------------------------------------------------------

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over the L tables."""
        codes = np.asarray(self.family.hash(x))[None]  # (1, L, K)
        keys = _combine_codes(codes, self._mults)[0]  # (L,)
        cand: set[int] = set()
        for t in range(self.family.num_tables):
            cand.update(self._tables[t].get(int(keys[t]), ()))
        return np.fromiter(cand, dtype=np.int64, count=len(cand))

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """-> (ids, scores, n_candidates). Exact re-rank of the candidates.

        scores are distances (ascending) for 'euclidean', similarities
        (descending) for 'cosine'.
        """
        cand = self.candidates(x)
        if cand.size == 0:
            return cand, np.empty(0, np.float32), 0
        sub = _tree_index(self.corpus, jnp.asarray(cand))
        scores = np.asarray(_score_batch(self.metric, x, sub))
        order = np.argsort(scores if self.metric == "euclidean" else -scores)
        order = order[:topk]
        return cand[order], scores[order], int(cand.size)


def _score_batch(metric: str, x, ys):
    fn = (contractions.distance if metric == "euclidean"
          else contractions.cosine_similarity)
    return jax.vmap(lambda y: fn(x, y))(ys)


def brute_force(metric: str, x, corpus, topk: int = 10):
    """Exact top-k over the whole corpus (recall reference)."""
    scores = np.asarray(_score_batch(metric, x, corpus))
    order = np.argsort(scores if metric == "euclidean" else -scores)[:topk]
    return order, scores[order]


def recall_at_k(index: LSHIndex, queries, topk: int = 10) -> dict[str, float]:
    """Mean recall@k of index.query vs. brute force over a query batch."""
    n_q = jax.tree.leaves(queries)[0].shape[0]
    hits, total, cand_total = 0, 0, 0
    for i in range(n_q):
        q = _tree_index(queries, i)
        truth, _ = brute_force(index.metric, q, index.corpus, topk)
        got, _, n_cand = index.query(q, topk)
        hits += len(set(truth.tolist()) & set(got.tolist()))
        total += topk
        cand_total += n_cand
    return {
        "recall": hits / max(total, 1),
        "mean_candidates": cand_total / max(n_q, 1),
        "corpus_size": index.size,
    }
