"""Multi-table LSH indexes for approximate nearest-neighbour search.

The classic (K, L) construction on top of the paper's hash families, built
on the segment core in ``repro.core.segments``: every index is a
``SegmentStore`` — one immutable base segment (per-table sorted uint32
bucket keys + permutation + corpus slice) plus bounded delta segments
(streaming inserts) and a tombstone mask (streaming deletes) — queried by a
single shared planner: hash the batch once, probe every segment with the
vmapped ``searchsorted``/gather path, filter tombstones inside the probe,
re-rank exactly in format, and merge the per-segment top-k with the stable
validity-aware two-key sort (the PR 2 shard merge, reused verbatim for
segments). Three deployments share that planner:

``DeviceLSHIndex`` (the default, exported as ``LSHIndex``) keeps the store
on one device and runs one jit program per query batch.

``ShardedLSHIndex`` is shard-native end-to-end: the base segment lays over
a mesh axis in S contiguous shards (the shard_map placement lives in
``repro.distributed.index_sharding``) and every mutation stays on the
shards. ``insert`` routes each batch to shards least-loaded-first in
contiguous slabs (one ``ShardedSegment`` delta per batch, placed with the
same NamedSharding rules as the base — nothing is replicated), and
``compact()`` folds each shard's base slice + delta slabs + tombstones
into a new base shard locally, with no cross-shard traffic; only an
explicit ``rebalance()`` re-partitions the live corpus contiguously when
occupancy skews. Results are identical to ``DeviceLSHIndex`` for any
shard count.

``HostLSHIndex`` keeps the FAISS-style dict-of-buckets build as the
bucket-membership semantics reference (``candidates()`` probes the dicts),
but serves ``query``/``query_batch`` through the same shared planner over a
single-segment store.

Mutation API (device + sharded): ``insert(batch)`` hashes the batch and
appends a small sorted delta segment (one jit sort program; queries start
probing it immediately), ``delete(ids)`` tombstones items by their current
effective ids (no recompilation — only mask bits flip), and ``compact()``
merges the surviving keys + corpus rows back into one base segment without
re-hashing. With the default exact bucket cap, query results match a fresh
build over the effective corpus bit-identically: ids and candidate counts
always; scores to float-reassociation ulps while deltas are outstanding or
while a shard-locally compacted base partitions shards differently from a
contiguous fresh build, and exactly whenever the stored arrays coincide
with a fresh build's (a flat ``compact()``, or a sharded ``rebalance()``).
Indexes built with an explicit ``bucket_cap`` keep live-window lookups, so
a truncated probe window gathers the first ``cap`` *live* members of each
bucket — tombstones no longer consume window space — but delta segments
still carry their own caps, so the fresh-rebuild parity guarantee applies
to the default cap only. Inserts past ``max_deltas`` outstanding deltas
trigger an automatic compaction.

Bucket keys are a universal multiply-add hash of the K integer hashcodes in
uint32 arithmetic (natural mod-2^32 wraparound) so the numpy host path and
the jnp device path produce bit-identical keys without requiring x64 mode.
Build, insert, and query hashing all run through the family's batch-native
``hash_keys`` program (``segments.bucket_keys`` / ``query_keys``):
projection, discretization, and the key combine are one fused program per
batch, on the XLA or Pallas backend the family's ``hash_backend`` selects.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import probing, segments
from repro.core.lsh import LSHFamily
from repro.core.probing import QUERY_MODES
from repro.core.segments import (SegmentStore, bucket_keys, build_segment,
                                 build_sharded_segment, make_mults,
                                 tree_index)

# Back-compat aliases: the pre-segment module exposed these underscored
# helpers; the tier-1 tests import them from here.
_combine_codes = segments._combine_codes
_make_mults = make_mults
_max_run_length = segments._max_run_length


def _check_metric(metric: str) -> None:
    if metric not in ("euclidean", "cosine"):
        raise ValueError(metric)


def _check_mode(mode: str, rng) -> None:
    """Shared query-mode validation: sampling modes need an explicit PRNG
    key per request (no hidden state — reusing a key replays the draw),
    and the deterministic top-k mode must not be handed one silently."""
    if mode not in QUERY_MODES:
        raise ValueError(
            f"unknown query mode {mode!r}; expected one of {QUERY_MODES}")
    if mode == "topk" and rng is not None:
        raise ValueError("rng applies to the sampling modes only; "
                         "mode='topk' is deterministic")
    if mode != "topk" and rng is None:
        raise ValueError(
            f"mode={mode!r} samples from the probed bucket union and needs "
            "an explicit PRNG key (pass rng=jax.random.PRNGKey(seed))")


def _score_fn(metric: str):
    return segments._score_fn(metric)


@jax.jit
def _hash_one(family, x):
    return family.hash(x)


# ---------------------------------------------------------------------------
# Shared single-query wrappers (one mixin, not three copies)
# ---------------------------------------------------------------------------


class _LSHIndexBase:
    """Query API shared by every index deployment.

    Subclasses provide ``query_batch`` / ``candidates_batch`` (and the
    ``family`` / ``metric`` / ``corpus`` attributes); the single-query
    wrappers below are the one shared implementation of the
    ``(ids, scores, n_candidates)`` numpy contract.
    """

    def candidates(self, x, probes: int = 1) -> np.ndarray:
        """Union of live bucket members over all tables/segments (sorted);
        ``probes`` = T > 1 widens each table to its T ranked buckets."""
        cand, valid = self.candidates_batch(tree_index(x, None),
                                            probes=probes)
        cand = np.asarray(cand[0])
        return np.sort(cand[np.asarray(valid[0])]).astype(np.int64)

    def query(self, x, topk: int = 10, *, probes: int = 1,
              mode: str = "topk", rng=None
              ) -> tuple[np.ndarray, np.ndarray, int]:
        """-> (ids, scores, n_candidates). Exact re-rank of the candidates.

        scores are distances (ascending) for 'euclidean', similarities
        (descending) for 'cosine'; rows with fewer than ``topk`` candidates
        are trimmed of the -1 fill. ``probes``/``mode``/``rng`` follow the
        ``query_batch`` contract (multi-probe expansion + sampling modes).
        """
        ids, scores, n_cand = self.query_batch(tree_index(x, None), topk,
                                               probes=probes, mode=mode,
                                               rng=rng)
        ids = np.asarray(ids[0])
        mask = ids >= 0
        return (ids[mask].astype(np.int64), np.asarray(scores[0])[mask],
                int(n_cand[0]))

    def effective_corpus(self):
        """The corpus the returned ids index into (rebuild-only paths)."""
        return self.corpus


@dataclasses.dataclass(frozen=True)
class PendingSwap:
    """A fully-built shadow store awaiting publication (the second buffer
    of the double-buffered swap).

    ``prepare_compact()`` / ``prepare_rebalance()`` build the replacement
    store off the query path — every device array materialized and placed —
    and hand back one of these; ``apply_swap()`` publishes it as a pointer
    flip. ``source``/``generation`` pin the store state the shadow was
    derived from, so a swap can never silently discard mutations that
    landed while the shadow was building."""

    store: SegmentStore
    kind: str                 # "compact" | "rebalance"
    source: SegmentStore
    generation: int
    corpus_cache: Any = None  # sharded: the ``_corpus`` value post-flip


class _SegmentedIndex(_LSHIndexBase):
    """Store-backed mutation + introspection API shared by the device and
    sharded deployments. Subclasses implement ``_new_store`` and
    ``_build_compact_store``."""

    store: SegmentStore | None

    @property
    def size(self) -> int:
        """Number of live (queryable) items."""
        return self.store.n_live if self.store is not None else 0

    @property
    def sorted_keys(self):
        return self.store.base.sorted_keys

    @property
    def perm(self):
        return self.store.base.perm

    @property
    def cap(self) -> int:
        return self.store.base.cap

    def effective_corpus(self):
        return self.store.effective_corpus()

    # -- mutations ----------------------------------------------------------

    def insert(self, batch, batch_size: int = 1024):
        """Append a batch of items as one small sorted delta segment.

        The batch is hashed once and sorted in one jit program; queries
        probe the new segment immediately. New items take the next
        effective ids (after every currently-live item). More than
        ``max_deltas`` outstanding deltas trigger an automatic
        ``compact()``.
        """
        if jax.tree.leaves(batch)[0].shape[0] == 0:
            return self
        keys = bucket_keys(self.family, self._mults, batch, batch_size)
        self.store.append_delta(
            build_segment(keys, batch, bucket_cap=self.bucket_cap))
        self._maybe_auto_compact()
        return self

    def delete(self, ids) -> int:
        """Tombstone items by their current effective ids (the numbering
        ``query``/``query_batch`` return). Later items shift down, exactly
        as in a fresh rebuild without them. Returns the number deleted."""
        return self.store.delete_effective(np.asarray(ids))

    def _maybe_auto_compact(self) -> None:
        """Compact when the delta count exceeds ``max_deltas``, accounting
        the fold's wall time separately (``auto_compact_s`` /
        ``auto_compactions``) so callers timing an ``insert`` can split the
        mutation cost from the compaction cost it occasionally triggers."""
        if len(self.store.deltas) <= self.max_deltas:
            return
        t0 = time.perf_counter()
        self.compact()
        jax.block_until_ready(self.store.base.sorted_keys)
        self.auto_compact_s += time.perf_counter() - t0
        self.auto_compactions += 1

    def _reset_mutation_state(self) -> None:
        """Rebuilding (``build()`` on a live index) starts a fresh mutation
        history — stale compaction/rebalance counters would otherwise
        describe the previous corpus."""
        self.compactions = 0
        self.auto_compactions = 0
        self.auto_compact_s = 0.0

    # -- double-buffered swap -----------------------------------------------

    def prepare_compact(self) -> PendingSwap | None:
        """Build the compacted replacement store OFF the query path.

        Gathers the stored corpus-order keys of every surviving item (no
        re-hashing), rebuilds the sorted tables, places every array, and
        blocks until all of it has landed — the live store is untouched and
        queries keep serving it throughout. Returns the pending shadow
        store for ``apply_swap`` (None when the store is pristine and there
        is nothing to fold)."""
        store = self.store
        if not store.mutated:
            return None
        if store.n_live == 0:
            raise ValueError("cannot compact an index with no live items")
        shadow = self._build_compact_store(store)
        jax.block_until_ready(jax.tree.leaves(shadow.view.all_arrays))
        return PendingSwap(store=shadow, kind="compact", source=store,
                           generation=store.generation)

    def apply_swap(self, pending: PendingSwap | None):
        """Publish a prepared shadow store: one pointer flip, no device
        work. Queries in flight finish on whichever store they pinned at
        dispatch (results are bit-identical to that store's answers);
        queries dispatched after the flip serve the new store. Raises
        RuntimeError if the live store mutated after ``pending`` was
        prepared — the shadow would silently drop those mutations —
        so callers (the serving scheduler's ingest lane) must serialize
        mutations with the prepare/apply pair."""
        if pending is None:
            return self
        store = self.store
        if (store is not pending.source
                or store.generation != pending.generation):
            raise RuntimeError(
                "store mutated since this swap was prepared; the shadow "
                "store is stale — call prepare again (serialize mutations "
                "with the prepare/apply pair, e.g. on the serving "
                "scheduler's ingest lane)")
        self._pre_publish(pending)
        self.store = pending.store      # the flip
        if pending.kind == "compact":
            self.compactions += 1
        else:
            self.rebalances += 1
        return self

    def _pre_publish(self, pending: PendingSwap) -> None:
        """Subclass hook: index-side cache updates that must ride the flip."""

    def compact(self):
        """Merge base + deltas minus tombstones into one fresh base segment.

        Runs as a synchronous double-buffered swap: the replacement store
        is fully built first (``prepare_compact`` — stored keys only, no
        re-hash), then published as a pointer flip, so even a caller
        interleaving queries from another thread never observes a
        half-built store. Afterwards effective and physical ids coincide
        and query programs return to the single-base shape. With the
        default exact cap results are unchanged by construction; with an
        explicit ``bucket_cap`` compaction reclaims the probe-window slots
        tombstones were consuming, so truncated buckets can regain
        candidates.
        """
        return self.apply_swap(self.prepare_compact())


# ---------------------------------------------------------------------------
# Device index (single-device segment store)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeviceLSHIndex(_SegmentedIndex):
    """Device-resident (K, L) index: a segment store of sorted bucket keys +
    permutations, fully batched jit-compiled queries, streaming mutations.

    corpus: any pytree whose leaves share a leading axis of size n. Query
    batches are pytrees with a leading batch axis B; `query_batch` returns
    (ids (B, topk) int32 with -1 fill, scores (B, topk), n_candidates (B,)).
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    bucket_cap: int | None = None  # None -> exact (largest build-time bucket)
    max_deltas: int = 8            # outstanding deltas before auto-compact
    swap_chunk_rows: int | None = 4096  # shadow-build copy chunk (None ->
                                        # one store-sized program per fold)
    probe_backend: str = "auto"    # 'auto' | 'xla' | 'pallas' — the fused
                                   # probe path (segments.resolved_probe_backend)

    store: SegmentStore | None = None
    compactions: int = 0
    auto_compactions: int = 0
    auto_compact_s: float = 0.0
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = make_mults(self.seed, self.family.num_codes)

    @property
    def probe_path(self) -> str:
        """The resolved probe program ``query_batch`` executes: ``"xla"``
        (the fused segment-major schedule) or ``"pallas"`` (the fused query
        kernel). Introspection hook for CI legs that must fail loudly if
        the requested backend silently falls back."""
        return segments.resolved_probe_backend(self.probe_backend)

    @property
    def corpus(self):
        """The effective (live) corpus the returned ids index into."""
        return self.store.effective_corpus() if self.store else None

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "DeviceLSHIndex":
        keys = bucket_keys(self.family, self._mults, corpus, batch_size)
        self.store = self._new_store(keys, corpus)
        self._reset_mutation_state()
        return self

    def _new_store(self, keys, corpus,
                   sort_throttled: bool = False) -> SegmentStore:
        return SegmentStore(
            build_segment(keys, corpus, bucket_cap=self.bucket_cap,
                          warn_layout=type(self).__name__,
                          sort_throttled=sort_throttled),
            live_window=self.bucket_cap is not None)

    def _build_compact_store(self, store: SegmentStore) -> SegmentStore:
        # chunked assembly (the default) keeps every fold program bounded
        # so concurrently dispatched queries interleave with the build —
        # values are bit-identical to the one-program gather
        if self.swap_chunk_rows is None:
            keys, corpus = store.effective_arrays()
            return self._new_store(keys, corpus)
        keys, corpus = store.effective_arrays_chunked(
            int(self.swap_chunk_rows))
        return self._new_store(keys, corpus, sort_throttled=True)

    # -- query --------------------------------------------------------------

    def candidates_batch(self, queries, *, probes: int = 1
                         ) -> tuple[jax.Array, jax.Array]:
        """-> (cand (B, W) effective ids with -1 fill, valid (B, W) bool)."""
        view = self.store.view
        return segments.segmented_candidates(
            self.family, view.all_arrays, jnp.asarray(self._mults),
            queries, caps=view.all_caps, probes=int(probes))

    def query_batch(self, queries, topk: int = 10, *, probes: int = 1,
                    mode: str = "topk", rng=None):
        """-> (ids (B, topk), scores (B, topk), n_candidates (B,)) jax arrays.

        Rows with fewer than topk candidates are filled with id -1 and
        +inf distance / -inf similarity. One jit-compiled program end-to-end
        over every segment (base + outstanding deltas, tombstones filtered).

        ``probes`` = T > 1 turns on query-directed multi-probe: each table
        probes its T most promising buckets (``repro.core.probing``), so
        fewer tables reach the same recall; T=1 is bit-identical to the
        single-probe program. ``mode`` selects the result semantics:
        ``"topk"`` (default) is the exact re-ranked top-k; ``"uniform"`` /
        ``"weighted"`` instead *sample* ``topk`` distinct members from the
        probed bucket union (uniformly / proportional to bucket size) and
        need an explicit per-request PRNG key via ``rng``.
        """
        _check_mode(mode, rng)
        view = self.store.view
        args = (self.family, view.all_arrays,
                jnp.asarray(self._mults), queries)
        if mode != "topk":
            return segments.segmented_sample(
                *args, rng, metric=self.metric, topk=topk,
                caps=view.all_caps, probes=int(probes), mode=mode)
        return segments.segmented_query(
            *args, metric=self.metric, topk=topk, caps=view.all_caps,
            probes=int(probes), probe_backend=self.probe_backend)


LSHIndex = DeviceLSHIndex  # default deployment


# ---------------------------------------------------------------------------
# Mesh-sharded index (sharded base segment + replicated deltas)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedLSHIndex(_SegmentedIndex):
    """Corpus-sharded (K, L) index over a named mesh axis with a global
    top-k merge — the multi-host layout of ``DeviceLSHIndex``.

    The *base* segment is partitioned into ``shards`` contiguous slices;
    each shard holds its own (L, n_s) sorted bucket keys + permutation
    (local ids, pad slots marked with the n_s sentinel) and its (n_s, ...)
    corpus slice, placed with ``NamedSharding``. Mutations are
    shard-native: ``insert`` routes each batch to shards with the
    deterministic least-loaded-first policy (``segments.route_balanced``)
    and appends one sharded delta slab per batch, placed exactly like the
    base; ``delete`` flips tombstone bits; ``compact()`` folds every
    shard's base slice + delta slabs + tombstones into a new base shard
    *locally* (no re-hash, no global gather — O(n/S) per shard), leaving
    each shard's item mix unchanged; ``rebalance()`` is the explicit
    global re-partition for when sustained skew (or compaction history)
    leaves occupancy uneven, and restores the contiguous fresh-build
    layout. A query batch runs as one jit program: replicated hashing,
    per-shard probe of the base block + every delta slab with an in-shard
    merge (via ``shard_map`` when a mesh carries the shard axis, ``vmap``
    otherwise — see ``query_path``), then the single global S-way merge.
    With the default exact cap the merged top-k is bit-identical to
    ``DeviceLSHIndex`` for any shard count and any routing.

    An explicit ``bucket_cap`` truncates each *shard's* slice of a bucket,
    so the union of candidates can exceed the single-device truncation (up
    to S*L*cap) — recall can only improve, throughput bounds are per shard.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    shards: int = 1
    bucket_cap: int | None = None  # None -> exact (largest per-shard bucket)
    max_deltas: int = 8
    swap_chunk_rows: int | None = 4096  # shadow-build copy chunk (None ->
                                        # one store-sized program per fold)
    probe_backend: str = "auto"    # 'auto' | 'xla' | 'pallas' — the fused
                                   # probe path (segments.resolved_probe_backend)
    keep_corpus: bool = True   # False drops the unsharded build-time copy
                               # (at real multi-host scale it won't fit;
                               # effective_corpus() regathers from shards)

    _corpus: Any = None            # build-time pytree (keep_corpus=True)
    store: SegmentStore | None = None
    compactions: int = 0
    rebalances: int = 0
    auto_compactions: int = 0
    auto_compact_s: float = 0.0
    mesh: Any = None               # jax Mesh carrying the shard axis, or None
    mesh_axis: str | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        if int(self.shards) < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        self._mults = make_mults(self.seed, self.family.num_codes)

    @property
    def corpus(self):
        """The effective (live) corpus the returned ids index into — the
        build-time pytree while pristine (None under ``keep_corpus=False``),
        regathered from the segments once mutated, matching
        ``DeviceLSHIndex.corpus``. A shard-local ``compact()`` invalidates
        the build-time copy (shards no longer hold contiguous slices); the
        regathered corpus is cached here so repeated access costs one
        gather, not one per call."""
        if self.store is None:
            return self._corpus
        if self.store.mutated:
            return self.store.effective_corpus()
        if self._corpus is None and self.keep_corpus:
            self._corpus = self.store.effective_corpus()
        return self._corpus

    @property
    def corpus_sharded(self):
        return self.store.base.corpus if self.store else None

    @property
    def shard_size(self) -> int:
        return self.store.base.shard_size

    @property
    def query_path(self) -> str:
        """The program ``query_batch`` executes: ``"shard_map"`` when a
        mesh carries the shard axis, ``"vmap"`` on the single-program
        fallback. Introspection hook for CI legs that must fail loudly if
        multi-device coverage silently degrades to the vmap path. The
        pallas probe backend always serves through the single-program
        path (its mesh shard_map dispatch is the deferred TPU leg), so it
        reports ``"vmap"`` even when a mesh exists."""
        return ("shard_map"
                if self.mesh is not None and self.probe_path != "pallas"
                else "vmap")

    @property
    def probe_path(self) -> str:
        """The resolved probe program ``query_batch`` executes: ``"xla"``
        (the fused segment-major schedule, inside whichever distribution
        program ``query_path`` names) or ``"pallas"`` (the fused query
        kernel, run per shard as a single program — its mesh shard_map
        dispatch is the deferred TPU leg, see ROADMAP)."""
        return segments.resolved_probe_backend(self.probe_backend)

    def occupancy(self) -> np.ndarray:
        """(S,) live items per shard (base + delta slabs)."""
        return self.store.shard_live_counts

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "ShardedLSHIndex":
        from repro.distributed import index_sharding  # deferred: core<->dist

        keys = bucket_keys(self.family, self._mults, corpus, batch_size)
        self.mesh, self.mesh_axis = index_sharding.resolve_mesh(
            int(self.shards))
        self.store = self._new_store(keys, corpus)
        self._corpus = corpus if self.keep_corpus else None
        self._reset_mutation_state()
        return self

    def _reset_mutation_state(self) -> None:
        super()._reset_mutation_state()
        self.rebalances = 0

    def _place(self, shadow: bool = False):
        if self.mesh is None:
            return lambda t: t
        from repro.distributed import index_sharding
        fn = (index_sharding.place_shadow if shadow
              else index_sharding.place_sharded)
        return functools.partial(fn, mesh=self.mesh, axis=self.mesh_axis)

    def _place_segment(self, seg, shadow: bool = False):
        place = self._place(shadow)
        return dataclasses.replace(
            seg, keys=place(seg.keys), sorted_keys=place(seg.sorted_keys),
            perm=place(seg.perm), corpus=place(seg.corpus))

    def _new_store(self, keys, corpus, shadow: bool = False) -> SegmentStore:
        seg = build_sharded_segment(
            keys, corpus, int(self.shards), bucket_cap=self.bucket_cap,
            warn_layout=type(self).__name__)
        live_window = self.bucket_cap is not None
        if self.mesh is None:
            return SegmentStore(seg, live_window=live_window)
        return SegmentStore(self._place_segment(seg, shadow),
                            place=self._place(), live_window=live_window)

    # -- mutations (shard-native) -------------------------------------------

    def insert(self, batch, batch_size: int = 1024):
        """Route a batch to shards (least-loaded-first, contiguous slabs)
        and append it as one sharded delta slab, hashed once and sorted
        per shard locally. New items take the next effective ids in batch
        order, exactly as on the device index; more than ``max_deltas``
        outstanding deltas trigger an automatic (shard-local) compaction.
        """
        if jax.tree.leaves(batch)[0].shape[0] == 0:
            return self
        n = jax.tree.leaves(batch)[0].shape[0]
        keys = bucket_keys(self.family, self._mults, batch, batch_size)
        alloc, offsets = segments.route_balanced(
            n, self.store.shard_live_counts)
        seg, positions = segments.build_sharded_delta(
            keys, batch, alloc, offsets, seq0=self.store.seq_len,
            bucket_cap=self.bucket_cap)
        if self.mesh is not None:
            seg = self._place_segment(seg)
        self.store.append_delta(seg, positions)
        self._maybe_auto_compact()
        return self

    def compact(self):
        """Fold each shard's base slice + delta slabs + tombstones into a
        new base shard, shard-locally: stored keys only (no re-hash), one
        per-shard gather + sort program with no cross-shard traffic, so
        steady-state compaction costs O(n/S) per shard. Shards keep the
        item mix routing gave them — their sequence ranges stay
        non-contiguous until an explicit ``rebalance()``; effective ids
        (and so query results) are unchanged by construction. Runs as a
        synchronous double-buffered swap (build shadow, flip pointer), the
        same machinery ``prepare_compact``/``apply_swap`` expose to the
        serving plane."""
        return self.apply_swap(self.prepare_compact())

    def _build_compact_store(self, store: SegmentStore) -> SegmentStore:
        """The shard-local fold, pure with respect to ``self``: builds and
        returns the replacement store; the live store (and every query
        pinned to its view) is untouched."""
        s = store.base.shards
        segs = store._segments()
        live2d = np.concatenate(
            [store.live_host[off:off + g.slots].reshape(s, g.shard_size)
             for off, g in zip(np.cumsum([0] + [g.slots for g in segs[:-1]]),
                               segs)], axis=1)
        pos2d = np.concatenate(
            [p.reshape(s, g.shard_size)
             for p, g in zip(store.slot_pos, segs)], axis=1)
        counts = live2d.sum(axis=1).astype(np.int64)
        new_ns = max(int(counts.max()), 1)
        w = live2d.shape[1]
        idx = np.full((s, new_ns), w, np.int64)
        new_pos = np.full((s, new_ns), -1, np.int64)
        eff_seq = np.cumsum(store._live_seq) - 1
        for sh in range(s):
            sel = np.flatnonzero(live2d[sh])    # slot order = seq order
            idx[sh, :sel.size] = sel
            new_pos[sh, :sel.size] = eff_seq[pos2d[sh, sel]]
        keys_cat = jnp.concatenate([g.keys for g in segs], axis=1)
        if self.swap_chunk_rows is None:
            corpus_cat = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=1),
                *[g.corpus for g in segs])
            keys_n, sorted_keys, perm, corpus_n, max_runs = \
                segments._slab_gather_sort(
                    keys_cat, corpus_cat, jnp.asarray(idx, jnp.int32),
                    jnp.asarray(counts, jnp.int32), shard_size=new_ns)
        else:
            # chunked fold (the default): same values as the monolithic
            # fold, issued as bounded programs — a small keys gather, one
            # sort per table, and per-chunk corpus copies in flat
            # (shard * slot) row space — with blocking between them, so
            # concurrent queries interleave with the build instead of
            # queueing behind one store-sized program
            keys_n = segments._slab_gather_keys(
                keys_cat, jnp.asarray(idx, jnp.int32))
            jax.block_until_ready(keys_n)
            segments._yield_slot()
            counts_j = jnp.asarray(counts, jnp.int32)
            tables = []
            for table in range(keys_n.shape[-1]):
                out = segments._sort_shard_table(
                    keys_n[:, :, table], counts_j, shard_size=new_ns)
                jax.block_until_ready(out)
                segments._yield_slot()
                tables.append(out)
            perm = jnp.stack([t[0] for t in tables], axis=1)
            sorted_keys = jnp.stack([t[1] for t in tables], axis=1)
            max_runs = jnp.stack([t[2] for t in tables])
            valid = idx < w          # sentinel w marks pad rows
            sh_i, col_i = np.nonzero(valid)
            srcs, src_idxs, dst_idxs = [], [], []
            off = 0
            for g in segs:
                wg = g.shard_size
                m = (idx[sh_i, col_i] >= off) & (idx[sh_i, col_i] < off + wg)
                srcs.append(jax.tree.map(
                    lambda a: a.reshape((s * wg,) + a.shape[2:]), g.corpus))
                src_idxs.append(sh_i[m] * wg + (idx[sh_i[m], col_i[m]] - off))
                dst_idxs.append(sh_i[m] * new_ns + col_i[m])
                off += wg
            flat = segments.gather_rows_chunked(
                srcs[0], srcs, src_idxs, dst_idxs, s * new_ns,
                chunk=int(self.swap_chunk_rows))
            corpus_n = jax.tree.map(
                lambda a: a.reshape((s, new_ns) + a.shape[1:]), flat)
        if self.bucket_cap is None:
            cap = max(int(np.asarray(max_runs).max()), 1)
            segments._warn_coarse(type(self).__name__, cap,
                                  self.family.num_tables, int(counts.max()),
                                  shards=s)
        else:
            cap = min(int(self.bucket_cap), new_ns)
        seg = segments.ShardedSegment(
            keys=keys_n, sorted_keys=sorted_keys, perm=perm, corpus=corpus_n,
            cap=cap, counts=tuple(int(c) for c in counts))
        if self.mesh is not None:
            seg = self._place_segment(seg, shadow=True)
        return SegmentStore(
            seg, place=self._place(), base_pos=new_pos.reshape(-1),
            live_window=self.bucket_cap is not None)

    def _pre_publish(self, pending: PendingSwap) -> None:
        # The shard layout changes under the flip: a shard-local compact
        # invalidates the build-time corpus copy (non-contiguous sequence
        # ranges → corpus_cache=None), a rebalance restores the fresh-build
        # layout and installs the gathered corpus as the pristine fallback.
        self._corpus = pending.corpus_cache

    def prepare_rebalance(self) -> PendingSwap:
        """Build the globally re-partitioned replacement store OFF the
        query path (the one deliberately global program in the mutation
        plane: gather the live corpus in sequence order, re-partition into
        S contiguous shards, re-sort per shard). Blocks until the shadow
        has landed on its shards; the live store keeps serving throughout.
        """
        store = self.store
        if store.n_live == 0:
            raise ValueError("cannot rebalance an index with no live items")
        keys, corpus = store.effective_arrays()
        shadow = self._new_store(keys, corpus, shadow=True)
        jax.block_until_ready(jax.tree.leaves(shadow.view.all_arrays))
        return PendingSwap(store=shadow, kind="rebalance", source=store,
                           generation=store.generation,
                           corpus_cache=corpus if self.keep_corpus else None)

    def rebalance(self):
        """Gather the live corpus (sequence order) and re-partition it into
        S contiguous, evenly-sized shards — for when routing skew or
        shard-local compaction history leaves occupancy uneven. Restores
        the exact layout of a fresh build over the effective corpus (so
        post-rebalance queries are bit-identical to one, scores included).
        Runs as a synchronous double-buffered swap, like ``compact``.
        """
        return self.apply_swap(self.prepare_rebalance())

    # -- query --------------------------------------------------------------

    def candidates_batch(self, queries, *, probes: int = 1
                         ) -> tuple[jax.Array, jax.Array]:
        """-> (cand (B, W) effective ids with -1 fill, valid bool)."""
        view = self.store.view
        return segments.sharded_candidates(
            self.family, view.seg_arrays(0), view.delta_arrays,
            jnp.asarray(self._mults), queries, cap=view.base.cap,
            delta_caps=view.delta_caps, probes=int(probes))

    def query_batch(self, queries, topk: int = 10, *, probes: int = 1,
                    mode: str = "topk", rng=None):
        """Same contract as DeviceLSHIndex.query_batch (effective ids,
        multi-probe ``probes``, sampling ``mode``/``rng``). A sampling
        query is one global draw over the cross-shard union, so it always
        runs the single-program vmap path regardless of the mesh
        (``query_path`` describes the ``"topk"`` program)."""
        _check_mode(mode, rng)
        view = self.store.view
        args = (self.family, view.seg_arrays(0),
                view.delta_arrays, jnp.asarray(self._mults), queries)
        kwargs = dict(metric=self.metric, topk=topk, cap=view.base.cap,
                      delta_caps=view.delta_caps, probes=int(probes))
        if mode != "topk":
            return segments.sharded_sample_vmap(*args, rng, mode=mode,
                                                **kwargs)
        kwargs["probe_backend"] = self.probe_backend
        if (self.mesh is not None
                and segments.resolved_probe_backend(self.probe_backend)
                != "pallas"):
            from repro.distributed import index_sharding
            return index_sharding.shard_map_query(
                *args, mesh=self.mesh, axis=self.mesh_axis, **kwargs)
        return segments.sharded_query_vmap(*args, **kwargs)


# ---------------------------------------------------------------------------
# Host index (dict-of-buckets build kept as the membership reference)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HostLSHIndex(_LSHIndexBase):
    """Dict-of-buckets build: the bucket-membership semantics reference.

    corpus: any pytree whose leaves share a leading axis of size n —
    e.g. stacked CPTensor factors (n, d, R), stacked TT cores, or a dense
    (n, d_1, ..., d_N) array. ``candidates()`` probes the host-side Python
    dicts one query at a time (the independent reference the device tests
    pin against); ``query``/``query_batch`` serve through the same shared
    segment planner as every other deployment. Rebuild-only: streaming
    mutations live on the device/sharded indexes.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    probe_backend: str = "auto"    # 'auto' | 'xla' | 'pallas'

    corpus: Any = None
    size: int = 0
    store: SegmentStore | None = None
    _tables: list[dict[int, list[int]]] | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = make_mults(self.seed, self.family.num_codes)

    @property
    def probe_path(self) -> str:
        """The resolved probe program (see DeviceLSHIndex.probe_path)."""
        return segments.resolved_probe_backend(self.probe_backend)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "HostLSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        keys = bucket_keys(self.family, self._mults, corpus, batch_size)
        all_keys = np.asarray(keys)
        self._tables = [dict() for _ in range(self.family.num_tables)]
        for i in range(n):
            for t in range(self.family.num_tables):
                self._tables[t].setdefault(int(all_keys[i, t]), []).append(i)
        self.store = SegmentStore(build_segment(
            keys, corpus, warn_layout=type(self).__name__))
        return self

    # -- query --------------------------------------------------------------

    def candidates(self, x, probes: int = 1) -> np.ndarray:
        """Union of bucket members over the L tables, via the host dicts.

        ``probes`` = T > 1 looks up each table's T ranked candidate keys
        (``repro.core.probing``) in the same dicts — membership stays
        dict-defined, so this is the reference the device multi-probe dedup
        (distinct members across overlapping probed buckets) is pinned to.
        """
        if probes == 1:
            codes = np.asarray(_hash_one(self.family, x))[None]  # (1, L, K)
            keys = _combine_codes(codes, self._mults)[:, :, None]  # (1, L, 1)
        else:
            keys = np.asarray(probing.probe_keys(
                self.family, jnp.asarray(self._mults), tree_index(x, None),
                probes=int(probes)))                      # (1, L, T)
        cand: set[int] = set()
        for t in range(self.family.num_tables):
            for key in keys[0, t]:
                cand.update(self._tables[t].get(int(key), ()))
        return np.fromiter(cand, dtype=np.int64, count=len(cand))

    def query_batch(self, queries, topk: int = 10, *, probes: int = 1,
                    mode: str = "topk", rng=None):
        """Same contract as DeviceLSHIndex.query_batch."""
        _check_mode(mode, rng)
        view = self.store.view
        args = (self.family, view.all_arrays,
                jnp.asarray(self._mults), queries)
        if mode != "topk":
            return segments.segmented_sample(
                *args, rng, metric=self.metric, topk=topk,
                caps=view.all_caps, probes=int(probes), mode=mode)
        return segments.segmented_query(
            *args, metric=self.metric, topk=topk, caps=view.all_caps,
            probes=int(probes), probe_backend=self.probe_backend)


# ---------------------------------------------------------------------------
# References / evaluation (vectorized: one batched score matrix, one
# query_batch call — no per-query Python loop)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric",))
def _score_matrix(metric, queries, corpus):
    """(B, ...) queries x (n, ...) corpus -> (B, n) exact scores."""
    score = _score_fn(metric)
    return jax.vmap(
        lambda q: jax.vmap(lambda y: score(q, y))(corpus))(queries)


def _score_batch(metric: str, x, ys):
    return _score_matrix(metric, tree_index(x, None), ys)[0]


def brute_force_batch(metric: str, queries, corpus, topk: int = 10):
    """Exact top-k over the whole corpus for a query batch.

    -> (ids (B, topk) int64, scores (B, topk)); one batched score matrix
    instead of a per-query loop.
    """
    scores = np.asarray(_score_matrix(metric, queries, corpus))
    order = np.argsort(scores if metric == "euclidean" else -scores,
                       axis=1)[:, :topk]
    return order, np.take_along_axis(scores, order, axis=1)


def brute_force(metric: str, x, corpus, topk: int = 10):
    """Exact top-k over the whole corpus (single-query recall reference)."""
    ids, scores = brute_force_batch(metric, tree_index(x, None), corpus, topk)
    return ids[0], scores[0]


def recall_at_k(index, queries, topk: int = 10,
                probes: int = 1) -> dict[str, float]:
    """Mean recall@k of index.query_batch vs. brute force over a query batch.

    Works for every index deployment (anything with the batched
    ``query_batch`` contract plus ``metric`` / ``effective_corpus`` /
    ``size``); the ground truth is one batched score matrix over the
    effective (live) corpus. ``probes`` = T > 1 measures the multi-probe
    query path (the (L, T) trade-off ``benchmarks/index_multiprobe``
    sweeps).
    """
    corpus = index.effective_corpus()
    truth, _ = brute_force_batch(index.metric, queries, corpus, topk)
    ids, _, n_cand = index.query_batch(queries, topk=topk, probes=probes)
    ids = np.asarray(ids)
    n_q = truth.shape[0]
    hits = sum(len(set(t) & set(row[row >= 0].tolist()))
               for t, row in zip(truth.tolist(), ids))
    return {
        "recall": hits / max(n_q * topk, 1),
        "mean_candidates": float(np.asarray(n_cand).sum()) / max(n_q, 1),
        "corpus_size": index.size,
    }
