"""Multi-table LSH indexes for approximate nearest-neighbour search.

The classic (K, L) construction on top of the paper's hash families:
L tables, each keyed by the combination of K hashcodes. Two deployments:

``DeviceLSHIndex`` (the default, exported as ``LSHIndex``) keeps the whole
index device-resident: build-time sorts the (L, n) uint32 bucket keys into
per-table sorted key arrays + permutation indices (all ``jax.Array``s), and
query-time is one jit-compiled program over a (B, ...) query batch —
vmapped ``searchsorted`` bucket lookup, bounded candidate gathering with
masking, and exact in-format re-rank via ``contractions``.

``ShardedLSHIndex`` partitions the corpus into S contiguous shards, each
with its own (L, n/S) sorted tables, and merges per-shard top-k results
globally — same results as ``DeviceLSHIndex``, laid out for a mesh (the
shard_map placement lives in ``repro.distributed.index_sharding``).

``HostLSHIndex`` is the FAISS-style host path (Python dict buckets, one
query at a time), kept for A/B comparison and as the semantics reference.

Layout of the device index (see ROADMAP.md "Device index layout"):

  sorted_keys : (L, n) uint32 — bucket keys of corpus items, sorted per table
  perm        : (L, n) int32  — corpus ids in the same sorted order
  cap         : static int    — max bucket members gathered per probe; the
                default is the largest bucket observed at build time, which
                makes device queries return exactly the host candidate set.
                A smaller explicit ``bucket_cap`` trades recall for speed by
                truncating oversized buckets (deterministically, in corpus
                order — the stable sort preserves insertion order).

Bucket keys are a universal multiply-add hash of the K integer hashcodes in
uint32 arithmetic (natural mod-2^32 wraparound) so the numpy host path and
the jnp device path produce bit-identical keys without requiring x64 mode.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contractions
from repro.core.lsh import LSHFamily


def _make_mults(seed: int, num_codes: int) -> np.ndarray:
    """Per-position odd uint32 multipliers for the universal bucket hash."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 32, size=(num_codes,), dtype=np.uint32) | 1


def _combine_codes(codes, mults):
    """(..., L, K) int codes -> (..., L) uint32 bucket keys.

    sum_k codes[k] * mults[k] in uint32 arithmetic. Distinct per-position
    multipliers make the key permutation-sensitive; the mod-2^32 wraparound
    is identical between numpy (host tables) and jnp (device tables), and
    int32 codes of any magnitude cast to uint32 without overflow errors.
    """
    xp = jnp if isinstance(codes, jax.Array) else np
    prods = codes.astype(xp.uint32) * xp.asarray(mults).astype(xp.uint32)
    return prods.sum(axis=-1, dtype=xp.uint32)


def _tree_index(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def _check_metric(metric: str) -> None:
    if metric not in ("euclidean", "cosine"):
        raise ValueError(metric)


@jax.jit
def _hash_batch(family, xs):
    return family.hash_batch(xs)


def _bucket_keys(family, mults, corpus, batch_size: int) -> jax.Array:
    """(n, L) uint32 bucket keys of the whole corpus, hashed in batches.

    The single source of build-time keys for both indexes — host tables are
    filled from np.asarray of this, keeping host/device keys bit-identical.
    """
    n = jax.tree.leaves(corpus)[0].shape[0]
    mults = jnp.asarray(mults)
    keys = []
    for start in range(0, n, batch_size):
        chunk = _tree_index(corpus, slice(start, min(start + batch_size, n)))
        keys.append(_combine_codes(_hash_batch(family, chunk), mults))
    return jnp.concatenate(keys, axis=0)


def _score_fn(metric: str):
    return (contractions.distance if metric == "euclidean"
            else contractions.cosine_similarity)


# ---------------------------------------------------------------------------
# Host index (reference semantics, kept for A/B)
# ---------------------------------------------------------------------------


@jax.jit
def _hash_one(family, x):
    return family.hash(x)


@dataclasses.dataclass
class HostLSHIndex:
    """Dict-of-buckets index: build once over a (stacked-pytree) corpus.

    corpus: any pytree whose leaves share a leading axis of size n —
    e.g. stacked CPTensor factors (n, d, R), stacked TT cores, or a dense
    (n, d_1, ..., d_N) array. Hashing runs batched on-device; bucket storage
    and probing are host-side Python dicts, one query at a time.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0

    corpus: Any = None
    size: int = 0
    _tables: list[dict[int, list[int]]] | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = _make_mults(self.seed, self.family.num_codes)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "HostLSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        all_keys = np.asarray(
            _bucket_keys(self.family, self._mults, corpus, batch_size))
        self._tables = [dict() for _ in range(self.family.num_tables)]
        for i in range(n):
            for t in range(self.family.num_tables):
                self._tables[t].setdefault(int(all_keys[i, t]), []).append(i)
        return self

    # -- query --------------------------------------------------------------

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over the L tables."""
        codes = np.asarray(_hash_one(self.family, x))[None]  # (1, L, K)
        keys = _combine_codes(codes, self._mults)[0]  # (L,)
        cand: set[int] = set()
        for t in range(self.family.num_tables):
            cand.update(self._tables[t].get(int(keys[t]), ()))
        return np.fromiter(cand, dtype=np.int64, count=len(cand))

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """-> (ids, scores, n_candidates). Exact re-rank of the candidates.

        scores are distances (ascending) for 'euclidean', similarities
        (descending) for 'cosine'.
        """
        cand = self.candidates(x)
        if cand.size == 0:
            return cand, np.empty(0, np.float32), 0
        sub = _tree_index(self.corpus, jnp.asarray(cand))
        scores = np.asarray(_score_batch(self.metric, x, sub))
        order = np.argsort(scores if self.metric == "euclidean" else -scores)
        order = order[:topk]
        return cand[order], scores[order], int(cand.size)


# ---------------------------------------------------------------------------
# Device index (sorted keys + permutation, fully batched queries)
# ---------------------------------------------------------------------------


def _max_run_length(sorted_keys: jax.Array) -> jax.Array:
    """Longest run of equal values along axis 1 of (L, n) sorted keys."""
    n = sorted_keys.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones(sorted_keys.shape[:1] + (1,), bool),
         sorted_keys[:, 1:] != sorted_keys[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(new_run, idx, 0), axis=1)
    return jnp.max(idx - run_start + 1)


def _probe_tables(sorted_keys, perm, keys, cap):
    """-> (cand (B, L*cap) int32 with -1 for invalid, valid (B, L*cap) bool).

    keys: (L, B) uint32 query bucket keys (already hashed + combined). For
    each query and table: searchsorted into the sorted key array, gather
    the next `cap` positions, keep those still inside the bucket (same key),
    then sort + mask duplicates so each corpus id appears at most once.
    `perm` entries >= n (the sharded pad sentinel) are masked like misses.
    """
    n = sorted_keys.shape[1]
    starts = jax.vmap(
        lambda sk, q: jnp.searchsorted(sk, q, side="left"))(sorted_keys, keys)
    pos = starts[:, :, None] + jnp.arange(cap, dtype=starts.dtype)  # (L, B, cap)
    in_range = pos < n
    posc = jnp.minimum(pos, n - 1)
    key_at = jax.vmap(lambda sk, p: sk[p])(sorted_keys, posc)
    hit = in_range & (key_at == keys[:, :, None])
    ids = jax.vmap(lambda pm, p: pm[p])(perm, posc)       # (L, B, cap)
    b = keys.shape[1]
    cand = jnp.where(hit, ids, n).transpose(1, 0, 2).reshape(b, -1)
    cand = jnp.sort(cand, axis=1)                         # invalid (>=n) last
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
    valid = (cand < n) & ~dup
    return jnp.where(valid, cand, -1).astype(jnp.int32), valid


def _gather_candidates(family, sorted_keys, perm, mults, queries, cap):
    """Hash a query batch and probe the tables (see _probe_tables)."""
    codes = family.hash_batch(queries)                    # (B, L, K)
    keys = _combine_codes(codes, mults).T                 # (L, B)
    return _probe_tables(sorted_keys, perm, keys, cap)


@functools.partial(jax.jit, static_argnames=("cap",))
def _device_candidates(family, sorted_keys, perm, mults, queries, *, cap):
    return _gather_candidates(family, sorted_keys, perm, mults, queries, cap)


def _bad_score(metric: str) -> float:
    return jnp.inf if metric == "euclidean" else -jnp.inf


def _select_topk(metric, topk, cand, scores, valid):
    """Stable two-key sort -> (ids (B, topk) with -1 fill, scores (B, topk)).

    Primary key: validity (invalid slots strictly last, independent of their
    score values); secondary key: the score in rank order (ascending distance
    / descending similarity, NaN after every finite score — XLA's total
    order, matching np.argsort in the host path). The stable sort breaks
    score ties by candidate position, i.e. ascending corpus id, which is
    what makes sharded and single-device selections bit-identical.
    """
    order_key = scores if metric == "euclidean" else -scores
    _, _, s_cand, s_scores, s_valid = jax.lax.sort(
        (~valid, order_key, cand, scores, valid),
        dimension=1, is_stable=True, num_keys=2)
    k = min(topk, cand.shape[1])
    bad = _bad_score(metric)
    ids = jnp.where(s_valid[:, :k], s_cand[:, :k], -1)
    out_scores = jnp.where(s_valid[:, :k], s_scores[:, :k], bad)
    if k < topk:
        ids = jnp.pad(ids, ((0, 0), (0, topk - k)), constant_values=-1)
        out_scores = jnp.pad(out_scores, ((0, 0), (0, topk - k)),
                             constant_values=bad)
    return ids, out_scores


def _rank_candidates(metric, topk, queries, corpus, cand, valid):
    """(cand, valid) (B, W) -> (ids (B, topk), scores (B, topk), n_cand (B,)).

    Exact in-format re-rank of every valid candidate followed by the
    validity-aware top-k selection. Rows with no valid candidate come out
    all -1 / bad-fill even when scores are NaN or +/-inf (e.g. a zero-norm
    query under cosine) — selection never trusts score sentinels alone.
    """
    n_cand = valid.sum(axis=1, dtype=jnp.int32)
    safe = jnp.where(valid, cand, 0)
    sub = _tree_index(corpus, safe)                       # leaves (B, C, ...)
    score = _score_fn(metric)
    scores = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: score(q, y))(ys))(queries, sub)
    scores = jnp.where(valid, scores, _bad_score(metric))
    ids, out_scores = _select_topk(metric, topk, cand, scores, valid)
    return ids, out_scores, n_cand


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap"))
def _device_query(family, corpus, sorted_keys, perm, mults, queries, *,
                  metric, topk, cap):
    """One program from query batch to top-k: hash -> probe -> gather -> rank."""
    cand, valid = _gather_candidates(family, sorted_keys, perm, mults,
                                     queries, cap)
    return _rank_candidates(metric, topk, queries, corpus, cand, valid)


@dataclasses.dataclass
class DeviceLSHIndex:
    """Device-resident (K, L) index: sorted bucket keys + permutation per
    table, fully batched jit-compiled queries.

    corpus: any pytree whose leaves share a leading axis of size n. Query
    batches are pytrees with a leading batch axis B; `query_batch` returns
    (ids (B, topk) int32 with -1 fill, scores (B, topk), n_candidates (B,)).
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    bucket_cap: int | None = None  # None -> exact (largest build-time bucket)

    corpus: Any = None
    size: int = 0
    sorted_keys: jax.Array | None = None  # (L, n) uint32
    perm: jax.Array | None = None         # (L, n) int32
    cap: int = 0
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        self._mults = _make_mults(self.seed, self.family.num_codes)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "DeviceLSHIndex":
        self.corpus = corpus
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        all_keys = _bucket_keys(self.family, self._mults, corpus,
                                batch_size).T             # (L, n)
        self.perm = jnp.argsort(all_keys, axis=1, stable=True).astype(jnp.int32)
        self.sorted_keys = jnp.take_along_axis(all_keys, self.perm, axis=1)
        if self.bucket_cap is None:
            self.cap = int(_max_run_length(self.sorted_keys))
            if self.cap * self.family.num_tables > n:
                warnings.warn(
                    f"DeviceLSHIndex: largest bucket has {self.cap} of {n} "
                    f"items, so the exact default cap gathers up to "
                    f"L*cap={self.cap * self.family.num_tables} candidates "
                    "per query (more than the corpus). The family is too "
                    "coarse for this data; raise num_codes / shrink "
                    "bucket_width, or pass an explicit bucket_cap to bound "
                    "per-query work at some recall cost.")
        else:
            self.cap = min(int(self.bucket_cap), n)
        return self

    # -- query --------------------------------------------------------------

    def candidates_batch(self, queries) -> tuple[jax.Array, jax.Array]:
        """-> (cand (B, L*cap) int32 with -1 fill, valid (B, L*cap) bool)."""
        return _device_candidates(self.family, self.sorted_keys, self.perm,
                                  jnp.asarray(self._mults), queries,
                                  cap=self.cap)

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over the L tables (single query)."""
        cand, valid = self.candidates_batch(_tree_index(x, None))
        cand = np.asarray(cand[0])
        return cand[np.asarray(valid[0])].astype(np.int64)

    def query_batch(self, queries, topk: int = 10):
        """-> (ids (B, topk), scores (B, topk), n_candidates (B,)) jax arrays.

        Rows with fewer than topk candidates are filled with id -1 and
        +inf distance / -inf similarity. One jit-compiled program end-to-end.
        """
        return _device_query(self.family, self.corpus, self.sorted_keys,
                             self.perm, jnp.asarray(self._mults), queries,
                             metric=self.metric, topk=topk, cap=self.cap)

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """Single-query convenience wrapper; same contract as HostLSHIndex."""
        ids, scores, n_cand = self.query_batch(_tree_index(x, None), topk)
        ids = np.asarray(ids[0])
        mask = ids >= 0
        return (ids[mask].astype(np.int64), np.asarray(scores[0])[mask],
                int(n_cand[0]))


LSHIndex = DeviceLSHIndex  # default deployment


# ---------------------------------------------------------------------------
# Mesh-sharded index (per-shard sorted tables + global top-k merge)
# ---------------------------------------------------------------------------


_PAD_KEY = np.uint32(0xFFFFFFFF)  # bucket key of shard-padding slots


def _shard_topk(metric, topk, cap, queries, corpus_s, sorted_keys_s, perm_s,
                keys, offset):
    """One shard's probe + re-rank -> ((B, topk) global ids, scores, n_cand).

    Operates on the shard-local (L, n_s) tables and (n_s, ...) corpus slice;
    ids come back already offset into the global corpus numbering (-1 fill).
    """
    cand, valid = _probe_tables(sorted_keys_s, perm_s, keys, cap)
    ids, scores, n_cand = _rank_candidates(metric, topk, queries, corpus_s,
                                           cand, valid)
    return jnp.where(ids >= 0, ids + offset, -1), scores, n_cand


def _merge_topk(metric, topk, ids, scores, n_cand):
    """(S, B, k) per-shard top-k -> global (ids, scores, n_cand).

    Shard-major concatenation + the same stable validity-aware selection as
    the single-device path: score ties fall back to concat position, which
    is (shard, within-shard rank) = ascending global id — so the merged
    top-k is bit-identical to ranking all candidates in one table.
    """
    s, b, k = ids.shape
    flat_ids = ids.transpose(1, 0, 2).reshape(b, s * k)
    flat_scores = scores.transpose(1, 0, 2).reshape(b, s * k)
    out_ids, out_scores = _select_topk(metric, topk, flat_ids, flat_scores,
                                       flat_ids >= 0)
    return out_ids, out_scores, n_cand.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap"))
def _sharded_query_vmap(family, corpus_sh, sorted_keys, perm, mults, offsets,
                        queries, *, metric, topk, cap):
    """Single-program sharded query without a mesh: vmap over the S axis.

    Used when fewer devices than shards exist (e.g. the 1-device tier-1
    run); identical math to the shard_map program in
    repro.distributed.index_sharding.
    """
    codes = family.hash_batch(queries)                   # replicated hashing
    keys = _combine_codes(codes, mults).T                # (L, B)
    per_shard = jax.vmap(
        lambda cs, sk, pm, off: _shard_topk(metric, topk, cap, queries, cs,
                                            sk, pm, keys, off)
    )(corpus_sh, sorted_keys, perm, offsets)
    return _merge_topk(metric, topk, *per_shard)


@functools.partial(jax.jit, static_argnames=("cap",))
def _sharded_candidates(family, sorted_keys, perm, mults, offsets, queries, *,
                        cap):
    """-> (cand (B, S*L*cap) global ids with -1 fill, valid bool mask)."""
    codes = family.hash_batch(queries)
    keys = _combine_codes(codes, mults).T
    def one(sk, pm, off):
        cand, valid = _probe_tables(sk, pm, keys, cap)
        return jnp.where(valid, cand + off, -1), valid
    cand, valid = jax.vmap(one)(sorted_keys, perm, offsets)  # (S, B, W)
    s, b, w = cand.shape
    return (cand.transpose(1, 0, 2).reshape(b, s * w),
            valid.transpose(1, 0, 2).reshape(b, s * w))


@dataclasses.dataclass
class ShardedLSHIndex:
    """Corpus-sharded (K, L) index over a named mesh axis with a global
    top-k merge — the multi-host layout of ``DeviceLSHIndex``.

    The corpus is partitioned into ``shards`` contiguous slices; each shard
    holds its own (L, n_s) sorted bucket keys + permutation (local ids, pad
    slots marked with the n_s sentinel) and its (n_s, ...) corpus slice.
    A query batch runs as one jit program: replicated hashing, per-shard
    searchsorted/gather/re-rank (via ``shard_map`` when a mesh carries the
    shard axis, ``vmap`` otherwise), then a global merge of the per-shard
    (scores, global ids). With the default exact cap the merged top-k is
    bit-identical to ``DeviceLSHIndex`` for any shard count.

    An explicit ``bucket_cap`` truncates each *shard's* slice of a bucket,
    so the union of candidates can exceed the single-device truncation (up
    to S*L*cap) — recall can only improve, throughput bounds are per shard.
    """

    family: LSHFamily
    metric: str = "euclidean"  # or "cosine"
    seed: int = 0
    shards: int = 1
    bucket_cap: int | None = None  # None -> exact (largest per-shard bucket)
    keep_corpus: bool = True   # False drops the unsharded copy after build
                               # (recall_at_k / brute-force references need
                               # it; at real multi-host scale it won't fit)

    corpus: Any = None             # original pytree (reference APIs only)
    corpus_sharded: Any = None     # leaves (S, n_s, ...), zero-padded
    size: int = 0
    shard_size: int = 0            # n_s = ceil(n / S)
    sorted_keys: jax.Array | None = None  # (S, L, n_s) uint32
    perm: jax.Array | None = None         # (S, L, n_s) int32, pad -> n_s
    offsets: jax.Array | None = None      # (S,) int32 global-id offsets
    cap: int = 0
    mesh: Any = None               # jax Mesh carrying the shard axis, or None
    mesh_axis: str | None = None
    _mults: np.ndarray | None = None

    def __post_init__(self):
        _check_metric(self.metric)
        if int(self.shards) < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        self._mults = _make_mults(self.seed, self.family.num_codes)

    # -- build --------------------------------------------------------------

    def build(self, corpus, batch_size: int = 1024) -> "ShardedLSHIndex":
        from repro.distributed import index_sharding  # deferred: core<->dist

        self.corpus = corpus if self.keep_corpus else None
        n = jax.tree.leaves(corpus)[0].shape[0]
        self.size = n
        s = int(self.shards)
        n_s = -(-n // s)
        self.shard_size = n_s
        pad = s * n_s - n
        all_keys = _bucket_keys(self.family, self._mults, corpus,
                                batch_size)                # (n, L)
        keys_sh = jnp.pad(all_keys, ((0, pad), (0, 0)),
                          constant_values=_PAD_KEY)
        keys_sh = keys_sh.reshape(s, n_s, -1).transpose(0, 2, 1)  # (S, L, n_s)
        perm_local = jnp.argsort(keys_sh, axis=2,
                                 stable=True).astype(jnp.int32)
        self.sorted_keys = jnp.take_along_axis(keys_sh, perm_local, axis=2)
        self.offsets = jnp.arange(s, dtype=jnp.int32) * n_s
        # pad slots (global id >= n) get the n_s sentinel: a probe that lands
        # on one (even via a _PAD_KEY collision) is masked as a miss.
        is_pad = (self.offsets[:, None, None] + perm_local) >= n
        self.perm = jnp.where(is_pad, n_s, perm_local)
        self.corpus_sharded = jax.tree.map(
            lambda a: jnp.pad(
                a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)
            ).reshape((s, n_s) + a.shape[1:]), corpus)
        if self.bucket_cap is None:
            self.cap = int(_max_run_length(
                self.sorted_keys.reshape(s * self.family.num_tables, n_s)))
            if self.cap * self.family.num_tables > n_s:
                warnings.warn(
                    f"ShardedLSHIndex: largest per-shard bucket has "
                    f"{self.cap} of {n_s} items, so the exact default cap "
                    f"gathers up to S*L*cap="
                    f"{s * self.family.num_tables * self.cap} candidates "
                    "per query (more than a shard holds). The family is too "
                    "coarse for this data; raise num_codes / shrink "
                    "bucket_width, or pass an explicit bucket_cap to bound "
                    "per-shard work at some recall cost.")
        else:
            self.cap = min(int(self.bucket_cap), n_s)
        self.mesh, self.mesh_axis = index_sharding.resolve_mesh(s)
        if self.mesh is not None:
            put = lambda tree: index_sharding.place_sharded(
                tree, self.mesh, self.mesh_axis)
            self.sorted_keys = put(self.sorted_keys)
            self.perm = put(self.perm)
            self.offsets = put(self.offsets)
            self.corpus_sharded = put(self.corpus_sharded)
        return self

    # -- query --------------------------------------------------------------

    def candidates_batch(self, queries) -> tuple[jax.Array, jax.Array]:
        """-> (cand (B, S*L*cap) global ids with -1 fill, valid bool)."""
        return _sharded_candidates(self.family, self.sorted_keys, self.perm,
                                   jnp.asarray(self._mults), self.offsets,
                                   queries, cap=self.cap)

    def candidates(self, x) -> np.ndarray:
        """Union of bucket members over shards and tables (single query)."""
        cand, valid = self.candidates_batch(_tree_index(x, None))
        cand = np.asarray(cand[0])
        return np.sort(cand[np.asarray(valid[0])]).astype(np.int64)

    def query_batch(self, queries, topk: int = 10):
        """Same contract as DeviceLSHIndex.query_batch; ids are global."""
        args = (self.family, self.corpus_sharded, self.sorted_keys, self.perm,
                jnp.asarray(self._mults), self.offsets, queries)
        if self.mesh is not None:
            from repro.distributed import index_sharding
            return index_sharding.shard_map_query(
                *args, metric=self.metric, topk=topk, cap=self.cap,
                mesh=self.mesh, axis=self.mesh_axis)
        return _sharded_query_vmap(*args, metric=self.metric, topk=topk,
                                   cap=self.cap)

    def query(self, x, topk: int = 10) -> tuple[np.ndarray, np.ndarray, int]:
        """Single-query convenience wrapper; same contract as HostLSHIndex."""
        ids, scores, n_cand = self.query_batch(_tree_index(x, None), topk)
        ids = np.asarray(ids[0])
        mask = ids >= 0
        return (ids[mask].astype(np.int64), np.asarray(scores[0])[mask],
                int(n_cand[0]))


# ---------------------------------------------------------------------------
# References / evaluation
# ---------------------------------------------------------------------------


def _score_batch(metric: str, x, ys):
    return jax.vmap(lambda y: _score_fn(metric)(x, y))(ys)


def brute_force(metric: str, x, corpus, topk: int = 10):
    """Exact top-k over the whole corpus (recall reference)."""
    scores = np.asarray(_score_batch(metric, x, corpus))
    order = np.argsort(scores if metric == "euclidean" else -scores)[:topk]
    return order, scores[order]


def recall_at_k(index, queries, topk: int = 10) -> dict[str, float]:
    """Mean recall@k of index.query vs. brute force over a query batch.

    Works for both HostLSHIndex and DeviceLSHIndex (any object with the
    single-query `query` contract plus `metric`/`corpus`/`size`).
    """
    n_q = jax.tree.leaves(queries)[0].shape[0]
    hits, total, cand_total = 0, 0, 0
    for i in range(n_q):
        q = _tree_index(queries, i)
        truth, _ = brute_force(index.metric, q, index.corpus, topk)
        got, _, n_cand = index.query(q, topk)
        hits += len(set(truth.tolist()) & set(got.tolist()))
        total += topk
        cand_total += n_cand
    return {
        "recall": hits / max(total, 1),
        "mean_candidates": cand_total / max(n_q, 1),
        "corpus_size": index.size,
    }
