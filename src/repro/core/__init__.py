"""TensorLSH core: the paper's contribution as a composable JAX library.

- tensor_formats: CP / TT tensor pytrees (Defs 4-7), densify, TT-SVD, CP-ALS
- contractions:   all dense/CP/TT inner-product paths at the paper's costs
- projections:    CP/TT/dense random projection families (Defs 8-9)
- lsh:            CP-E2LSH, TT-E2LSH, CP-SRP, TT-SRP + naive baselines (Defs 10-13)
- index:          multi-table (K, L) ANN indexes with exact in-format re-rank
                  (device-resident batched DeviceLSHIndex, mesh-sharded
                  ShardedLSHIndex + host-dict HostLSHIndex reference)
- probing:        query-directed multi-probe key expansion (T ranked bucket
                  keys per table) + the uniform/weighted sampling query modes
- theory:         closed-form collision probabilities, rank conditions
"""

from repro.core.tensor_formats import (CPTensor, TTTensor, cp_rademacher,
                                       cp_gaussian, tt_rademacher, tt_gaussian,
                                       cp_random_data, tt_random_data,
                                       cp_to_dense, tt_to_dense, dense_to_tt,
                                       cp_als, khatri_rao)
from repro.core.contractions import (inner, norm, distance, cosine_similarity,
                                     inner_cp_cp, inner_cp_tt, inner_tt_tt,
                                     inner_dense_cp, inner_dense_tt,
                                     inner_dense_dense)
from repro.core.projections import (CPProjection, TTProjection, DenseProjection,
                                    sample_cp_projection, sample_tt_projection,
                                    sample_dense_projection, project,
                                    project_batch)
from repro.core.lsh import (LSHFamily, make_family, e2lsh_discretize,
                            srp_discretize, pack_bits, unpack_bits,
                            naive_storage_size)
from repro.core.index import (LSHIndex, DeviceLSHIndex, HostLSHIndex,
                              ShardedLSHIndex, brute_force,
                              brute_force_batch, recall_at_k)
from repro.core.probing import QUERY_MODES, expansion_size, probe_keys
from repro.core.segments import SegmentStore, ShardedSegment, TableSegment
from repro.core import theory
