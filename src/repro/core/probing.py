"""Query-directed multi-probe key expansion for the segment indexes.

Single-probe queries visit one bucket per table; recall then scales with
the number of tables L — the dominant per-chip memory cost. Query-directed
multi-probe (Lv et al., "Multi-Probe LSH") instead visits, per table, the
T bucket keys most likely to hold near neighbours, so L can shrink several
fold at equal recall:

  * E2LSH kinds rank perturbations of the floor quantization by the
    residual r_k = (v_k + b_k) / w - floor(...): shifting code k by +1 has
    squared boundary distance (1 - r_k)^2, by -1 has r_k^2. The classic
    formulation expands perturbation sets with a min-heap; here the set is
    static — the 2K single-coordinate deltas plus every pair on distinct
    coordinates (score = sum of the singles) — and the ranking is one
    vectorized stable top-T, which covers the heap's reachable set up to
    pair depth (ample for the T <= 16 regime the indexes probe).
  * SRP kinds rank single bit flips by the projection margin |v_k| and
    pair flips by the margin sum — flip the lowest-margin bits first.

The expansion never re-hashes: the universal bucket key is linear in the
codes (key = sum_k codes[k] * mults[k] in uint32), so perturbing code k by
+/-1 shifts the key by exactly +/-mults[k] (mod 2^32) and every candidate
key is ``base_key + delta`` for a per-candidate delta. Slot 0 of the
emitted (B, L, T) tensor is always the base key; slots beyond the
expansion's reach (T - 1 > the candidate count) repeat the base key, which
the planner's global candidate dedup collapses for free.

Scores are ranked with a stable ascending sort, so ties break to the
lower candidate index — singles before pairs, +1 before -1, low coords
first — deterministically on every backend; the host-side reference
enumeration in tests/test_multiprobe.py mirrors the order exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import E2LSH_KINDS, _combine_codes

QUERY_MODES = ("topk", "uniform", "weighted")


def expansion_size(kind: str, num_codes: int) -> int:
    """Number of distinct perturbation candidates the expansion ranks
    (excluding the base bucket): 2K singles + 2K(K-1) distinct-coordinate
    pairs for E2LSH, K single flips + C(K, 2) pair flips for SRP."""
    k = num_codes
    if kind in E2LSH_KINDS:
        return 2 * k * k
    return k + k * (k - 1) // 2


def _pair_indices(coord: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Static (a, b) index pairs over the single-perturbation axis: every
    a < b whose perturbations touch distinct code coordinates (a +1/-1
    pair on one E2LSH coordinate is the identity, never a candidate)."""
    n = coord.size
    pa, pb = np.triu_indices(n, k=1)
    keep = coord[pa] != coord[pb]
    return pa[keep], pb[keep]


def scores_and_deltas(family, mults, aux):
    """Perturbation candidates of a hashed batch, in the static candidate
    order (2K / K singles first, then distinct-coordinate pairs).

    ``aux`` is the (B, L, K) tensor from ``family.hash_batch_aux``. Returns
    (scores (B, L, C) float32 — lower probes earlier, deltas (B, L, C)
    uint32 key shifts), C = ``expansion_size``.
    """
    k = family.num_codes
    mults = jnp.asarray(mults, jnp.uint32)
    if family.kind in E2LSH_KINDS:
        r = aux                                           # floor residuals
        s1 = jnp.concatenate([(1.0 - r) ** 2, r ** 2], axis=-1)  # (B, L, 2K)
        d1 = jnp.concatenate([mults, jnp.uint32(0) - mults])     # (2K,)
        d1 = jnp.broadcast_to(d1, s1.shape)
        coord = np.concatenate([np.arange(k), np.arange(k)])
    else:
        s1 = jnp.abs(aux)                                 # |margin|, (B, L, K)
        # flipping a set bit (v > 0, code 1 -> 0) subtracts mults[k]
        d1 = jnp.where(aux > 0, jnp.uint32(0) - mults, mults)
        coord = np.arange(k)
    pa, pb = _pair_indices(coord)
    scores = jnp.concatenate([s1, s1[..., pa] + s1[..., pb]], axis=-1)
    deltas = jnp.concatenate([d1, d1[..., pa] + d1[..., pb]], axis=-1)
    return scores, deltas


@functools.partial(jax.jit, static_argnames=("probes",))
def probe_keys(family, mults, queries, *, probes: int) -> jax.Array:
    """-> (B, L, T) uint32 ranked candidate bucket keys, T = ``probes``.

    Slot 0 is the base bucket key (bit-identical to ``hash_keys``); slots
    1..T-1 are the top-(T-1) perturbation keys by ascending score (stable —
    ties break to the static candidate order); slots past the expansion
    size repeat the base key. One fused program: projection -> discretize ->
    combine -> expansion ranking.
    """
    t = int(probes)
    if t < 1:
        raise ValueError(f"probes must be >= 1, got {probes}")
    mults = jnp.asarray(mults)
    codes, aux = family.hash_batch_aux(queries)
    base = _combine_codes(codes, mults)                   # (B, L)
    if t == 1:
        return base[..., None]
    scores, deltas = scores_and_deltas(family, mults, aux)
    n = min(t - 1, scores.shape[-1])
    order = jnp.argsort(scores, axis=-1, stable=True)[..., :n]
    keys = base[..., None] + jnp.take_along_axis(deltas, order, axis=-1)
    keys = jnp.concatenate([base[..., None], keys], axis=-1)
    if 1 + n < t:
        pad = jnp.broadcast_to(base[..., None],
                               base.shape + (t - 1 - n,))
        keys = jnp.concatenate([keys, pad], axis=-1)
    return keys
