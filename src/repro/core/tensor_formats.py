"""CP and TT tensor formats (paper §3.3, Definitions 4-7).

A tensor X in R^{d_1 x ... x d_N} is stored either densely, in CP format

    X = scale * sum_r  a_r^(1) o a_r^(2) o ... o a_r^(N)          (Def. 4)

with factor matrices A^(n) in R^{d_n x R}, or in TT format

    X[i_1,...,i_N] = scale * G1[:,i_1,:] G2[:,i_2,:] ... GN[:,i_N,:]   (Def. 5)

with cores G^(n) in R^{r_{n-1} x d_n x r_n}, r_0 = r_N = 1.

Both formats are registered JAX pytrees; `scale` is static metadata so it can
encode the paper's 1/sqrt(R) (Def. 6) and 1/sqrt(R^{N-1}) (Def. 7) exactly
while factor/core entries remain raw +-1 Rademacher samples (MXU-friendly).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CPTensor:
    """Rank-R CP decomposition tensor (paper Definition 4)."""

    factors: tuple[jax.Array, ...]  # each (d_n, R)
    scale: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    @property
    def rank(self) -> int:
        return self.factors[0].shape[-1]

    @property
    def ndim(self) -> int:
        return len(self.factors)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(f.shape[0] for f in self.factors)

    @property
    def dtype(self):
        return self.factors[0].dtype

    def storage_size(self) -> int:
        """Number of stored scalars: O(N d R) (paper Remark 3)."""
        return sum(int(np.prod(f.shape)) for f in self.factors)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TTTensor:
    """Rank-R tensor-train decomposition tensor (paper Definition 5)."""

    cores: tuple[jax.Array, ...]  # each (r_{n-1}, d_n, r_n); r_0 = r_N = 1
    scale: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    @property
    def ranks(self) -> tuple[int, ...]:
        # (r_0, r_1, ..., r_N)
        return tuple(c.shape[0] for c in self.cores) + (self.cores[-1].shape[-1],)

    @property
    def rank(self) -> int:
        return max(self.ranks)

    @property
    def ndim(self) -> int:
        return len(self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(c.shape[1] for c in self.cores)

    @property
    def dtype(self):
        return self.cores[0].dtype

    def storage_size(self) -> int:
        """Number of stored scalars: O(N d R^2) (paper Remark 5)."""
        return sum(int(np.prod(c.shape)) for c in self.cores)


# ---------------------------------------------------------------------------
# Random tensors (paper Definitions 6 and 7, plus Gaussian data tensors)
# ---------------------------------------------------------------------------


def _rademacher(key, shape, dtype):
    return (2.0 * jax.random.bernoulli(key, 0.5, shape).astype(dtype)) - 1.0


def cp_rademacher(key: jax.Array, dims: Sequence[int], rank: int,
                  dtype=jnp.float32) -> CPTensor:
    """CP-Rademacher distributed tensor, P ~ CP_Rad(R) (paper Definition 6).

    P = (1/sqrt(R)) [[A^(1), ..., A^(N)]], A^(n)[i,j] iid +-1 w.p. 1/2.
    """
    keys = jax.random.split(key, len(dims))
    factors = tuple(_rademacher(k, (d, rank), dtype) for k, d in zip(keys, dims))
    return CPTensor(factors=factors, scale=1.0 / math.sqrt(rank))


def cp_gaussian(key: jax.Array, dims: Sequence[int], rank: int,
                dtype=jnp.float32) -> CPTensor:
    """CP-Gaussian distributed tensor, P ~ CP_N(R) (paper Definition 6)."""
    keys = jax.random.split(key, len(dims))
    factors = tuple(jax.random.normal(k, (d, rank), dtype) for k, d in zip(keys, dims))
    return CPTensor(factors=factors, scale=1.0 / math.sqrt(rank))


def _tt_core_shapes(dims: Sequence[int], rank: int) -> list[tuple[int, int, int]]:
    n = len(dims)
    shapes = []
    for i, d in enumerate(dims):
        r_prev = 1 if i == 0 else rank
        r_next = 1 if i == n - 1 else rank
        shapes.append((r_prev, d, r_next))
    return shapes


def tt_rademacher(key: jax.Array, dims: Sequence[int], rank: int,
                  dtype=jnp.float32) -> TTTensor:
    """TT-Rademacher distributed tensor, T ~ TT_Rad(R) (paper Definition 7).

    T = (1/sqrt(R^{N-1})) <<G1, ..., GN>>, core entries iid +-1 w.p. 1/2.
    """
    shapes = _tt_core_shapes(dims, rank)
    keys = jax.random.split(key, len(shapes))
    cores = tuple(_rademacher(k, s, dtype) for k, s in zip(keys, shapes))
    return TTTensor(cores=cores, scale=1.0 / math.sqrt(rank ** (len(dims) - 1)))


def tt_gaussian(key: jax.Array, dims: Sequence[int], rank: int,
                dtype=jnp.float32) -> TTTensor:
    """TT-Gaussian distributed tensor, T ~ TT_N(R) (paper Definition 7)."""
    shapes = _tt_core_shapes(dims, rank)
    keys = jax.random.split(key, len(shapes))
    cores = tuple(jax.random.normal(k, s, dtype) for k, s in zip(keys, shapes))
    return TTTensor(cores=cores, scale=1.0 / math.sqrt(rank ** (len(dims) - 1)))


def cp_random_data(key: jax.Array, dims: Sequence[int], rank: int,
                   dtype=jnp.float32) -> CPTensor:
    """A random *data* tensor given in rank-R^ CP decomposition format."""
    keys = jax.random.split(key, len(dims))
    factors = tuple(
        jax.random.normal(k, (d, rank), dtype) / math.sqrt(d) for k, d in zip(keys, dims)
    )
    return CPTensor(factors=factors, scale=1.0)


def tt_random_data(key: jax.Array, dims: Sequence[int], rank: int,
                   dtype=jnp.float32) -> TTTensor:
    """A random *data* tensor given in rank-R^ TT decomposition format."""
    shapes = _tt_core_shapes(dims, rank)
    keys = jax.random.split(key, len(shapes))
    cores = tuple(
        jax.random.normal(k, s, dtype) / math.sqrt(s[0] * s[1]) ** 0.5
        for k, s in zip(keys, shapes)
    )
    return TTTensor(cores=cores, scale=1.0)


# ---------------------------------------------------------------------------
# Densification (test oracles; exponential O(d^N) memory, small shapes only)
# ---------------------------------------------------------------------------


def cp_to_dense(x: CPTensor) -> jax.Array:
    """Materialize a CP tensor: X = scale * sum_r (x)_n a_r^(n)."""
    acc = x.factors[0]  # (d1, R)
    for f in x.factors[1:]:
        acc = acc[..., None, :] * f  # (..., d_k, R)
    return x.scale * jnp.sum(acc, axis=-1)


def tt_to_dense(x: TTTensor) -> jax.Array:
    """Materialize a TT tensor via sequential core contraction."""
    acc = x.cores[0]  # (1, d1, r1)
    acc = acc.reshape(acc.shape[1], acc.shape[2])  # (d1, r1)
    for core in x.cores[1:]:
        acc = jnp.tensordot(acc, core, axes=(-1, 0))  # (..., d_k, r_k)
    return x.scale * acc.reshape(acc.shape[:-1])


def dense_to_tt(x: jax.Array, max_rank: int, eps: float = 0.0) -> TTTensor:
    """TT-SVD (Oseledets 2011): decompose a dense tensor into TT format.

    Used for round-trip property tests — `TT rank can be computed efficiently`
    (paper §2.2), in contrast to CP rank which is NP-hard.
    """
    dims = x.shape
    n = len(dims)
    cores = []
    r_prev = 1
    c = x.reshape(r_prev * dims[0], -1)
    for i in range(n - 1):
        u, s, vt = jnp.linalg.svd(c, full_matrices=False)
        if eps > 0.0:
            keep = int(jnp.sum(s > eps * s[0]))
            r = max(1, min(max_rank, keep))
        else:
            r = min(max_rank, s.shape[0])
        u, s, vt = u[:, :r], s[:r], vt[:r]
        cores.append(u.reshape(r_prev, dims[i], r))
        c = (s[:, None] * vt).reshape(r * dims[i + 1], -1) if i + 1 < n - 1 else (s[:, None] * vt)
        r_prev = r
    cores.append(c.reshape(r_prev, dims[-1], 1))
    return TTTensor(cores=tuple(cores), scale=1.0)


def cp_als(x: jax.Array, rank: int, iters: int = 25, key=None) -> CPTensor:
    """Plain ALS fit of a rank-R CP model to a small dense tensor.

    Only for tests/examples. The paper never requires computing a CP
    decomposition (NP-hard, §2.2); inputs are assumed *given* in CP format.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    dims = x.shape
    n = len(dims)
    keys = jax.random.split(key, n)
    factors = [jax.random.normal(k, (d, rank), x.dtype) for k, d in zip(keys, dims)]

    def unfold(t, mode):
        return jnp.moveaxis(t, mode, 0).reshape(dims[mode], -1)

    for _ in range(iters):
        for mode in range(n):
            others = [factors[m] for m in range(n) if m != mode]
            gram = math.prod(1 for _ in others)  # placeholder to keep mypy calm
            g = jnp.ones((rank, rank), x.dtype)
            for f in others:
                g = g * (f.T @ f)
            kr = None  # Khatri-Rao of the other factors, reverse order
            for f in reversed(others):
                kr = f if kr is None else (kr[:, None, :] * f[None, :, :]).reshape(-1, rank)
            mttkrp = unfold(x, mode) @ kr
            factors[mode] = jnp.linalg.solve(g.T, mttkrp.T).T
    return CPTensor(factors=tuple(factors), scale=1.0)


def khatri_rao(mats: Sequence[jax.Array]) -> jax.Array:
    """Column-wise Khatri-Rao product of (d_n, R) matrices -> (prod d_n, R)."""
    out = mats[0]
    for m in mats[1:]:
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, m.shape[1])
    return out
