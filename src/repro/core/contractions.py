"""Inner products between dense / CP / TT tensors with the paper's costs.

The LSH hash code (Definitions 10-13) is a discretization of <P, X> where P
is a CP- or TT-Rademacher projection tensor. The whole efficiency claim of the
paper rests on evaluating <P, X> *without reshaping X to a d^N vector*:

  <CP(R^), CP(R)>  : O(N d max{R,R^}^2)   — per-mode Gram matrices, Hadamard
  <CP(R^), TT(R)>  : O(N d max{R,R^}^3)   — chain with a (R^ x r) state
  <TT(R^), TT(R)>  : O(N d max{R,R^}^3)   — chain with a (r^ x r) state
  <dense,  CP(R)>  : O(R d^N)             — mode-by-mode contraction
  <dense,  TT(R)>  : O(R^2 d^N)           — mode-by-mode contraction
  <dense,  dense>  : O(d^N)               — the naive-method primitive

(paper Remarks 1-6 and Tables 1-2). All functions are jit-compatible and
dispatch via `inner(x, y)`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tensor_formats import CPTensor, TTTensor


def inner_dense_dense(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.vdot(x, y)


def inner_cp_cp(x: CPTensor, y: CPTensor) -> jax.Array:
    """<X, Y> for two CP tensors: sum of Hadamard product of per-mode Grams.

    <X, Y> = sx*sy * sum_{r,q} prod_n (A_x^(n)T A_y^(n))[r, q]
    Cost: N matmuls of (R^ x d)(d x R) -> O(N d R^ R).
    """
    h = None
    for fx, fy in zip(x.factors, y.factors):
        g = fx.T @ fy  # (R^, R)
        h = g if h is None else h * g
    return (x.scale * y.scale) * jnp.sum(h)


def inner_tt_tt(x: TTTensor, y: TTTensor) -> jax.Array:
    """<X, Y> for two TT tensors via the transfer-matrix chain.

    State S in R^{r^_{n} x r_{n}}; per mode: S' = sum_i Gx[:,i,:]^T S Gy[:,i,:],
    computed as einsum('ab,aic,bid->cd'). Cost O(N d max{R^,R}^3).
    """
    s = jnp.ones((1, 1), x.cores[0].dtype)
    for gx, gy in zip(x.cores, y.cores):
        s = jnp.einsum("ab,aic,bid->cd", s, gx, gy)
    return (x.scale * y.scale) * s.reshape(())


def inner_cp_tt(x: CPTensor, y: TTTensor) -> jax.Array:
    """<X, Y> for X in CP format and Y in TT format.

    For each CP rank r the rank-1 component contracts through the TT chain;
    batched over r with a (R^ x r_n) state. Cost O(N d max{R^,R}^3) — matches
    the paper's CP-E2LSH-on-TT-input / TT-E2LSH-on-CP-input complexity.
    """
    rank = x.rank
    s = jnp.ones((rank, 1), x.factors[0].dtype)
    for a, g in zip(x.factors, y.cores):
        # s: (R^, r_prev), g: (r_prev, d, r_next), a: (d, R^)
        s = jnp.einsum("ra,aib,ir->rb", s, g, a)
    return (x.scale * y.scale) * jnp.sum(s)


def inner_dense_cp(x: jax.Array, y: CPTensor) -> jax.Array:
    """<X, Y> for dense X, CP Y: contract one mode at a time, keep rank axis.

    Cost O(R d^N) and O(d^{N-1} R) intermediate memory — never materializes
    the d^N projection vector of the naive method.
    """
    t = jnp.tensordot(y.factors[0], x, axes=(0, 0))  # (R, d2, ..., dN)
    for f in y.factors[1:]:
        # t: (R, d_k, rest...), f: (d_k, R) -> diagonal in R
        t = jnp.einsum("ri...,ir->r...", t, f)
    return y.scale * jnp.sum(t)


def inner_dense_tt(x: jax.Array, y: TTTensor) -> jax.Array:
    """<X, Y> for dense X, TT Y: sweep cores left to right. Cost O(R^2 d^N)."""
    g0 = y.cores[0]  # (1, d1, r1)
    t = jnp.tensordot(g0[0], x, axes=(0, 0))  # (r1, d2, ..., dN)
    for core in y.cores[1:]:
        # t: (r_prev, d_k, rest...), core: (r_prev, d_k, r_next)
        t = jnp.einsum("ai...,air->r...", t, core)
    return y.scale * t.reshape(())


def inner(x, y) -> jax.Array:
    """Polymorphic <x, y> over {dense, CP, TT} x {dense, CP, TT}."""
    if isinstance(x, CPTensor):
        if isinstance(y, CPTensor):
            return inner_cp_cp(x, y)
        if isinstance(y, TTTensor):
            return inner_cp_tt(x, y)
        return inner_dense_cp(y, x)
    if isinstance(x, TTTensor):
        if isinstance(y, CPTensor):
            return inner_cp_tt(y, x)
        if isinstance(y, TTTensor):
            return inner_tt_tt(x, y)
        return inner_dense_tt(y, x)
    if isinstance(y, CPTensor):
        return inner_dense_cp(x, y)
    if isinstance(y, TTTensor):
        return inner_dense_tt(x, y)
    return inner_dense_dense(x, y)


def norm(x) -> jax.Array:
    """Frobenius norm ||X||_F computed in-format (paper §3.3)."""
    return jnp.sqrt(jnp.maximum(inner(x, x), 0.0))


def distance(x, y) -> jax.Array:
    """Euclidean distance ||X - Y||_F (paper Eq. 3.5) computed in-format via
    ||X||^2 + ||Y||^2 - 2<X,Y> (no densification)."""
    d2 = inner(x, x) + inner(y, y) - 2.0 * inner(x, y)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def cosine_similarity(x, y) -> jax.Array:
    """cos(theta) = <X,Y> / (||X||_F ||Y||_F) (paper Eq. 3.6), in-format."""
    return inner(x, y) / (norm(x) * norm(y))
