"""Tensorized random projections (paper §3.4, Definitions 8-9).

A projection family maps X in R^{d_1 x...x d_N} to R^K:

    f_CP(R)(X)_k = (1/sqrt(K)) <P_k, X>,  P_k ~ CP_Rad(R)   (Def. 8)
    f_TT(R)(X)_k = (1/sqrt(K)) <T_k, X>,  T_k ~ TT_Rad(R)   (Def. 9)

The K projection tensors are stored *stacked* — CP: per-mode (K, d_n, R)
factor stacks; TT: per-mode (K, r, d_n, r) core stacks — so that all K inner
products lower to a handful of batched einsums (MXU matmuls on TPU) instead of
K independent chains. The LSH families (lsh.py) use `normalize=False` because
Definitions 10-13 hash the raw <P, X>.

`project_batch` is the primal evaluation path: every projection x input
format pair has an explicit *batched* contraction over a (B, ...) input
batch (no `vmap` of a per-example program — the hot hashing loop of the
index layer runs through here). `project` is the batch-of-1 special case.

For a batch of **dense** inputs against a CP/TT projection the batched path
first densifies the K projection tensors (O(K d^N R) once per call) and
runs one (B, d^N) x (d^N, K) matmul: per example that is O(K d^N) instead
of the O(K R d^N) of the mode-by-mode chain — with a dense input there is
no d^N to avoid, so amortizing the densification over the batch is a strict
win for B >= R. CP/TT-format inputs keep the in-format contractions at the
paper's O(K N d R^2) costs.

`DenseProjection` is the paper's naive baseline: a (K, prod(d_n)) Gaussian
matrix applied to the reshaped tensor — O(K d^N) space and time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tensor_formats import CPTensor, TTTensor


def _rademacher(key, shape, dtype):
    return (2.0 * jax.random.bernoulli(key, 0.5, shape).astype(dtype)) - 1.0


def _sample(key, shape, dist, dtype):
    if dist == "rademacher":
        return _rademacher(key, shape, dtype)
    if dist == "gaussian":
        return jax.random.normal(key, shape, dtype)
    raise ValueError(f"unknown dist {dist!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CPProjection:
    """K stacked CP_Rad(R) / CP_N(R) projection tensors (Definitions 6, 8)."""

    factors: tuple[jax.Array, ...]  # each (K, d_n, R)
    scale: float = dataclasses.field(metadata=dict(static=True))  # 1/sqrt(R) [* 1/sqrt(K)]

    @property
    def num_hashes(self) -> int:
        return self.factors[0].shape[0]

    @property
    def rank(self) -> int:
        return self.factors[0].shape[-1]

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(f.shape[1] for f in self.factors)

    def storage_size(self) -> int:
        """O(K N d R) stored scalars (paper Remark 1)."""
        return sum(int(np.prod(f.shape)) for f in self.factors)

    def single(self, k: int) -> CPTensor:
        """The k-th projection tensor P_k as a plain CPTensor."""
        return CPTensor(tuple(f[k] for f in self.factors), scale=self.scale)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TTProjection:
    """K stacked TT_Rad(R) / TT_N(R) projection tensors (Definitions 7, 9)."""

    cores: tuple[jax.Array, ...]  # each (K, r_{n-1}, d_n, r_n)
    scale: float = dataclasses.field(metadata=dict(static=True))

    @property
    def num_hashes(self) -> int:
        return self.cores[0].shape[0]

    @property
    def rank(self) -> int:
        return max(max(c.shape[1], c.shape[3]) for c in self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(c.shape[2] for c in self.cores)

    def storage_size(self) -> int:
        """O(K N d R^2) stored scalars (paper Remark 2)."""
        return sum(int(np.prod(c.shape)) for c in self.cores)

    def single(self, k: int) -> TTTensor:
        return TTTensor(tuple(c[k] for c in self.cores), scale=self.scale)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseProjection:
    """Naive-method baseline: (K, prod d_n) Gaussian matrix (paper §2)."""

    matrix: jax.Array  # (K, prod(dims))
    dims_: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    scale: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    @property
    def num_hashes(self) -> int:
        return self.matrix.shape[0]

    @property
    def dims(self) -> tuple[int, ...]:
        return self.dims_

    def storage_size(self) -> int:
        """O(K d^N) stored scalars — exponential in N."""
        return int(np.prod(self.matrix.shape))


Projection = CPProjection | TTProjection | DenseProjection


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------


def sample_cp_projection(key, num_hashes: int, dims: Sequence[int], rank: int,
                         dist: str = "rademacher", normalize: bool = False,
                         dtype=jnp.float32) -> CPProjection:
    keys = jax.random.split(key, len(dims))
    factors = tuple(
        _sample(k, (num_hashes, d, rank), dist, dtype) for k, d in zip(keys, dims)
    )
    scale = 1.0 / math.sqrt(rank)
    if normalize:  # the 1/sqrt(K) of Definition 8
        scale /= math.sqrt(num_hashes)
    return CPProjection(factors=factors, scale=scale)


def sample_tt_projection(key, num_hashes: int, dims: Sequence[int], rank: int,
                         dist: str = "rademacher", normalize: bool = False,
                         dtype=jnp.float32) -> TTProjection:
    n = len(dims)
    keys = jax.random.split(key, n)
    cores = []
    for i, (k, d) in enumerate(zip(keys, dims)):
        r_prev = 1 if i == 0 else rank
        r_next = 1 if i == n - 1 else rank
        cores.append(_sample(k, (num_hashes, r_prev, d, r_next), dist, dtype))
    scale = 1.0 / math.sqrt(rank ** (n - 1))
    if normalize:
        scale /= math.sqrt(num_hashes)
    return TTProjection(cores=tuple(cores), scale=scale)


def sample_dense_projection(key, num_hashes: int, dims: Sequence[int],
                            dist: str = "gaussian", normalize: bool = False,
                            dtype=jnp.float32) -> DenseProjection:
    size = int(np.prod(list(dims)))
    m = _sample(key, (num_hashes, size), dist, dtype)
    scale = 1.0 / math.sqrt(num_hashes) if normalize else 1.0
    return DenseProjection(matrix=m, dims_=tuple(dims), scale=scale)


# ---------------------------------------------------------------------------
# Projection materialization (dense-input fast path)
# ---------------------------------------------------------------------------

# Above this many elements of peak intermediate (K * d^N * R — the einsum
# chains below carry a trailing rank axis until the final sum/slice) the
# densified projection stack is not materialized and the mode-by-mode
# chain is used instead.
MATERIALIZE_LIMIT = 1 << 24


def _materialize_cp(p: CPProjection) -> jax.Array:
    """All K projection tensors densified at once -> (K, d_1, ..., d_N)."""
    acc = p.factors[0]                                    # (K, d_1, R)
    for f in p.factors[1:]:
        acc = jnp.einsum("k...r,kir->k...ir", acc, f)
    return p.scale * jnp.sum(acc, axis=-1)


def _materialize_tt(p: TTProjection) -> jax.Array:
    """All K projection tensors densified at once -> (K, d_1, ..., d_N)."""
    acc = p.cores[0][:, 0]                                # (K, d_1, r_1)
    for c in p.cores[1:]:
        acc = jnp.einsum("k...a,kaib->k...ib", acc, c)
    return p.scale * acc[..., 0]


def _can_materialize(p: Projection) -> bool:
    return (p.num_hashes * int(np.prod(p.dims)) * p.rank
            <= MATERIALIZE_LIMIT)


# ---------------------------------------------------------------------------
# Batched projection application: (B, ...) inputs -> (B, K) values.
# Every path is an explicit batched einsum program — the primal evaluation
# the hashing pipeline fuses with discretization and code-combine.
# ---------------------------------------------------------------------------


def _project_cp_on_cp_batch(p: CPProjection, xs: CPTensor) -> jax.Array:
    """(B, K) values of <P_k, X_z>, X in CP format. O(B K N d R R^)."""
    h = None
    for a, f in zip(xs.factors, p.factors):               # (B, d, R^), (K, d, R)
        g = jnp.einsum("zir,kiq->zkrq", a, f)             # per-mode Gram
        h = g if h is None else h * g
    return (xs.scale * p.scale) * jnp.sum(h, axis=(2, 3))


def _project_cp_on_tt_batch(p: CPProjection, xs: TTTensor) -> jax.Array:
    """(B, K) values of <P_k, X_z>, X in TT format. O(B K N d max{R,R^}^3)."""
    b = xs.cores[0].shape[0]
    s = jnp.ones((b, p.num_hashes, p.rank, 1), xs.cores[0].dtype)
    for g, f in zip(xs.cores, p.factors):
        # s: (B, K, R, a), g: (B, a, d, c), f: (K, d, R)
        s = jnp.einsum("zkra,zaic,kir->zkrc", s, g, f)
    return (xs.scale * p.scale) * jnp.sum(s, axis=(2, 3))


def _project_cp_on_dense_batch(p: CPProjection, xs: jax.Array) -> jax.Array:
    """(B, K) values for dense inputs.

    Default: densify the K projection tensors once (O(K R d^N)) and run one
    (B, d^N) x (d^N, K) matmul — O(K d^N) per example, an R-fold saving over
    the chain. Falls back to the O(K R d^N)-per-example mode-by-mode chain
    when the densified stack would exceed MATERIALIZE_LIMIT.
    """
    if _can_materialize(p):
        m = _materialize_cp(p)
        return jnp.einsum("zd,kd->zk", xs.reshape(xs.shape[0], -1),
                          m.reshape(m.shape[0], -1))
    t = jnp.einsum("zi...,kir->zkr...", xs, p.factors[0])
    for f in p.factors[1:]:
        t = jnp.einsum("zkri...,kir->zkr...", t, f)
    return p.scale * jnp.sum(t, axis=2)


def _project_tt_on_tt_batch(p: TTProjection, xs: TTTensor) -> jax.Array:
    """(B, K) values of <T_k, X_z>, X in TT format. O(B K N d max{R,R^}^3)."""
    b = xs.cores[0].shape[0]
    s = jnp.ones((b, p.num_hashes, 1, 1), xs.cores[0].dtype)
    for gx, gp in zip(xs.cores, p.cores):
        # s: (B, K, a, b), gx: (B, a, d, c), gp: (K, b, d, e)
        s = jnp.einsum("zkab,zaic,kbie->zkce", s, gx, gp)
    return (xs.scale * p.scale) * s.reshape(b, p.num_hashes)


def _project_tt_on_cp_batch(p: TTProjection, xs: CPTensor) -> jax.Array:
    """(B, K) values of <T_k, X_z>, X in CP format. O(B K N d max{R,R^}^3)."""
    b = xs.factors[0].shape[0]
    s = jnp.ones((b, p.num_hashes, xs.factors[0].shape[-1], 1),
                 xs.factors[0].dtype)
    for a, gp in zip(xs.factors, p.cores):
        # s: (B, K, R^, b), gp: (K, b, d, e), a: (B, d, R^)
        s = jnp.einsum("zkrb,kbie,zir->zkre", s, gp, a)
    return (xs.scale * p.scale) * jnp.sum(s, axis=(2, 3))


def _project_tt_on_dense_batch(p: TTProjection, xs: jax.Array) -> jax.Array:
    """(B, K) values for dense inputs: densify-once + one matmul (see the
    CP variant), falling back to the per-mode chain above the size limit."""
    if _can_materialize(p):
        m = _materialize_tt(p)
        return jnp.einsum("zd,kd->zk", xs.reshape(xs.shape[0], -1),
                          m.reshape(m.shape[0], -1))
    t = jnp.einsum("zi...,kair->zkr...", xs, p.cores[0])  # a == 1
    for core in p.cores[1:]:
        t = jnp.einsum("zkai...,kair->zkr...", t, core)
    return p.scale * t.reshape(t.shape[0], p.num_hashes)


def _densify_batch(xs):
    """Materialize a batched CP/TT input pytree -> (B, d_1, ..., d_N)."""
    if isinstance(xs, CPTensor):
        acc = xs.factors[0]                               # (B, d_1, R)
        for f in xs.factors[1:]:
            acc = jnp.einsum("z...r,zir->z...ir", acc, f)
        return xs.scale * jnp.sum(acc, axis=-1)
    if isinstance(xs, TTTensor):
        acc = xs.cores[0][:, 0]                           # (B, d_1, r_1)
        for c in xs.cores[1:]:
            acc = jnp.einsum("z...a,zaib->z...ib", acc, c)
        return xs.scale * acc[..., 0]
    return xs


def _project_dense_on_any_batch(p: DenseProjection, xs) -> jax.Array:
    """(B, K) naive-method values: materialize + one matmul (paper §2)."""
    flat = _densify_batch(xs)
    return p.scale * jnp.einsum("zd,kd->zk",
                                flat.reshape(flat.shape[0], -1), p.matrix)


def project_batch(p: Projection, xs) -> jax.Array:
    """Apply a projection family to a batch (leading axis on every leaf) of
    tensors -> (B, K) projected values. The primal evaluation path."""
    if isinstance(p, CPProjection):
        if isinstance(xs, CPTensor):
            return _project_cp_on_cp_batch(p, xs)
        if isinstance(xs, TTTensor):
            return _project_cp_on_tt_batch(p, xs)
        return _project_cp_on_dense_batch(p, xs)
    if isinstance(p, TTProjection):
        if isinstance(xs, CPTensor):
            return _project_tt_on_cp_batch(p, xs)
        if isinstance(xs, TTTensor):
            return _project_tt_on_tt_batch(p, xs)
        return _project_tt_on_dense_batch(p, xs)
    if isinstance(p, DenseProjection):
        return _project_dense_on_any_batch(p, xs)
    raise TypeError(f"unknown projection {type(p)}")


def project(p: Projection, x) -> jax.Array:
    """Apply a projection family to one tensor -> (K,) projected values
    (the batch-of-1 case of ``project_batch``)."""
    return project_batch(p, jax.tree.map(lambda a: a[None], x))[0]
