"""Tensorized random projections (paper §3.4, Definitions 8-9).

A projection family maps X in R^{d_1 x...x d_N} to R^K:

    f_CP(R)(X)_k = (1/sqrt(K)) <P_k, X>,  P_k ~ CP_Rad(R)   (Def. 8)
    f_TT(R)(X)_k = (1/sqrt(K)) <T_k, X>,  T_k ~ TT_Rad(R)   (Def. 9)

The K projection tensors are stored *stacked* — CP: per-mode (K, d_n, R)
factor stacks; TT: per-mode (K, r, d_n, r) core stacks — so that all K inner
products lower to a handful of batched einsums (MXU matmuls on TPU) instead of
K independent chains. The LSH families (lsh.py) use `normalize=False` because
Definitions 10-13 hash the raw <P, X>.

`DenseProjection` is the paper's naive baseline: a (K, prod(d_n)) Gaussian
matrix applied to the reshaped tensor — O(K d^N) space and time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tensor_formats import CPTensor, TTTensor


def _rademacher(key, shape, dtype):
    return (2.0 * jax.random.bernoulli(key, 0.5, shape).astype(dtype)) - 1.0


def _sample(key, shape, dist, dtype):
    if dist == "rademacher":
        return _rademacher(key, shape, dtype)
    if dist == "gaussian":
        return jax.random.normal(key, shape, dtype)
    raise ValueError(f"unknown dist {dist!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CPProjection:
    """K stacked CP_Rad(R) / CP_N(R) projection tensors (Definitions 6, 8)."""

    factors: tuple[jax.Array, ...]  # each (K, d_n, R)
    scale: float = dataclasses.field(metadata=dict(static=True))  # 1/sqrt(R) [* 1/sqrt(K)]

    @property
    def num_hashes(self) -> int:
        return self.factors[0].shape[0]

    @property
    def rank(self) -> int:
        return self.factors[0].shape[-1]

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(f.shape[1] for f in self.factors)

    def storage_size(self) -> int:
        """O(K N d R) stored scalars (paper Remark 1)."""
        return sum(int(np.prod(f.shape)) for f in self.factors)

    def single(self, k: int) -> CPTensor:
        """The k-th projection tensor P_k as a plain CPTensor."""
        return CPTensor(tuple(f[k] for f in self.factors), scale=self.scale)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TTProjection:
    """K stacked TT_Rad(R) / TT_N(R) projection tensors (Definitions 7, 9)."""

    cores: tuple[jax.Array, ...]  # each (K, r_{n-1}, d_n, r_n)
    scale: float = dataclasses.field(metadata=dict(static=True))

    @property
    def num_hashes(self) -> int:
        return self.cores[0].shape[0]

    @property
    def rank(self) -> int:
        return max(max(c.shape[1], c.shape[3]) for c in self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(c.shape[2] for c in self.cores)

    def storage_size(self) -> int:
        """O(K N d R^2) stored scalars (paper Remark 2)."""
        return sum(int(np.prod(c.shape)) for c in self.cores)

    def single(self, k: int) -> TTTensor:
        return TTTensor(tuple(c[k] for c in self.cores), scale=self.scale)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseProjection:
    """Naive-method baseline: (K, prod d_n) Gaussian matrix (paper §2)."""

    matrix: jax.Array  # (K, prod(dims))
    dims_: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    scale: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    @property
    def num_hashes(self) -> int:
        return self.matrix.shape[0]

    @property
    def dims(self) -> tuple[int, ...]:
        return self.dims_

    def storage_size(self) -> int:
        """O(K d^N) stored scalars — exponential in N."""
        return int(np.prod(self.matrix.shape))


Projection = CPProjection | TTProjection | DenseProjection


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------


def sample_cp_projection(key, num_hashes: int, dims: Sequence[int], rank: int,
                         dist: str = "rademacher", normalize: bool = False,
                         dtype=jnp.float32) -> CPProjection:
    keys = jax.random.split(key, len(dims))
    factors = tuple(
        _sample(k, (num_hashes, d, rank), dist, dtype) for k, d in zip(keys, dims)
    )
    scale = 1.0 / math.sqrt(rank)
    if normalize:  # the 1/sqrt(K) of Definition 8
        scale /= math.sqrt(num_hashes)
    return CPProjection(factors=factors, scale=scale)


def sample_tt_projection(key, num_hashes: int, dims: Sequence[int], rank: int,
                         dist: str = "rademacher", normalize: bool = False,
                         dtype=jnp.float32) -> TTProjection:
    n = len(dims)
    keys = jax.random.split(key, n)
    cores = []
    for i, (k, d) in enumerate(zip(keys, dims)):
        r_prev = 1 if i == 0 else rank
        r_next = 1 if i == n - 1 else rank
        cores.append(_sample(k, (num_hashes, r_prev, d, r_next), dist, dtype))
    scale = 1.0 / math.sqrt(rank ** (n - 1))
    if normalize:
        scale /= math.sqrt(num_hashes)
    return TTProjection(cores=tuple(cores), scale=scale)


def sample_dense_projection(key, num_hashes: int, dims: Sequence[int],
                            dist: str = "gaussian", normalize: bool = False,
                            dtype=jnp.float32) -> DenseProjection:
    size = int(np.prod(list(dims)))
    m = _sample(key, (num_hashes, size), dist, dtype)
    scale = 1.0 / math.sqrt(num_hashes) if normalize else 1.0
    return DenseProjection(matrix=m, dims_=tuple(dims), scale=scale)


# ---------------------------------------------------------------------------
# Projection application: X (dense | CP | TT)  ->  (K,) values
# All K inner products are evaluated with stacked batched einsums.
# ---------------------------------------------------------------------------


def _project_cp_on_cp(p: CPProjection, x: CPTensor) -> jax.Array:
    """(K,) values of <P_k, X>, X in CP format. O(K N d max{R,R^}^2)."""
    h = None
    for a, f in zip(x.factors, p.factors):
        g = jnp.einsum("ir,kiq->krq", a, f)  # per-mode Gram, batched over K
        h = g if h is None else h * g
    return (x.scale * p.scale) * jnp.sum(h, axis=(1, 2))


def _project_cp_on_tt(p: CPProjection, x: TTTensor) -> jax.Array:
    """(K,) values of <P_k, X>, X in TT format. O(K N d max{R,R^}^3)."""
    rank = p.rank
    k = p.num_hashes
    s = jnp.ones((k, rank, 1), x.cores[0].dtype)
    for g, f in zip(x.cores, p.factors):
        # s: (K, R, a), g: (a, d, b), f: (K, d, R)
        s = jnp.einsum("kra,aib,kir->krb", s, g, f)
    return (x.scale * p.scale) * jnp.sum(s, axis=(1, 2))


def _project_cp_on_dense(p: CPProjection, x: jax.Array) -> jax.Array:
    """(K,) values of <P_k, X>, dense X. O(K R d^N), no d^N reshape."""
    t = jnp.einsum("i...,kir->kr...", x, p.factors[0])
    for f in p.factors[1:]:
        t = jnp.einsum("kri...,kir->kr...", t, f)
    return p.scale * jnp.sum(t, axis=1)


def _project_tt_on_tt(p: TTProjection, x: TTTensor) -> jax.Array:
    """(K,) values of <T_k, X>, X in TT format. O(K N d max{R,R^}^3)."""
    k = p.num_hashes
    s = jnp.ones((k, 1, 1), x.cores[0].dtype)
    for gx, gp in zip(x.cores, p.cores):
        # s: (K, a, b), gx: (a, d, c), gp: (K, b, d, e)
        s = jnp.einsum("kab,aic,kbie->kce", s, gx, gp)
    return (x.scale * p.scale) * s.reshape(k)


def _project_tt_on_cp(p: TTProjection, x: CPTensor) -> jax.Array:
    """(K,) values of <T_k, X>, X in CP format. O(K N d max{R,R^}^3)."""
    k = p.num_hashes
    rank = x.rank
    s = jnp.ones((k, rank, 1), x.factors[0].dtype)
    for a, gp in zip(x.factors, p.cores):
        # s: (K, R^, b), gp: (K, b, d, e), a: (d, R^)
        s = jnp.einsum("krb,kbie,ir->kre", s, gp, a)
    return (x.scale * p.scale) * jnp.sum(s, axis=(1, 2))


def _project_tt_on_dense(p: TTProjection, x: jax.Array) -> jax.Array:
    """(K,) values of <T_k, X>, dense X. O(K R^2 d^N)."""
    t = jnp.einsum("i...,kair->kr...", x, p.cores[0])  # a == 1
    for core in p.cores[1:]:
        t = jnp.einsum("kai...,kair->kr...", t, core)
    return p.scale * t.reshape(p.num_hashes)


def _project_dense_on_any(p: DenseProjection, x) -> jax.Array:
    from repro.core.tensor_formats import cp_to_dense, tt_to_dense

    if isinstance(x, CPTensor):
        x = cp_to_dense(x)  # the naive method reshapes/materializes
    elif isinstance(x, TTTensor):
        x = tt_to_dense(x)
    return p.scale * (p.matrix @ x.reshape(-1))


def project(p: Projection, x) -> jax.Array:
    """Apply a projection family to one tensor -> (K,) projected values."""
    if isinstance(p, CPProjection):
        if isinstance(x, CPTensor):
            return _project_cp_on_cp(p, x)
        if isinstance(x, TTTensor):
            return _project_cp_on_tt(p, x)
        return _project_cp_on_dense(p, x)
    if isinstance(p, TTProjection):
        if isinstance(x, CPTensor):
            return _project_tt_on_cp(p, x)
        if isinstance(x, TTTensor):
            return _project_tt_on_tt(p, x)
        return _project_tt_on_dense(p, x)
    if isinstance(p, DenseProjection):
        return _project_dense_on_any(p, x)
    raise TypeError(f"unknown projection {type(p)}")


def project_batch(p: Projection, xs) -> jax.Array:
    """Apply to a batch of tensors (leading axis on every leaf) -> (B, K)."""
    return jax.vmap(lambda x: project(p, x))(xs)
