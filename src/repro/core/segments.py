"""Immutable sorted segments + LSM-style segment store for the LSH indexes.

This is the storage/query core every index class in ``repro.core.index``
builds on. The unit of storage is an immutable segment — per hash table the
bucket keys of its items sorted ascending, the matching permutation of local
item ids, and the corpus slice the ids point into (exactly the PR 1 device
layout, per segment instead of per index):

  ``TableSegment``   keys (m, L) uint32 in corpus order, sorted_keys (L, m),
                     perm (L, m) int32, corpus pytree with leading dim m.
  ``ShardedSegment`` the same arrays with a leading shard dim S and per-shard
                     local ids (pad slots carry the n_s sentinel), laid out
                     for a mesh axis — the PR 2 sharded base.

Mutability is layered on top, LSM-style, by ``SegmentStore``: one base
segment plus a bounded list of small delta segments (streaming inserts) and
a tombstone mask over every slot (streaming deletes). A query probes every
segment with the same searchsorted/gather path, filters tombstones inside
the probe (dead slots are masked exactly like bucket misses, so they never
reach ranking or the candidate count), re-ranks per segment, and merges the
per-segment top-k with the stable validity-aware two-key sort from PR 2 —
the same merge that makes sharded top-k bit-identical to the single-device
path makes the segmented top-k bit-identical to one flat table.

Ids returned by queries are *effective* ids: the rank of the item in the
live corpus in slot order (base items first, then deltas in insert order,
tombstones skipped). That makes a mutated store's results directly
comparable to a fresh rebuild over the effective corpus, and it is the
numbering ``delete()`` accepts. ``compact()`` gathers the surviving keys
and corpus rows (no re-hash — keys are stored in corpus order precisely so
compaction never touches the hash families) and rebuilds one base segment,
after which effective and physical ids coincide again.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contractions
# The universal bucket hash lives with the families (lsh.hash_keys fuses it
# into the hashing program); re-exported here for the host/table builders.
from repro.core.lsh import _combine_codes, make_mults

_PAD_KEY = np.uint32(0xFFFFFFFF)  # bucket key of shard-padding slots


def tree_index(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def _score_fn(metric: str):
    return (contractions.distance if metric == "euclidean"
            else contractions.cosine_similarity)


def _bad_score(metric: str) -> float:
    return jnp.inf if metric == "euclidean" else -jnp.inf


@jax.jit
def _hash_keys(family, xs, mults):
    """One fused program: batched projection -> discretize -> combine."""
    return family.hash_keys(xs, mults)


def bucket_keys(family, mults, corpus, batch_size: int) -> jax.Array:
    """(n, L) uint32 bucket keys of a corpus pytree, hashed in batches.

    The single source of build-time keys for every segment kind — host dict
    tables are filled from np.asarray of this, keeping host/device keys
    bit-identical. Each batch runs as ONE fused jit program through
    ``family.hash_keys`` (projection, discretize, and the uint32 radix
    combine never round-trip through separate dispatches).
    """
    n = jax.tree.leaves(corpus)[0].shape[0]
    mults = jnp.asarray(mults)
    keys = []
    for start in range(0, n, batch_size):
        chunk = tree_index(corpus, slice(start, min(start + batch_size, n)))
        keys.append(_hash_keys(family, chunk, mults))
    return jnp.concatenate(keys, axis=0)


def query_keys(family, mults, queries) -> jax.Array:
    """Hash a query batch once -> (L, B) uint32 bucket keys (fused)."""
    return family.hash_keys(queries, jnp.asarray(mults)).T


def _max_run_length(sorted_keys: jax.Array) -> jax.Array:
    """Longest run of equal values along the last axis of sorted keys."""
    flat = sorted_keys.reshape(-1, sorted_keys.shape[-1])
    n = flat.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones(flat.shape[:1] + (1,), bool),
         flat[:, 1:] != flat[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(new_run, idx, 0), axis=1)
    return jnp.max(idx - run_start + 1)


# ---------------------------------------------------------------------------
# Immutable segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TableSegment:
    """One immutable sorted run: per-table sorted keys + permutation + the
    corpus slice. ``keys`` keeps the corpus-order copy so compaction can
    rebuild sorted tables without re-hashing."""

    keys: jax.Array         # (m, L) uint32, corpus order
    sorted_keys: jax.Array  # (L, m) uint32, ascending per table
    perm: jax.Array         # (L, m) int32 local ids in sorted-key order
    corpus: Any             # pytree, leaves (m, ...)
    cap: int                # static probe width (largest bucket at build,
                            # or the explicit bucket_cap truncation)

    @property
    def slots(self) -> int:
        return self.keys.shape[0]

    @property
    def items(self) -> int:       # every slot holds a real item
        return self.keys.shape[0]


@dataclasses.dataclass(frozen=True)
class ShardedSegment:
    """The sharded base: ``TableSegment`` arrays with a leading shard dim.

    Local ids are per shard; pad slots (global slot id >= items) carry the
    ``shard_size`` sentinel so a probe landing on one — even via a _PAD_KEY
    collision — is masked as a miss by the liveness lookup.
    """

    keys: jax.Array         # (S, n_s, L) uint32, corpus order, pads _PAD_KEY
    sorted_keys: jax.Array  # (S, L, n_s) uint32
    perm: jax.Array         # (S, L, n_s) int32, pad slots -> n_s sentinel
    corpus: Any             # pytree, leaves (S, n_s, ...), zero-padded
    cap: int                # static probe width (largest per-shard bucket)
    items: int              # real (unpadded) item count n

    @property
    def shards(self) -> int:
        return self.keys.shape[0]

    @property
    def shard_size(self) -> int:
        return self.keys.shape[1]

    @property
    def slots(self) -> int:
        return self.keys.shape[0] * self.keys.shape[1]


@jax.jit
def _sort_tables(keys_t: jax.Array):
    """(..., L, m) keys -> (perm, sorted_keys, max_run) along the last axis."""
    perm = jnp.argsort(keys_t, axis=-1, stable=True).astype(jnp.int32)
    sorted_keys = jnp.take_along_axis(keys_t, perm, axis=-1)
    return perm, sorted_keys, _max_run_length(sorted_keys)


def _warn_coarse(layout: str, cap: int, num_tables: int, n: int,
                 shards: int = 1) -> None:
    """Shared coarse-family warning: the exact default cap would gather more
    candidates than the store — for sharded bases, one shard — holds.
    Emitted from the shared segment-build path so every layout (device,
    sharded, host) warns identically; ``n`` is the per-shard item count
    when ``shards`` > 1."""
    if not n or cap * num_tables <= n:
        return
    fix = ("The family is too coarse for this data; raise num_codes / "
           "shrink bucket_width, or pass an explicit bucket_cap to bound "
           "{} work at some recall cost.")
    if shards > 1:
        warnings.warn(
            f"{layout}: largest per-shard bucket has {cap} of {n} items, so "
            f"the exact default cap gathers up to S*L*cap="
            f"{shards * num_tables * cap} candidates per query (more than a "
            "shard holds). " + fix.format("per-shard"))
    else:
        warnings.warn(
            f"{layout}: largest bucket has {cap} of {n} items, so the exact "
            f"default cap gathers up to L*cap={cap * num_tables} candidates "
            "per query (more than the corpus). " + fix.format("per-query"))


def build_segment(keys: jax.Array, corpus, *, bucket_cap: int | None = None,
                  warn_layout: str | None = None) -> TableSegment:
    """(m, L) corpus-order keys + corpus slice -> sorted TableSegment.

    One jit program sorts every table and measures the largest bucket; the
    coarse-family warning fires only for base builds (``warn_layout`` set) —
    small delta segments trip the threshold by construction.
    """
    m = keys.shape[0]
    perm, sorted_keys, max_run = _sort_tables(keys.T)
    if bucket_cap is None:
        cap = int(max_run) if m else 0
        if warn_layout is not None:
            _warn_coarse(warn_layout, cap, keys.shape[1], m)
    else:
        cap = min(int(bucket_cap), m)
    return TableSegment(keys=keys, sorted_keys=sorted_keys, perm=perm,
                        corpus=corpus, cap=cap)


def build_sharded_segment(keys: jax.Array, corpus, shards: int, *,
                          bucket_cap: int | None = None,
                          warn_layout: str | None = None) -> ShardedSegment:
    """(n, L) corpus-order keys + corpus -> S-sharded segment (unplaced).

    The corpus is split into S contiguous slices; the last is zero-padded
    (pad keys = _PAD_KEY, pad perm entries = the n_s sentinel). Mesh
    placement is the caller's concern (``distributed.index_sharding``).
    """
    n, num_tables = keys.shape
    n_s = max(-(-n // shards), 1)
    pad = shards * n_s - n
    keys_sh = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=_PAD_KEY)
    keys_sh = keys_sh.reshape(shards, n_s, num_tables)
    perm, sorted_keys, max_run = _sort_tables(keys_sh.transpose(0, 2, 1))
    # pad slots get the n_s sentinel: liveness lookup masks them as misses
    offsets = jnp.arange(shards, dtype=jnp.int32)[:, None, None] * n_s
    perm = jnp.where(offsets + perm >= n, n_s, perm)
    corpus_sh = jax.tree.map(
        lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        .reshape((shards, n_s) + a.shape[1:]), corpus)
    if bucket_cap is None:
        cap = int(max_run) if n else 0
        if warn_layout is not None:
            _warn_coarse(warn_layout, cap, num_tables, n_s, shards)
    else:
        cap = min(int(bucket_cap), n_s)
    return ShardedSegment(keys=keys_sh, sorted_keys=sorted_keys, perm=perm,
                          corpus=corpus_sh, cap=cap, items=n)


# ---------------------------------------------------------------------------
# Probe / rank / merge — the shared query math
# ---------------------------------------------------------------------------


def probe_tables(sorted_keys, perm, keys, cap, live):
    """-> (cand (B, L*cap) int32 with -1 for invalid, valid (B, L*cap) bool).

    keys: (L, B) uint32 query bucket keys (already hashed + combined). For
    each query and table: searchsorted into the sorted key array, gather
    the next ``cap`` positions, keep those still inside the bucket (same
    key) whose slot is live, then sort + mask duplicates so each local id
    appears at most once. ``live`` is an (m+1,) lookup — entry m covers the
    sharded pad sentinel, tombstoned slots are False — so dead slots are
    filtered exactly like bucket misses, before ranking or counting.
    """
    m = sorted_keys.shape[1]
    starts = jax.vmap(
        lambda sk, q: jnp.searchsorted(sk, q, side="left"))(sorted_keys, keys)
    pos = starts[:, :, None] + jnp.arange(cap, dtype=starts.dtype)  # (L, B, cap)
    in_range = pos < m
    posc = jnp.minimum(pos, max(m - 1, 0))
    key_at = jax.vmap(lambda sk, p: sk[p])(sorted_keys, posc)
    hit = in_range & (key_at == keys[:, :, None])
    ids = jax.vmap(lambda pm, p: pm[p])(perm, posc)       # (L, B, cap)
    hit &= live[ids]                                      # tombstones + pads
    b = keys.shape[1]
    cand = jnp.where(hit, ids, m).transpose(1, 0, 2).reshape(b, -1)
    cand = jnp.sort(cand, axis=1)                         # invalid (>=m) last
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
    valid = (cand < m) & ~dup
    return jnp.where(valid, cand, -1).astype(jnp.int32), valid


def select_topk(metric, topk, cand, scores, valid):
    """Stable two-key sort -> (ids (B, topk) with -1 fill, scores (B, topk)).

    Primary key: validity (invalid slots strictly last, independent of their
    score values); secondary key: the score in rank order (ascending distance
    / descending similarity, NaN after every finite score — XLA's total
    order, matching np.argsort in the host path). The stable sort breaks
    score ties by candidate position, i.e. ascending id, which is what makes
    sharded, segmented, and single-table selections bit-identical.
    """
    order_key = scores if metric == "euclidean" else -scores
    _, _, s_cand, s_scores, s_valid = jax.lax.sort(
        (~valid, order_key, cand, scores, valid),
        dimension=1, is_stable=True, num_keys=2)
    k = min(topk, cand.shape[1])
    bad = _bad_score(metric)
    ids = jnp.where(s_valid[:, :k], s_cand[:, :k], -1)
    out_scores = jnp.where(s_valid[:, :k], s_scores[:, :k], bad)
    if k < topk:
        ids = jnp.pad(ids, ((0, 0), (0, topk - k)), constant_values=-1)
        out_scores = jnp.pad(out_scores, ((0, 0), (0, topk - k)),
                             constant_values=bad)
    return ids, out_scores


def rank_candidates(metric, topk, queries, corpus, cand, valid):
    """(cand, valid) (B, W) -> (ids (B, topk), scores (B, topk), n_cand (B,)).

    Exact in-format re-rank of every valid candidate followed by the
    validity-aware top-k selection. Rows with no valid candidate come out
    all -1 / bad-fill even when scores are NaN or +/-inf (e.g. a zero-norm
    query under cosine) — selection never trusts score sentinels alone.
    """
    n_cand = valid.sum(axis=1, dtype=jnp.int32)
    safe = jnp.where(valid, cand, 0)
    sub = tree_index(corpus, safe)                        # leaves (B, C, ...)
    score = _score_fn(metric)
    scores = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: score(q, y))(ys))(queries, sub)
    scores = jnp.where(valid, scores, _bad_score(metric))
    ids, out_scores = select_topk(metric, topk, cand, scores, valid)
    return ids, out_scores, n_cand


def segment_candidates(seg_arrays, keys, cap):
    """One segment's probe -> (cand (B, L*cap) effective ids with -1 fill,
    valid (B, L*cap) bool). ``seg_arrays`` is the (corpus, sorted_keys,
    perm, live, eff) tuple; local ids are mapped through ``eff`` into the
    store's effective (live-corpus) numbering."""
    _, sorted_keys, perm, live, eff = seg_arrays
    cand, valid = probe_tables(sorted_keys, perm, keys, cap, live)
    safe = jnp.where(valid, cand, 0)
    return jnp.where(valid, eff[safe], -1), valid


def segment_topk(metric, topk, cap, queries, seg_arrays, keys):
    """One segment's probe + re-rank -> ((B, topk) effective ids, scores,
    n_cand). ``seg_arrays`` is the (corpus, sorted_keys, perm, live, eff)
    tuple; candidates come back already mapped through ``eff`` into the
    store's effective (live-corpus) numbering, -1 fill preserved."""
    corpus, sorted_keys, perm, live, eff = seg_arrays
    cand, valid = probe_tables(sorted_keys, perm, keys, cap, live)
    ids, scores, n_cand = rank_candidates(metric, topk, queries, corpus,
                                          cand, valid)
    return jnp.where(ids >= 0, eff[jnp.where(ids >= 0, ids, 0)], -1), \
        scores, n_cand


def merge_topk(metric, topk, ids, scores, n_cand):
    """(G, B, k) per-group top-k -> global (ids, scores, n_cand).

    Group-major concatenation + the same stable validity-aware selection as
    the single-table path: score ties fall back to concat position, which is
    (group, within-group rank) = ascending effective id whenever the groups
    are ordered by slot offset — so the merged top-k is bit-identical to
    ranking all candidates in one table. Groups are shards, delta segments,
    or both.
    """
    g, b, k = ids.shape
    flat_ids = ids.transpose(1, 0, 2).reshape(b, g * k)
    flat_scores = scores.transpose(1, 0, 2).reshape(b, g * k)
    out_ids, out_scores = select_topk(metric, topk, flat_ids, flat_scores,
                                      flat_ids >= 0)
    return out_ids, out_scores, n_cand.sum(axis=0)


def merge_with_deltas(metric, topk, groups, deltas, delta_caps, queries,
                      keys):
    """Probe the replicated delta segments and merge them, in slot order,
    with the base's per-group top-k ``groups`` ((G, B, k) ids/scores/n_cand
    — G shards, or 1 for a single-device base). The single merge body shared
    by the vmapped and the shard_map sharded query programs, which must stay
    bit-identical."""
    ids, scores, n_cand = groups
    outs = [(ids, scores, n_cand)]
    for seg_arrays, dcap in zip(deltas, delta_caps):
        i, s, n = segment_topk(metric, topk, dcap, queries, seg_arrays, keys)
        outs.append((i[None], s[None], n[None]))
    return merge_topk(metric, topk,
                      jnp.concatenate([o[0] for o in outs]),
                      jnp.concatenate([o[1] for o in outs]),
                      jnp.concatenate([o[2] for o in outs]))


# ---------------------------------------------------------------------------
# The shared query planner (single-device / host / vmapped-sharded programs;
# the shard_map variant lives in repro.distributed.index_sharding)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric", "topk", "caps"))
def segmented_query(family, segs, mults, queries, *, metric, topk, caps):
    """One program from query batch to top-k over every segment: hash once,
    probe + re-rank each segment, merge. ``segs`` is a tuple of per-segment
    array tuples ordered by slot offset (base first, deltas in insert
    order); ``caps`` the matching static probe widths."""
    keys = query_keys(family, mults, queries)
    outs = [segment_topk(metric, topk, cap, queries, sa, keys)
            for sa, cap in zip(segs, caps)]
    return merge_topk(metric, topk,
                      jnp.stack([o[0] for o in outs]),
                      jnp.stack([o[1] for o in outs]),
                      jnp.stack([o[2] for o in outs]))


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps"))
def sharded_query_vmap(family, base, deltas, mults, queries, *, metric, topk,
                       cap, delta_caps):
    """Single-program sharded query without a mesh: vmap over the S axis of
    the base segment, plus the delta segments, merged in slot order.

    Used when fewer devices than shards exist (e.g. the 1-device tier-1
    run); identical math to the shard_map program in
    repro.distributed.index_sharding.
    """
    keys = query_keys(family, mults, queries)
    per_shard = jax.vmap(
        lambda cs, sk, pm, lv, ef: segment_topk(
            metric, topk, cap, queries, (cs, sk, pm, lv, ef), keys)
    )(*base)                                              # (S, B, k) each
    return merge_with_deltas(metric, topk, per_shard, deltas, delta_caps,
                             queries, keys)


@functools.partial(jax.jit, static_argnames=("caps",))
def segmented_candidates(family, segs, mults, queries, *, caps):
    """-> (cand (B, sum L*cap_g) effective ids with -1 fill, valid mask)."""
    keys = query_keys(family, mults, queries)
    cands, valids = [], []
    for seg_arrays, cap in zip(segs, caps):
        cand, valid = segment_candidates(seg_arrays, keys, cap)
        cands.append(cand)
        valids.append(valid)
    return jnp.concatenate(cands, axis=1), jnp.concatenate(valids, axis=1)


@functools.partial(jax.jit, static_argnames=("cap", "delta_caps"))
def sharded_candidates(family, base, deltas, mults, queries, *, cap,
                       delta_caps):
    """Sharded-base variant of ``segmented_candidates`` (vmap over shards)."""
    keys = query_keys(family, mults, queries)
    _, sorted_keys, perm, live, eff = base
    cand, valid = jax.vmap(
        lambda sk, pm, lv, ef: segment_candidates((None, sk, pm, lv, ef),
                                                  keys, cap)
    )(sorted_keys, perm, live, eff)                       # (S, B, W)
    s, b, w = cand.shape
    cands = [cand.transpose(1, 0, 2).reshape(b, s * w)]
    valids = [valid.transpose(1, 0, 2).reshape(b, s * w)]
    for seg_arrays, dcap in zip(deltas, delta_caps):
        dc, dv = segment_candidates(seg_arrays, keys, dcap)
        cands.append(dc)
        valids.append(dv)
    return jnp.concatenate(cands, axis=1), jnp.concatenate(valids, axis=1)


# ---------------------------------------------------------------------------
# Mutable store: base + deltas + tombstones
# ---------------------------------------------------------------------------


class SegmentStore:
    """LSM-style mutable view over immutable segments.

    Holds one base segment (``TableSegment`` or ``ShardedSegment``), a
    bounded list of delta ``TableSegment``s, and a host-side tombstone mask
    over every slot (shard-pad slots are born dead). After each mutation it
    re-derives the per-segment device arrays the planner consumes:

      live  (m+1,) bool   per segment (sharded base: (S, n_s+1)) — slot
                          liveness with the pad-sentinel entry always False
      eff   (m,) int32    per segment (sharded base: (S, n_s)) — the slot's
                          effective id: its rank among live slots in slot
                          order, i.e. its index in ``effective_corpus()``

    Deletes only flip mask bits (same array shapes -> no recompilation);
    inserts append a segment (bounded recompiles, the index compacts past
    ``max_deltas``). ``place_base`` lets the sharded index keep the derived
    base arrays on its mesh.
    """

    def __init__(self, base, *, place_base: Callable | None = None):
        self.base = base
        self.deltas: list[TableSegment] = []
        self.place_base = place_base or (lambda t: t)
        self.live_host = np.zeros(base.slots, bool)
        self.live_host[:base.items] = True     # shard pads (>= items) dead
        self._refresh()

    # -- derived state ------------------------------------------------------

    def _refresh(self) -> None:
        eff_all = (np.cumsum(self.live_host) - 1).astype(np.int32)
        self.n_live = int(self.live_host.sum())
        self.n_dead = (self.live_host.size - self.base.slots
                       + self.base.items - self.n_live)
        pos, luts = 0, []
        for seg in [self.base] + self.deltas:
            live = self.live_host[pos:pos + seg.slots]
            eff = eff_all[pos:pos + seg.slots]
            if isinstance(seg, ShardedSegment):
                s, n_s = seg.shards, seg.shard_size
                lut = (jnp.asarray(np.pad(live.reshape(s, n_s),
                                          ((0, 0), (0, 1)))),
                       jnp.asarray(eff.reshape(s, n_s)))
                lut = self.place_base(lut)
            else:
                lut = (jnp.asarray(np.append(live, False)), jnp.asarray(eff))
            luts.append(lut)
            pos += seg.slots
        self._luts = luts

    def seg_arrays(self, i: int):
        """(corpus, sorted_keys, perm, live, eff) of segment i (0 = base)."""
        seg = ([self.base] + self.deltas)[i]
        live, eff = self._luts[i]
        return (seg.corpus, seg.sorted_keys, seg.perm, live, eff)

    @property
    def delta_arrays(self) -> tuple:
        return tuple(self.seg_arrays(1 + i) for i in range(len(self.deltas)))

    @property
    def delta_caps(self) -> tuple[int, ...]:
        return tuple(d.cap for d in self.deltas)

    @property
    def all_arrays(self) -> tuple:
        return tuple(self.seg_arrays(i)
                     for i in range(1 + len(self.deltas)))

    @property
    def all_caps(self) -> tuple[int, ...]:
        return (self.base.cap,) + self.delta_caps

    @property
    def mutated(self) -> bool:
        return bool(self.deltas) or self.n_dead > 0

    # -- mutations ----------------------------------------------------------

    def append_delta(self, seg: TableSegment) -> None:
        """O(batch) append: earlier segments' liveness and effective ids are
        untouched (new items rank after every live item), so only the new
        segment's lookups are built — no base-array re-upload per insert."""
        start = self.n_live
        self.deltas.append(seg)
        self.live_host = np.concatenate(
            [self.live_host, np.ones(seg.slots, bool)])
        self._luts.append((
            jnp.asarray(np.append(np.ones(seg.slots, bool), False)),
            jnp.arange(start, start + seg.slots, dtype=jnp.int32)))
        self.n_live += seg.slots

    def delete_effective(self, ids: np.ndarray) -> int:
        """Tombstone items by their current *effective* ids (the numbering
        queries return). Returns the number of newly-dead items."""
        ids = np.unique(np.asarray(ids, np.int64))
        if ids.size == 0:
            return 0
        if ids.size and (ids[0] < 0 or ids[-1] >= self.n_live):
            raise IndexError(
                f"delete ids must be in [0, {self.n_live}), got "
                f"[{ids[0]}, {ids[-1]}]")
        slots = np.flatnonzero(self.live_host)[ids]
        self.live_host[slots] = False
        self._refresh()
        return int(ids.size)

    # -- effective (live) views --------------------------------------------

    def _flat_keys_and_corpus(self):
        segs = [self.base] + self.deltas
        flat_keys, flat_corpus = [], []
        for seg in segs:
            if isinstance(seg, ShardedSegment):
                flat_keys.append(seg.keys.reshape(-1, seg.keys.shape[-1]))
                flat_corpus.append(jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), seg.corpus))
            else:
                flat_keys.append(seg.keys)
                flat_corpus.append(seg.corpus)
        keys = jnp.concatenate(flat_keys, axis=0)
        corpus = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *flat_corpus)
        return keys, corpus

    def effective_arrays(self):
        """-> ((n_live, L) keys, corpus pytree) of live items in slot order —
        the compaction input; keys come from storage, never from re-hashing."""
        keys, corpus = self._flat_keys_and_corpus()
        idx = jnp.asarray(np.flatnonzero(self.live_host))
        return keys[idx], tree_index(corpus, idx)

    def effective_corpus(self):
        """The live corpus in effective-id order (zero-copy when pristine)."""
        if not self.mutated:
            if isinstance(self.base, ShardedSegment):
                flat = jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), self.base.corpus)
                return tree_index(flat, slice(0, self.base.items))
            return self.base.corpus
        return self.effective_arrays()[1]
