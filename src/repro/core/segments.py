"""Immutable sorted segments + LSM-style segment store for the LSH indexes.

This is the storage/query core every index class in ``repro.core.index``
builds on. The unit of storage is an immutable segment — per hash table the
bucket keys of its items sorted ascending, the matching permutation of local
item ids, and the corpus slice the ids point into (exactly the PR 1 device
layout, per segment instead of per index):

  ``TableSegment``   keys (m, L) uint32 in corpus order, sorted_keys (L, m),
                     perm (L, m) int32, corpus pytree with leading dim m.
  ``ShardedSegment`` the same arrays with a leading shard dim S and per-shard
                     local ids (pad slots carry the n_s sentinel), laid out
                     for a mesh axis — the PR 2 sharded base.

Mutability is layered on top, LSM-style, by ``SegmentStore``: one base
segment plus a bounded list of small delta segments (streaming inserts) and
a tombstone mask over every slot (streaming deletes). Delta segments are
``TableSegment``s on the single-device store and ``ShardedSegment`` slabs
on the sharded store — ``route_balanced`` assigns each insert batch to
shards least-loaded-first in contiguous slabs, so the mutation plane is
shard-native end-to-end and nothing is replicated. A query probes every
segment with the same searchsorted/gather path, filters tombstones inside
the probe (dead slots are masked exactly like bucket misses, so they never
reach ranking or the candidate count), re-ranks per segment, and merges the
per-segment top-k with the stable validity-aware sort from PR 2 (extended
with the effective id as a third sort key, which makes the merge
independent of how items are partitioned into segments and shards).

Ids returned by queries are *effective* ids: the rank of the item in the
live corpus in *sequence order* (the order items entered the store — base
items first, then deltas in insert order, tombstones skipped). Because
routed delta slabs interleave shards, each segment carries a host-side
``slot_pos`` map from slot to sequence position; effective ids derive from
it, so a mutated store's results stay directly comparable to a fresh
rebuild over the effective corpus, and it is the numbering ``delete()``
accepts. ``compact()`` folds the surviving keys and corpus rows (no
re-hash — keys are stored in corpus order precisely so compaction never
touches the hash families) into a new base; the sharded fold is
shard-local (``_slab_gather_sort``), so shards keep whatever mix of items
they held and only an explicit ``rebalance()`` moves items across shards.

Indexes built with an explicit ``bucket_cap`` keep per-segment live-window
lookups (``live_rank``/``live_pos``): a truncated probe window skips
tombstoned slots and gathers the first ``cap`` *live* members of each
bucket, so heavy deletes no longer silently shrink capped candidate sets
until compaction.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contractions, probing
# The universal bucket hash lives with the families (lsh.hash_keys fuses it
# into the hashing program); re-exported here for the host/table builders.
from repro.core.lsh import _combine_codes, make_mults
# The probe epilogue (bucket windows, dedup, packed top-k selection) is
# shared with the fused Pallas query kernel — one implementation, so the
# xla and pallas probe backends are bit-identical by construction.
from repro.kernels import epilogues as _epi

_PAD_KEY = np.uint32(0xFFFFFFFF)  # bucket key of shard-padding slots
_NO_ID = np.int32(0x7FFFFFFF)     # effective-id sentinel of probe misses
                                  # (sorts after every real effective id)

PROBE_BACKENDS = ("auto", "xla", "pallas")


def resolved_probe_backend(probe_backend: str = "auto") -> str:
    """'xla' or 'pallas': the explicit knob, else the REPRO_PROBE_BACKEND
    env var (read at trace time), else pallas on TPU / xla elsewhere —
    mirroring ``LSHFamily.resolved_backend`` for the hashing stage.

    'xla' is the restructured segment-major schedule (one fused scan over
    segments, hoisted-norm re-rank, packed top-k selection); 'pallas' the
    fused query kernel in ``repro.kernels.fused_query`` (interpret mode on
    CPU). Both are bit-identical to the reference planner
    (``segmented_query_reference``), pinned by tests/test_fused_probe.py.
    """
    b = (probe_backend or "auto").strip().lower()
    if b == "auto":
        b = os.environ.get("REPRO_PROBE_BACKEND", "").strip().lower() or "auto"
    if b == "auto":
        from repro.kernels.ops import on_tpu
        b = "pallas" if on_tpu() else "xla"
    if b not in ("xla", "pallas"):
        raise ValueError(
            f"probe_backend must be one of {PROBE_BACKENDS}, got "
            f"{probe_backend!r}")
    return b


def tree_index(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def _score_fn(metric: str):
    return (contractions.distance if metric == "euclidean"
            else contractions.cosine_similarity)


def _bad_score(metric: str) -> float:
    return jnp.inf if metric == "euclidean" else -jnp.inf


@jax.jit
def _hash_keys(family, xs, mults):
    """One fused program: batched projection -> discretize -> combine."""
    return family.hash_keys(xs, mults)


def bucket_keys(family, mults, corpus, batch_size: int) -> jax.Array:
    """(n, L) uint32 bucket keys of a corpus pytree, hashed in batches.

    The single source of build-time keys for every segment kind — host dict
    tables are filled from np.asarray of this, keeping host/device keys
    bit-identical. Each batch runs as ONE fused jit program through
    ``family.hash_keys`` (projection, discretize, and the uint32 radix
    combine never round-trip through separate dispatches).
    """
    n = jax.tree.leaves(corpus)[0].shape[0]
    mults = jnp.asarray(mults)
    keys = []
    for start in range(0, n, batch_size):
        chunk = tree_index(corpus, slice(start, min(start + batch_size, n)))
        keys.append(_hash_keys(family, chunk, mults))
    return jnp.concatenate(keys, axis=0)


def query_keys(family, mults, queries, probes: int = 1) -> jax.Array:
    """Hash a query batch once -> (L, B) uint32 bucket keys (fused).

    With ``probes`` = T > 1 the multi-probe expansion of
    ``repro.core.probing`` widens each (query, table) cell to its T ranked
    candidate bucket keys -> (L, T, B); slot 0 along T is the base key,
    bit-identical to the single-probe tensor.
    """
    if probes == 1:
        return family.hash_keys(queries, jnp.asarray(mults)).T
    keys = probing.probe_keys(family, mults, queries, probes=probes)
    return jnp.moveaxis(keys, 0, -1)                      # (B,L,T) -> (L,T,B)


def _max_run_length(sorted_keys: jax.Array) -> jax.Array:
    """Longest run of equal values along the last axis of sorted keys."""
    return _max_run_length_masked(sorted_keys,
                                  jnp.ones(sorted_keys.shape, bool))


def _max_run_length_masked(sorted_keys: jax.Array,
                           valid: jax.Array) -> jax.Array:
    """Longest run of equal values along the last axis, counting only
    ``valid`` positions (runs break at invalid slots). Pad slots sort to
    the tail of their key run (stable sort, pads carry the largest local
    ids), so masking them yields the true largest *stored* bucket."""
    flat = sorted_keys.reshape(-1, sorted_keys.shape[-1])
    v = valid.reshape(flat.shape)
    n = flat.shape[1]
    if n == 0:
        return jnp.int32(0)
    idx = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones(flat.shape[:1] + (1,), bool),
         (flat[:, 1:] != flat[:, :-1]) | ~v[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(new_run, idx, 0), axis=1)
    return jnp.max(jnp.where(v, idx - run_start + 1, 0))


# ---------------------------------------------------------------------------
# Immutable segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TableSegment:
    """One immutable sorted run: per-table sorted keys + permutation + the
    corpus slice. ``keys`` keeps the corpus-order copy so compaction can
    rebuild sorted tables without re-hashing."""

    keys: jax.Array         # (m, L) uint32, corpus order
    sorted_keys: jax.Array  # (L, m) uint32, ascending per table
    perm: jax.Array         # (L, m) int32 local ids in sorted-key order
    corpus: Any             # pytree, leaves (m, ...)
    cap: int                # static probe width (largest bucket at build,
                            # or the explicit bucket_cap truncation)

    @property
    def slots(self) -> int:
        return self.keys.shape[0]

    @property
    def items(self) -> int:       # every slot holds a real item
        return self.keys.shape[0]


@dataclasses.dataclass(frozen=True)
class ShardedSegment:
    """Sharded arrays with a leading shard dim: the sharded *base* and the
    routed delta *slabs* share this layout.

    Each shard holds ``counts[s]`` real items in slots ``[0, counts[s])`` of
    its slab; the remaining slots are padding (pad keys = _PAD_KEY, pad perm
    entries = the ``shard_size`` sentinel, so a probe landing on one — even
    via a _PAD_KEY collision — is masked as a miss by the liveness lookup).
    A fresh contiguous build fills every shard but the last; slab deltas and
    shard-locally compacted bases carry arbitrary per-shard counts.
    """

    keys: jax.Array         # (S, n_s, L) uint32, corpus order, pads _PAD_KEY
    sorted_keys: jax.Array  # (S, L, n_s) uint32
    perm: jax.Array         # (S, L, n_s) int32, pad slots -> n_s sentinel
    corpus: Any             # pytree, leaves (S, n_s, ...), zero-padded
    cap: int                # static probe width (largest per-shard bucket)
    counts: tuple[int, ...]  # real item count per shard

    @property
    def items(self) -> int:   # real (unpadded) item count n
        return sum(self.counts)

    @property
    def shards(self) -> int:
        return self.keys.shape[0]

    @property
    def shard_size(self) -> int:
        return self.keys.shape[1]

    @property
    def slots(self) -> int:
        return self.keys.shape[0] * self.keys.shape[1]


@jax.jit
def _sort_tables(keys_t: jax.Array):
    """(..., L, m) keys -> (perm, sorted_keys, max_run) along the last axis."""
    perm = jnp.argsort(keys_t, axis=-1, stable=True).astype(jnp.int32)
    sorted_keys = jnp.take_along_axis(keys_t, perm, axis=-1)
    return perm, sorted_keys, _max_run_length(sorted_keys)


def _warn_coarse(layout: str, cap: int, num_tables: int, n: int,
                 shards: int = 1) -> None:
    """Shared coarse-family warning: the exact default cap would gather more
    candidates than the store — for sharded bases, one shard — holds.
    Emitted from the shared segment-build path so every layout (device,
    sharded, host) warns identically; ``n`` is the per-shard item count
    when ``shards`` > 1."""
    if not n or cap * num_tables <= n:
        return
    fix = ("The family is too coarse for this data; raise num_codes / "
           "shrink bucket_width, or pass an explicit bucket_cap to bound "
           "{} work at some recall cost.")
    if shards > 1:
        warnings.warn(
            f"{layout}: largest per-shard bucket has {cap} of {n} items, so "
            f"the exact default cap gathers up to S*L*cap="
            f"{shards * num_tables * cap} candidates per query (more than a "
            "shard holds). " + fix.format("per-shard"))
    else:
        warnings.warn(
            f"{layout}: largest bucket has {cap} of {n} items, so the exact "
            f"default cap gathers up to L*cap={cap * num_tables} candidates "
            "per query (more than the corpus). " + fix.format("per-query"))


def build_segment(keys: jax.Array, corpus, *, bucket_cap: int | None = None,
                  warn_layout: str | None = None,
                  sort_throttled: bool = False) -> TableSegment:
    """(m, L) corpus-order keys + corpus slice -> sorted TableSegment.

    One jit program sorts every table and measures the largest bucket; the
    coarse-family warning fires only for base builds (``warn_layout`` set) —
    small delta segments trip the threshold by construction.
    ``sort_throttled`` sorts table-by-table instead (identical values) so
    a shadow build's sort stays off a concurrent query's critical path.
    """
    m = keys.shape[0]
    sorter = _sort_tables_throttled if sort_throttled else _sort_tables
    perm, sorted_keys, max_run = sorter(keys.T)
    if bucket_cap is None:
        cap = int(max_run) if m else 0
        if warn_layout is not None:
            _warn_coarse(warn_layout, cap, keys.shape[1], m)
    else:
        cap = min(int(bucket_cap), m)
    return TableSegment(keys=keys, sorted_keys=sorted_keys, perm=perm,
                        corpus=corpus, cap=cap)


def build_sharded_segment(keys: jax.Array, corpus, shards: int, *,
                          bucket_cap: int | None = None,
                          warn_layout: str | None = None) -> ShardedSegment:
    """(n, L) corpus-order keys + corpus -> S-sharded segment (unplaced).

    The corpus is split into S contiguous slices; the last is zero-padded
    (pad keys = _PAD_KEY, pad perm entries = the n_s sentinel). Mesh
    placement is the caller's concern (``distributed.index_sharding``).
    """
    n, num_tables = keys.shape
    n_s = max(-(-n // shards), 1)
    pad = shards * n_s - n
    keys_sh = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=_PAD_KEY)
    keys_sh = keys_sh.reshape(shards, n_s, num_tables)
    perm, sorted_keys, max_run = _sort_tables(keys_sh.transpose(0, 2, 1))
    # pad slots get the n_s sentinel: liveness lookup masks them as misses
    offsets = jnp.arange(shards, dtype=jnp.int32)[:, None, None] * n_s
    perm = jnp.where(offsets + perm >= n, n_s, perm)
    corpus_sh = jax.tree.map(
        lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        .reshape((shards, n_s) + a.shape[1:]), corpus)
    if bucket_cap is None:
        cap = int(max_run) if n else 0
        if warn_layout is not None:
            _warn_coarse(warn_layout, cap, num_tables, n_s, shards)
    else:
        cap = min(int(bucket_cap), n_s)
    counts = tuple(int(np.clip(n - s * n_s, 0, n_s)) for s in range(shards))
    return ShardedSegment(keys=keys_sh, sorted_keys=sorted_keys, perm=perm,
                          corpus=corpus_sh, cap=cap, counts=counts)


# ---------------------------------------------------------------------------
# Routed delta slabs + shard-local fold (the shard-native mutation plane)
# ---------------------------------------------------------------------------


def route_balanced(batch_n: int, loads) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic balance policy: fill the least-loaded shard first.

    -> (alloc (S,), offsets (S,)) int64, shard-id order: shard ``s`` takes
    the contiguous batch slab ``[offsets[s], offsets[s] + alloc[s])``.
    Water-fill over ascending (load, shard id): the lowest shards are
    raised toward a common level, leftovers go one item each to the
    least-loaded shards — so steady-state ingest keeps shard occupancy
    within one item of even without ever moving stored rows.
    """
    loads = np.asarray(loads, np.int64)
    s = loads.size
    order = np.lexsort((np.arange(s), loads))
    lv = loads[order]
    alloc_sorted = np.zeros(s, np.int64)
    b = int(batch_n)
    if b > 0:
        for k in range(1, s + 1):
            room = int((lv[k] - lv[:k]).sum()) if k < s else b
            if room >= b:
                level, extra = divmod(int(lv[:k].sum()) + b, k)
                tgt = np.full(k, level, np.int64)
                tgt[:extra] += 1
                alloc_sorted[:k] = tgt - lv[:k]
                break
    alloc = np.zeros(s, np.int64)
    alloc[order] = alloc_sorted
    offsets = np.zeros(s, np.int64)
    offsets[order] = np.concatenate(([0], np.cumsum(alloc_sorted)[:-1]))
    return alloc, offsets


@functools.partial(jax.jit, static_argnames=("shards", "shard_size"))
def _slab_scatter_sort(keys, corpus, idx, counts, *, shards, shard_size):
    """Scatter a routed batch into per-shard slabs and sort each locally.

    ``keys`` (B, L) corpus-order bucket keys; ``idx`` (S * shard_size,)
    int32 rows into the batch (row B = pad); ``counts`` (S,) int32 real
    rows per shard. One program: pad-row gather -> per-shard stable sort
    -> pad sentinel -> masked max bucket run. The device half of
    ``build_sharded_delta`` — also the ``insert_program`` the dry run
    AOT-profiles.
    """
    b, num_tables = keys.shape
    keys_pad = jnp.concatenate(
        [keys, jnp.full((1, num_tables), _PAD_KEY, jnp.uint32)])
    keys_sh = keys_pad[idx].reshape(shards, shard_size, num_tables)
    corpus_sh = jax.tree.map(
        lambda a: jnp.concatenate([a, jnp.zeros_like(a[:1])])[idx]
        .reshape((shards, shard_size) + a.shape[1:]), corpus)
    perm, sorted_keys, _ = _sort_tables(keys_sh.transpose(0, 2, 1))
    pad = perm >= counts[:, None, None]
    perm = jnp.where(pad, shard_size, perm)
    # per-shard max runs (host takes the max): keeps the program free of
    # even the scalar cross-shard reduce a global max would schedule
    max_runs = jax.vmap(_max_run_length_masked)(sorted_keys, ~pad)
    return keys_sh, sorted_keys, perm, corpus_sh, max_runs


def build_sharded_delta(keys, corpus, alloc, offsets, *, seq0: int,
                        bucket_cap: int | None = None
                        ) -> tuple[ShardedSegment, np.ndarray]:
    """(B, L) batch keys + batch corpus + a ``route_balanced`` plan ->
    (slab ShardedSegment, positions).

    ``positions`` is the (S * slab,) int64 slot -> sequence-position map
    (``seq0 + batch row``, -1 for pad slots) ``SegmentStore.append_delta``
    consumes; offsets are closed-form, so the bookkeeping never inspects
    the routed arrays. The slab width is the largest per-shard allocation
    rounded up to a coarse grid (8, then 64 past 256 slots): routing
    drifts the raw width by a few items between batches, and ``shard_size``
    is a static program shape — quantizing it keeps steady-state ingest on
    one compiled scatter+sort program instead of recompiling every batch.
    """
    b, _ = keys.shape
    s = alloc.size
    raw = max(int(alloc.max()), 1)
    q = 64 if raw >= 256 else 8
    slab = -(-raw // q) * q
    idx = np.full((s, slab), b, np.int64)
    pos = np.full((s, slab), -1, np.int64)
    for sh in range(s):
        c, o = int(alloc[sh]), int(offsets[sh])
        idx[sh, :c] = o + np.arange(c)
        pos[sh, :c] = seq0 + o + np.arange(c)
    keys_sh, sorted_keys, perm, corpus_sh, max_runs = _slab_scatter_sort(
        keys, corpus, jnp.asarray(idx.reshape(-1), jnp.int32),
        jnp.asarray(alloc, jnp.int32), shards=s, shard_size=slab)
    cap = min(int(bucket_cap), slab) if bucket_cap is not None \
        else max(int(np.asarray(max_runs).max()), 1)
    seg = ShardedSegment(keys=keys_sh, sorted_keys=sorted_keys, perm=perm,
                         corpus=corpus_sh, cap=cap,
                         counts=tuple(int(a) for a in alloc))
    return seg, pos.reshape(-1)


@functools.partial(jax.jit, static_argnames=("shard_size",))
def _slab_gather_sort(keys_cat, corpus_cat, idx, counts, *, shard_size):
    """Shard-local compaction fold: each shard gathers its own survivors
    from the concatenated base + delta slabs and re-sorts locally.

    ``keys_cat`` (S, W, L) / ``corpus_cat`` leaves (S, W, ...) are the
    per-shard slot axes of every segment concatenated (W = sum of slab
    widths); ``idx`` (S, shard_size) indexes into W (W = pad), ``counts``
    (S,) real survivors per shard. Every op is elementwise or a gather
    along the non-sharded slot axis, so under the mesh the program stays
    shard-local — no collective, no global gather. Also the
    ``compact_program`` the dry run AOT-profiles.
    """
    s, w, num_tables = keys_cat.shape
    keys_pad = jnp.concatenate(
        [keys_cat, jnp.full((s, 1, num_tables), _PAD_KEY, jnp.uint32)],
        axis=1)
    keys_n = jnp.take_along_axis(keys_pad, idx[:, :, None], axis=1)
    corpus_n = jax.tree.map(
        lambda a: jnp.take_along_axis(
            jnp.concatenate([a, jnp.zeros_like(a[:, :1])], axis=1),
            idx.reshape((s, shard_size) + (1,) * (a.ndim - 2)), axis=1),
        corpus_cat)
    perm, sorted_keys, _ = _sort_tables(keys_n.transpose(0, 2, 1))
    pad = perm >= counts[:, None, None]
    perm = jnp.where(pad, shard_size, perm)
    max_runs = jax.vmap(_max_run_length_masked)(sorted_keys, ~pad)
    return keys_n, sorted_keys, perm, corpus_n, max_runs


@jax.jit
def _slab_gather_keys(keys_cat, idx):
    """The keys half of ``_slab_gather_sort``'s gather (pad rows get
    ``_PAD_KEY``), kept as its own bounded program for the chunked shadow
    build: bucket keys are a few bytes per item, so this program stays
    small regardless of corpus width. -> (S, shard_size, L) keys."""
    s, w, num_tables = keys_cat.shape
    keys_pad = jnp.concatenate(
        [keys_cat, jnp.full((s, 1, num_tables), _PAD_KEY, jnp.uint32)],
        axis=1)
    return jnp.take_along_axis(keys_pad, idx[:, :, None], axis=1)


@functools.partial(jax.jit, static_argnames=("shard_size",))
def _sort_shard_table(keys_l, counts, *, shard_size):
    """Sort ONE table's (S, shard_size) fold keys — the same stable sort,
    pad sentinel, and masked max-run math ``_slab_gather_sort`` applies to
    all tables at once, so per-table outputs are bit-identical slices of
    the monolithic fold's. The chunked shadow build issues L of these
    (blocking between them) instead of one L-times-larger sort program."""
    perm = jnp.argsort(keys_l, axis=-1, stable=True).astype(jnp.int32)
    sorted_keys = jnp.take_along_axis(keys_l, perm, axis=-1)
    pad = perm >= counts[:, None]
    perm = jnp.where(pad, shard_size, perm)
    max_run = _max_run_length_masked(sorted_keys, ~pad)
    return perm, sorted_keys, max_run


_BUILD_YIELD_S = 0.0
_BUILD_BUSY_FN: Callable[[], bool] | None = None


@contextlib.contextmanager
def cooperative_build(yield_s: float = 0.008, busy=None):
    """Make the throttled build loops sleep ``yield_s`` after each bounded
    program while the block is active (and, with ``busy``, only while
    foreground work actually exists).

    Blocking per program keeps the *device* queue one program deep, but on
    a machine with few cores the build thread usually keeps the CPU after
    ``block_until_ready`` returns and enqueues its next program before a
    waiting query-lane thread ever runs — so a query still convoys behind
    several build programs in a row, and even once it runs, its program
    timeshares the core with the build's back-to-back programs at ~half
    speed. The sleep hands the core (and the GIL) over between programs,
    leaving a concurrent query the majority of the core for the duration
    of the build (measured on one core: compacting-phase p99 within
    ~1.4x of quiet vs ~2x with back-to-back programs). Build wall time is
    off the query path by design, so trading it for query latency is the
    right direction — but only when there is a query to trade for:
    ``busy`` (a nullary predicate, e.g. "any query in flight") gates each
    sleep so an unloaded build still runs at full speed instead of
    stretching its own wall — and with it the interference window the
    next query can land in — by a blanket slowdown.

    The flags are process-global on purpose: they are set by background
    mutation executors (the scheduler's ingest lane) around whole
    operations, and the loops they gate run several layers down the store
    build with no parameter path through ``SegmentStore.__init__``."""
    global _BUILD_YIELD_S, _BUILD_BUSY_FN
    prev = (_BUILD_YIELD_S, _BUILD_BUSY_FN)
    _BUILD_YIELD_S, _BUILD_BUSY_FN = yield_s, busy
    try:
        yield
    finally:
        _BUILD_YIELD_S, _BUILD_BUSY_FN = prev


def _yield_slot() -> None:
    """One cooperative-yield point between bounded build programs (no-op
    unless inside :func:`cooperative_build`, or when its ``busy``
    predicate says no foreground work is waiting)."""
    if _BUILD_YIELD_S > 0.0 and (_BUILD_BUSY_FN is None or _BUILD_BUSY_FN()):
        time.sleep(_BUILD_YIELD_S)


def _sort_tables_throttled(keys_t: jax.Array):
    """``_sort_tables`` issued as one bounded program per table, blocking
    between programs — identical values (tables sort independently). The
    chunked shadow build uses it so the fold's sort never queues one
    all-tables program ahead of a concurrently dispatched query."""
    outs = []
    for table in range(keys_t.shape[-2]):
        out = _sort_tables(keys_t[..., table:table + 1, :])
        jax.block_until_ready(out)
        _yield_slot()
        outs.append(out)
    perm = jnp.concatenate([o[0] for o in outs], axis=-2)
    sorted_keys = jnp.concatenate([o[1] for o in outs], axis=-2)
    return perm, sorted_keys, jnp.max(jnp.stack([o[2] for o in outs]))


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_chunk(buf, src, src_idx, dst_idx):
    """One bounded program of the chunked shadow-build copy: gather
    ``src_idx`` rows from one source segment and scatter them into the
    donated destination buffers in place (``dst_idx`` past the end marks
    chunk padding and is dropped). Donation makes the update O(chunk), not
    O(buffer): the runtime aliases the output onto the input allocation."""
    return jax.tree.map(
        lambda b, s: b.at[dst_idx].set(s[src_idx], mode="drop"), buf, src)


def gather_rows_chunked(template, srcs, src_idxs, dst_idxs, out_rows, *,
                        chunk: int = 4096):
    """Assemble ``out_rows`` live corpus rows into fresh zero-initialized
    buffers via bounded per-chunk gather+scatter programs.

    The monolithic folds (``_slab_gather_sort``, ``effective_arrays``)
    move the whole store through one XLA program; a device executes
    programs in order, so on a single-stream backend every concurrently
    dispatched query waits the full store copy out — the exact serving
    stall ``prepare_compact`` exists to avoid. This path issues the same
    copy as ceil(rows/chunk) programs per source segment instead, each
    touching at most ``chunk`` rows, and blocks on every chunk before
    enqueuing the next — dispatch is async, so without the throttle the
    fold floods the device queue in one burst and a concurrent query
    waits behind all of it anyway. With it the queue stays one chunk deep
    and query programs interleave between chunks. Values are identical to
    the monolithic gather: every live row is written exactly once and
    unwritten rows stay zero, matching the pad-row zeros of the
    one-program path.

    ``srcs`` are per-segment corpus pytrees with a flat leading axis;
    ``src_idxs``/``dst_idxs`` the matching host-side row maps into them
    and into the flat output. ``template`` supplies output leaf shapes.
    """
    buf = jax.tree.map(
        lambda a: jnp.zeros((out_rows,) + a.shape[1:], a.dtype), template)
    for src, s_idx, d_idx in zip(srcs, src_idxs, dst_idxs):
        for c0 in range(0, len(s_idx), chunk):
            s_c = np.asarray(s_idx[c0:c0 + chunk], np.int32)
            d_c = np.asarray(d_idx[c0:c0 + chunk], np.int32)
            if s_c.size < chunk:    # pad to the compiled chunk shape;
                fill = chunk - s_c.size  # dst sentinel rows are dropped
                s_c = np.pad(s_c, (0, fill))
                d_c = np.pad(d_c, (0, fill), constant_values=out_rows)
            buf = _scatter_rows_chunk(buf, src, jnp.asarray(s_c),
                                      jnp.asarray(d_c))
            jax.block_until_ready(jax.tree.leaves(buf))
            _yield_slot()
    return buf


# ---------------------------------------------------------------------------
# Probe / rank / merge — the shared query math
# ---------------------------------------------------------------------------


def _probe_windows(sorted_keys, perm, keys, cap, live, win=None):
    """Raw probe windows, pre-dedup -> (ids (B, W) local ids, hit (B, W)).

    ``keys`` is (L, B) single-probe or (L, T, B) multi-probe; every op
    broadcasts over the optional probe axis, which is then folded into the
    flattened window axis W = L[*T]*cap (query-major, table-major, probe-
    major, window-minor — the exact (L, B) flattening order extended by T).
    One (query, table, probe, window-slot) cell per output column: the same
    local id recurs once per probed bucket that holds it, which is what the
    weighted sampling mode counts; ``probe_tables`` sorts + masks the
    recurrences away for the top-k path. ``hit`` is True only for in-range
    slots of the probed bucket whose slot is live (``live`` is the (m+1,)
    lookup — entry m covers the sharded pad sentinel, tombstoned slots are
    False — so dead slots are filtered exactly like bucket misses).

    ``win`` (stores built with an explicit ``bucket_cap``) is the
    (live_rank (L, m+1), live_pos (L, m)) live-window lookup: the probe
    then gathers the first ``cap`` *live* positions of the bucket instead
    of the first ``cap`` positions, so tombstoned slots stop consuming
    truncation-window space (a dense window silently drops live bucket
    members past ``cap`` dead ones until compaction). The live-window
    bound is hoisted to one rank compare per (query, table, probe) — see
    ``repro.kernels.epilogues.probe_windows``, where the implementation
    lives (shared with the fused Pallas query kernel).
    """
    return _epi.probe_windows(sorted_keys, perm, keys, cap, live, win)


def probe_tables(sorted_keys, perm, keys, cap, live, win=None):
    """-> (cand (B, W) int32 with -1 for invalid, valid (B, W) bool),
    W = L[*T]*cap.

    keys: (L, B) uint32 query bucket keys (already hashed + combined), or
    (L, T, B) ranked multi-probe keys. For each query and table (and probe):
    searchsorted into the sorted key array, gather the next ``cap``
    positions, keep those still inside the bucket (same key) whose slot is
    live, then sort + mask duplicates so each local id appears at most
    once — including across the T probed buckets of one table, whose
    windows overlap whenever probes collide (padded expansions repeat the
    base key), so ``n_cand`` counts distinct members at any T.
    """
    m = sorted_keys.shape[1]
    ids, hit = _probe_windows(sorted_keys, perm, keys, cap, live, win)
    cand, valid = _epi.dedup_windows(ids, hit, m)
    return jnp.where(valid, cand, -1).astype(jnp.int32), valid


def select_topk(metric, topk, cand, scores, valid):
    """Stable three-key sort -> (ids (B, topk) with -1 fill, scores (B, topk)).

    Primary key: validity (invalid slots strictly last, independent of their
    score values); secondary key: the score in rank order (ascending distance
    / descending similarity, NaN after every finite score — XLA's total
    order, matching np.argsort in the host path); tertiary key: the
    candidate id itself, so score ties resolve to the ascending id
    *regardless of candidate position*. Single-table probes present
    candidates in ascending-id order, where the id key reproduces the old
    stable positional tie-break bit-for-bit; merges over shards and routed
    delta slabs present them in partition order, where the explicit key is
    what keeps selection independent of how items are laid out — the
    invariant behind mutated-vs-fresh parity for any shard routing.
    """
    order_key = scores if metric == "euclidean" else -scores
    _, _, s_cand, s_scores, s_valid = jax.lax.sort(
        (~valid, order_key, cand, scores, valid),
        dimension=1, is_stable=True, num_keys=3)
    k = min(topk, cand.shape[1])
    bad = _bad_score(metric)
    ids = jnp.where(s_valid[:, :k], s_cand[:, :k], -1)
    out_scores = jnp.where(s_valid[:, :k], s_scores[:, :k], bad)
    if k < topk:
        ids = jnp.pad(ids, ((0, 0), (0, topk - k)), constant_values=-1)
        out_scores = jnp.pad(out_scores, ((0, 0), (0, topk - k)),
                             constant_values=bad)
    return ids, out_scores


def rank_candidates(metric, topk, queries, corpus, cand, valid):
    """(cand, valid) (B, W) -> (ids (B, topk), scores (B, topk), n_cand (B,)).

    Exact in-format re-rank of every valid candidate followed by the
    validity-aware top-k selection. Rows with no valid candidate come out
    all -1 / bad-fill even when scores are NaN or +/-inf (e.g. a zero-norm
    query under cosine) — selection never trusts score sentinels alone.
    """
    n_cand = valid.sum(axis=1, dtype=jnp.int32)
    safe = jnp.where(valid, cand, 0)
    sub = tree_index(corpus, safe)                        # leaves (B, C, ...)
    score = _score_fn(metric)
    scores = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: score(q, y))(ys))(queries, sub)
    scores = jnp.where(valid, scores, _bad_score(metric))
    ids, out_scores = select_topk(metric, topk, cand, scores, valid)
    return ids, out_scores, n_cand


def segment_candidates(seg_arrays, keys, cap):
    """One segment's probe -> (cand (B, L*cap) effective ids with -1 fill,
    valid (B, L*cap) bool). ``seg_arrays`` is the (corpus, sorted_keys,
    perm, live, eff, win) tuple; local ids are mapped through ``eff`` into
    the store's effective (live-corpus) numbering."""
    _, sorted_keys, perm, live, eff, win = seg_arrays
    cand, valid = probe_tables(sorted_keys, perm, keys, cap, live, win)
    safe = jnp.where(valid, cand, 0)
    return jnp.where(valid, eff[safe], -1), valid


def segment_topk(metric, topk, cap, queries, seg_arrays, keys):
    """One segment's probe + re-rank -> ((B, topk) effective ids, scores,
    n_cand). ``seg_arrays`` is the (corpus, sorted_keys, perm, live, eff,
    win) tuple; candidates come back already mapped through ``eff`` into
    the store's effective (live-corpus) numbering, -1 fill preserved."""
    corpus, sorted_keys, perm, live, eff, win = seg_arrays
    cand, valid = probe_tables(sorted_keys, perm, keys, cap, live, win)
    ids, scores, n_cand = rank_candidates(metric, topk, queries, corpus,
                                          cand, valid)
    return jnp.where(ids >= 0, eff[jnp.where(ids >= 0, ids, 0)], -1), \
        scores, n_cand


def merge_topk(metric, topk, ids, scores, n_cand):
    """(G, B, k) per-group top-k -> global (ids, scores, n_cand).

    Group-major concatenation + the same stable validity-aware selection as
    the single-table path. The effective id rides along as the third sort
    key, so score ties resolve identically however items are partitioned
    into groups — shards, delta slabs, or both — and the merged top-k is
    bit-identical to ranking all candidates in one table.
    """
    g, b, k = ids.shape
    flat_ids = ids.transpose(1, 0, 2).reshape(b, g * k)
    flat_scores = scores.transpose(1, 0, 2).reshape(b, g * k)
    out_ids, out_scores = select_topk(metric, topk, flat_ids, flat_scores,
                                      flat_ids >= 0)
    return out_ids, out_scores, n_cand.sum(axis=0)


def shard_topk_with_deltas(metric, topk, cap, delta_caps, queries, base_s,
                           deltas_s, keys):
    """One shard's merged top-k over its base slice + its delta slabs.

    ``base_s`` / each element of ``deltas_s`` is a per-shard (corpus,
    sorted_keys, perm, live, eff, win) tuple (no leading shard dim). The
    single body shared verbatim by the vmapped and the shard_map sharded
    query programs, which must stay bit-identical; the per-shard top-k
    covers base + deltas together, so the only cross-shard stage left is
    the final S-way merge."""
    outs = [segment_topk(metric, topk, cap, queries, base_s, keys)]
    for seg_arrays, dcap in zip(deltas_s, delta_caps):
        outs.append(segment_topk(metric, topk, dcap, queries, seg_arrays,
                                 keys))
    if len(outs) == 1:
        return outs[0]
    return merge_topk(metric, topk,
                      jnp.stack([o[0] for o in outs]),
                      jnp.stack([o[1] for o in outs]),
                      jnp.stack([o[2] for o in outs]))


# ---------------------------------------------------------------------------
# The fused probe schedule (probe_backend='xla'): one segment-major scan,
# hoisted-norm re-rank, one packed top-k over every segment's candidates
# ---------------------------------------------------------------------------


def hoisted_scores(metric, queries, corpus, safe):
    """Exact re-rank scores of gathered candidates, hoisted-norm schedule.

    ``safe`` is the (B, W) clamped candidate matrix. Instead of evaluating
    the three-contraction score on every materialized (B, W) candidate pair
    (``rank_candidates``' schedule — the corpus self-inner <Y, Y> is
    recomputed per (query, candidate) cell), the per-item self-inners are
    computed once over the segment (m of them instead of B*W) and gathered
    as scalars; only the cross inner <Q, Y> touches the gathered corpus
    rows. The scalar combine is the exact expression of
    ``contractions.distance`` / ``cosine_similarity`` — the same three
    inner products flow through the same add/mul/sqrt order, so scores are
    bit-identical to the reference schedule (pinned by
    tests/test_fused_probe.py); only the redundant work is gone.

    The per-item <Y, Y> sweep deliberately runs through the SAME
    gather-into-nested-vmap structure the reference uses for its per-cell
    self-inners (an identity gather batched (1, m)): XLA's CPU backend
    picks the reduction lowering per program structure, and a plain
    row-vmap over the contiguous corpus can round the last bit differently
    from the reference's batched gathered dots on some shapes. Routing the
    hoisted sweep through the identical structure keeps the values
    bit-equal at every shape, not just the benchmarked ones.
    """
    inner = contractions.inner
    m = jax.tree.leaves(corpus)[0].shape[0]
    rows = tree_index(corpus, jnp.arange(m)[None])        # leaves (1, m, ...)
    yy = jax.vmap(
        lambda ys: jax.vmap(lambda y: inner(y, y))(ys))(rows)[0]  # (m,)
    qq = jax.vmap(lambda q: inner(q, q))(queries)         # (B,)
    sub = tree_index(corpus, safe)                        # leaves (B, W, ...)
    qy = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: inner(q, y))(ys))(queries, sub)
    if metric == "euclidean":
        d2 = qq[:, None] + yy[safe] - 2.0 * qy
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    nq = jnp.sqrt(jnp.maximum(qq, 0.0))
    ny = jnp.sqrt(jnp.maximum(yy, 0.0))
    return qy / (nq[:, None] * ny[safe])


def segment_packed_candidates(metric, cap, queries, seg_arrays, keys):
    """One segment's probe + hoisted re-rank -> packed selection operands
    (hi (B, W) uint32 order keys, lo (B, W) int32 effective ids, n_cand
    (B,)). The probe epilogue stages (windows, dedup, packing) are the
    shared implementations in ``repro.kernels.epilogues``."""
    corpus, sorted_keys, perm, live, eff, win = seg_arrays
    m = sorted_keys.shape[1]
    ids, hit = _epi.probe_windows(sorted_keys, perm, keys, cap, live, win)
    cand, valid = _epi.dedup_windows(ids, hit, m)
    safe = jnp.where(valid, cand, 0)
    scores = hoisted_scores(metric, queries, corpus, safe)
    hi, lo = _epi.pack_candidates(metric, eff[safe], scores, valid)
    return hi, lo, valid.sum(axis=1, dtype=jnp.int32)


def _packed_query_segments(metric, topk, queries, segs, caps, keys):
    """Fused multi-segment top-k: every segment's packed candidates feed
    ONE flat packed selection. Bit-identical to per-segment ``segment_topk``
    + ``merge_topk``: both selections are keyed by (validity, score,
    effective id) — a strict total order, since effective ids are unique
    across a store's segments — so the merge tree and the flat sort pick
    the same top-k in the same order."""
    parts = [segment_packed_candidates(metric, cap, queries, sa, keys)
             for sa, cap in zip(segs, caps)]
    ids, scores = _epi.packed_select(
        metric, topk,
        jnp.concatenate([p[0] for p in parts], axis=1),
        jnp.concatenate([p[1] for p in parts], axis=1))
    n_cand = parts[0][2]
    for _, _, nc in parts[1:]:
        n_cand = n_cand + nc
    return ids, scores, n_cand


def shard_packed_topk_with_deltas(metric, topk, cap, delta_caps, queries,
                                  base_s, deltas_s, keys):
    """One shard's fused top-k over its base slice + delta slabs — the
    packed-selection counterpart of ``shard_topk_with_deltas``, shared by
    the vmapped and the shard_map sharded query programs (bit-identical to
    the reference body; see ``_packed_query_segments``)."""
    segs = (base_s,) + tuple(deltas_s)
    caps = (cap,) + tuple(delta_caps)
    return _packed_query_segments(metric, topk, queries, segs, caps, keys)


# ---------------------------------------------------------------------------
# The shared query planner (single-device / host / vmapped-sharded programs;
# the shard_map variant lives in repro.distributed.index_sharding)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric", "topk", "caps",
                                             "probes", "probe_backend"))
def segmented_query(family, segs, mults, queries, *, metric, topk, caps,
                    probes=1, probe_backend="auto"):
    """One program from query batch to top-k over every segment: hash once
    (expanding to T ranked bucket keys per table when ``probes`` > 1),
    probe + re-rank each segment, select. ``segs`` is a tuple of per-segment
    array tuples ordered by slot offset (base first, deltas in insert
    order); ``caps`` the matching static probe widths.

    ``probe_backend`` picks the probe/re-rank/select evaluation path (see
    ``resolved_probe_backend``): 'xla' runs the fused segment-major
    schedule in this module, 'pallas' the fused query kernel. Both are
    bit-identical to ``segmented_query_reference``.
    """
    if resolved_probe_backend(probe_backend) == "pallas":
        from repro.kernels import fused_query
        return fused_query.fused_query(family, segs, mults, queries,
                                       metric=metric, topk=topk, caps=caps,
                                       probes=probes)
    keys = query_keys(family, mults, queries, probes)
    return _packed_query_segments(metric, topk, queries, segs, caps, keys)


@functools.partial(jax.jit, static_argnames=("metric", "topk", "caps",
                                             "probes"))
def segmented_query_reference(family, segs, mults, queries, *, metric, topk,
                              caps, probes=1):
    """The reference planner: per-segment probe_tables + rank_candidates +
    merge_topk as separate stages. Every fused probe backend is pinned
    bit-identical to this program (tests/test_fused_probe.py); the
    sampling query modes and the candidate-inspection paths still run its
    stages directly."""
    keys = query_keys(family, mults, queries, probes)
    outs = [segment_topk(metric, topk, cap, queries, sa, keys)
            for sa, cap in zip(segs, caps)]
    return merge_topk(metric, topk,
                      jnp.stack([o[0] for o in outs]),
                      jnp.stack([o[1] for o in outs]),
                      jnp.stack([o[2] for o in outs]))


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps", "probes",
                                             "probe_backend"))
def sharded_query_vmap(family, base, deltas, mults, queries, *, metric, topk,
                       cap, delta_caps, probes=1, probe_backend="auto"):
    """Single-program sharded query without a mesh: probe every (shard,
    segment) and select globally.

    Used when fewer devices than shards exist (e.g. the 1-device tier-1
    run); bit-identical to the shard_map program in
    repro.distributed.index_sharding. On the 'xla' probe backend the
    per-shard packed candidates (vmapped over the S axis) feed ONE flat
    packed selection — no per-shard top-k + S-way merge tree; the flat
    sort is keyed by (validity, score, effective id), and effective ids
    are globally unique across shards, so the result is bit-identical to
    ``sharded_query_vmap_reference`` (and to the merge tree). The 'pallas'
    backend runs the fused query kernel per shard and merges.
    """
    if resolved_probe_backend(probe_backend) == "pallas":
        from repro.kernels import fused_query
        return fused_query.fused_query_sharded(
            family, base, deltas, mults, queries, metric=metric, topk=topk,
            cap=cap, delta_caps=delta_caps, probes=probes)
    keys = query_keys(family, mults, queries, probes)

    def shard_packed(base_s, deltas_s):
        segs = (base_s,) + tuple(deltas_s)
        caps = (cap,) + tuple(delta_caps)
        parts = [segment_packed_candidates(metric, c, queries, sa, keys)
                 for sa, c in zip(segs, caps)]
        nc = parts[0][2]
        for _, _, n in parts[1:]:
            nc = nc + n
        return (jnp.concatenate([p[0] for p in parts], axis=1),
                jnp.concatenate([p[1] for p in parts], axis=1), nc)

    hi, lo, nc = jax.vmap(shard_packed, in_axes=(0, 0))(base, deltas)
    s, b, w = hi.shape
    ids, scores = _epi.packed_select(metric, topk,
                                     hi.transpose(1, 0, 2).reshape(b, s * w),
                                     lo.transpose(1, 0, 2).reshape(b, s * w))
    return ids, scores, nc.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps", "probes"))
def sharded_query_vmap_reference(family, base, deltas, mults, queries, *,
                                 metric, topk, cap, delta_caps, probes=1):
    """Reference sharded planner: vmap the per-shard base + delta-slab
    merge-tree body (``shard_topk_with_deltas``) over the S axis, then the
    global S-way merge — the program every fused probe backend is pinned
    bit-identical to."""
    keys = query_keys(family, mults, queries, probes)
    per_shard = jax.vmap(
        lambda base_s, deltas_s: shard_topk_with_deltas(
            metric, topk, cap, delta_caps, queries, base_s, deltas_s, keys),
        in_axes=(0, 0))(base, deltas)                     # (S, B, k) each
    return merge_topk(metric, topk, *per_shard)


@functools.partial(jax.jit, static_argnames=("caps", "probes"))
def segmented_candidates(family, segs, mults, queries, *, caps, probes=1):
    """-> (cand (B, sum L[*T]*cap_g) effective ids with -1 fill, valid)."""
    keys = query_keys(family, mults, queries, probes)
    cands, valids = [], []
    for seg_arrays, cap in zip(segs, caps):
        cand, valid = segment_candidates(seg_arrays, keys, cap)
        cands.append(cand)
        valids.append(valid)
    return jnp.concatenate(cands, axis=1), jnp.concatenate(valids, axis=1)


@functools.partial(jax.jit, static_argnames=("cap", "delta_caps", "probes"))
def sharded_candidates(family, base, deltas, mults, queries, *, cap,
                       delta_caps, probes=1):
    """Sharded-base + sharded-delta-slab variant of
    ``segmented_candidates`` (vmap over shards for every segment)."""
    keys = query_keys(family, mults, queries, probes)
    parts = [jax.vmap(lambda b_s: segment_candidates(b_s, keys, cap))(base)]
    for seg_arrays, dcap in zip(deltas, delta_caps):
        parts.append(jax.vmap(
            lambda d_s, dcap=dcap: segment_candidates(d_s, keys, dcap)
        )(seg_arrays))                                    # (S, B, W) each
    cands, valids = [], []
    for cand, valid in parts:
        s, b, w = cand.shape
        cands.append(cand.transpose(1, 0, 2).reshape(b, s * w))
        valids.append(valid.transpose(1, 0, 2).reshape(b, s * w))
    return jnp.concatenate(cands, axis=1), jnp.concatenate(valids, axis=1)


# ---------------------------------------------------------------------------
# Sampling query modes (uniform / weighted over the probed bucket union)
# ---------------------------------------------------------------------------


def _segment_scored_hits(metric, cap, queries, seg_arrays, keys):
    """One segment's raw probe windows, scored and mapped to effective ids.

    -> (eid (B, W) int32 — the effective id of each raw window hit,
    ``_NO_ID`` for misses; scores (B, W) exact metric scores, bad-fill for
    misses), W = L[*T]*cap. Pre-dedup on purpose: the same item recurs once
    per (table, probe, segment-window) hit, and that multiplicity is the
    ``weighted`` sampling weight. Recurrences of one item gather the same
    corpus row, so their scores are bit-identical — any run member can
    represent the item after the id sort in ``_sample_topk``.
    """
    corpus, sorted_keys, perm, live, eff, win = seg_arrays
    ids, hit = _probe_windows(sorted_keys, perm, keys, cap, live, win)
    safe = jnp.where(hit, ids, 0)
    eid = jnp.where(hit, eff[safe], _NO_ID)
    sub = tree_index(corpus, safe)                        # leaves (B, W, ...)
    score = _score_fn(metric)
    scores = jax.vmap(
        lambda q, ys: jax.vmap(lambda y: score(q, y))(ys))(queries, sub)
    return eid, jnp.where(hit, scores, _bad_score(metric))


def _sample_topk(metric, topk, mode, rng, eid, scores):
    """Gumbel-top-k sample of ``topk`` distinct members from the probed
    union -> (ids (B, topk) with -1 fill, scores (B, topk), n_cand (B,)).

    ``eid``/``scores`` are the concatenated raw window hits of every
    segment ((B, W), misses = ``_NO_ID``/bad-fill). The rows are sorted by
    effective id (scores ride along), so each distinct member forms one
    run; the run length is its raw hit multiplicity. Per-run logits are 0
    for ``uniform`` (every distinct live member equally likely) and
    log(multiplicity) for ``weighted`` (a member is drawn with probability
    proportional to how many probed buckets hold it — equivalently,
    uniform over raw (bucket, member) tickets, so bigger probed buckets
    contribute proportionally more draws); non-run slots get -inf. Adding
    one Gumbel(0, 1) draw per slot and taking the top ``topk`` perturbed
    logits is then an exact without-replacement sample of ``topk`` distinct
    members from that distribution (the marginal of the first draw is the
    exact softmax categorical — what the seeded chi-square tests pin).
    Rows with fewer than ``topk`` distinct members sample them all.
    ``n_cand`` counts the distinct members, matching the top-k path at the
    same (L, T). The sampled subset is presented through ``select_topk``
    (score order, -1 fill), so the output contract matches ``query_batch``.
    """
    b, w = eid.shape
    s_eid, s_scores = jax.lax.sort((eid, scores), dimension=1, is_stable=True,
                                   num_keys=1)
    prev = jnp.concatenate(
        [jnp.full((b, 1), -1, s_eid.dtype), s_eid[:, :-1]], axis=1)
    newrun = s_eid != prev                   # first slot of each id run (the
    isfirst = newrun & (s_eid != _NO_ID)     # _NO_ID tail forms its own run)
    idx = jnp.arange(w, dtype=jnp.int32)
    bound = jnp.where(newrun, idx, w)
    nxt = jax.lax.cummin(bound[:, ::-1], axis=1)[:, ::-1]  # next boundary >= i
    nxt = jnp.concatenate(
        [nxt[:, 1:], jnp.full((b, 1), w, jnp.int32)], axis=1)  # strictly > i
    mult = jnp.where(isfirst, nxt - idx, 0)  # raw hit multiplicity of the run
    n_cand = isfirst.sum(axis=1, dtype=jnp.int32)
    if mode == "uniform":
        logits = jnp.where(isfirst, 0.0, -jnp.inf)
    elif mode == "weighted":
        logits = jnp.where(isfirst,
                           jnp.log(jnp.maximum(mult, 1).astype(jnp.float32)),
                           -jnp.inf)
    else:
        raise ValueError(f"unknown sampling mode {mode!r}")
    pert = logits + jax.random.gumbel(rng, (b, w), dtype=jnp.float32)
    k = min(topk, w)
    _, sel = jax.lax.top_k(pert, k)
    cand = jnp.take_along_axis(s_eid, sel, axis=1).astype(jnp.int32)
    cscores = jnp.take_along_axis(s_scores, sel, axis=1)
    cvalid = jnp.take_along_axis(isfirst, sel, axis=1)
    ids, out_scores = select_topk(metric, topk, cand, cscores, cvalid)
    return ids, out_scores, n_cand


@functools.partial(jax.jit, static_argnames=("metric", "topk", "caps",
                                             "probes", "mode"))
def segmented_sample(family, segs, mults, queries, rng, *, metric, topk,
                     caps, probes, mode):
    """Sampling-mode variant of ``segmented_query``: hash once (expanding
    to T probes), collect every segment's raw scored window hits, and draw
    ``topk`` distinct members per query from the probed union — uniform or
    bucket-size-weighted — with one explicit PRNG key per call (each query
    row consumes independent Gumbel noise from it)."""
    keys = query_keys(family, mults, queries, probes)
    parts = [_segment_scored_hits(metric, cap, queries, sa, keys)
             for sa, cap in zip(segs, caps)]
    return _sample_topk(metric, topk, mode, rng,
                        jnp.concatenate([p[0] for p in parts], axis=1),
                        jnp.concatenate([p[1] for p in parts], axis=1))


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps", "probes", "mode"))
def sharded_sample_vmap(family, base, deltas, mults, queries, rng, *, metric,
                        topk, cap, delta_caps, probes, mode):
    """Sharded-base + sharded-delta-slab variant of ``segmented_sample``
    (vmap over shards for every segment, then one global draw over the
    cross-shard union — sampling is a global decision, so the sharded
    index always runs this single-program path, mesh or not)."""
    keys = query_keys(family, mults, queries, probes)
    parts = [jax.vmap(
        lambda b_s: _segment_scored_hits(metric, cap, queries, b_s, keys)
    )(base)]
    for seg_arrays, dcap in zip(deltas, delta_caps):
        parts.append(jax.vmap(
            lambda d_s, dcap=dcap: _segment_scored_hits(metric, dcap,
                                                        queries, d_s, keys)
        )(seg_arrays))                                    # (S, B, W) each
    eids, scoreses = [], []
    for eid, sc in parts:
        s, b, w = eid.shape
        eids.append(eid.transpose(1, 0, 2).reshape(b, s * w))
        scoreses.append(sc.transpose(1, 0, 2).reshape(b, s * w))
    return _sample_topk(metric, topk, mode, rng,
                        jnp.concatenate(eids, axis=1),
                        jnp.concatenate(scoreses, axis=1))


# ---------------------------------------------------------------------------
# Live-window lookups (explicit bucket_cap stores)
# ---------------------------------------------------------------------------


@jax.jit
@jax.jit
def _live_window_table(perm_l, live):
    """One table of ``_live_window_tables`` as its own bounded program."""
    live_sorted = live[perm_l]                            # (m,) bool
    rank = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(live_sorted, dtype=jnp.int32)])
    pos = jnp.argsort(~live_sorted, stable=True).astype(jnp.int32)
    return rank, pos


def _live_window_tables(perm, live):
    """(L, m) perm + (m+1,) live -> (live_rank (L, m+1), live_pos (L, m)).

    ``live_rank[p]`` counts the live slots among sorted positions [0, p) of
    the table; ``live_pos`` lists the live positions in ascending order
    (dead positions follow, also ascending — a probe walking past the live
    members of a bucket lands on dead slots that the liveness mask then
    filters). Together they let a truncated probe window address the j-th
    *live* member of a bucket directly. Issued as one bounded program per
    table (tables are independent, so values are unchanged), blocking
    between programs: window rebuilds run on the mutation plane — deletes,
    shadow-store builds — and must never queue one all-tables argsort
    ahead of a concurrently dispatched query."""
    outs = []
    for table in range(perm.shape[0]):
        out = _live_window_table(perm[table], live)
        jax.block_until_ready(out)
        _yield_slot()
        outs.append(out)
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def _live_window_tables_sharded(perm, live):
    """Sharded variant of ``_live_window_tables``: perm (S, L, n_s) + live
    (S, n_s + 1) -> (rank (S, L, n_s + 1), pos (S, L, n_s)), one bounded
    per-(table, shard) program, throttled like the flat version. Shards
    are independent too, so splitting below the table level changes no
    value (integer sort/scan math) — it bounds each program at O(n_s)
    instead of O(S * n_s), which is what keeps a concurrent query's wait
    to one slab-sized program during a background delete at high S."""
    outs = []
    for table in range(perm.shape[1]):
        shards = []
        for sh in range(perm.shape[0]):
            out = _live_window_table(perm[sh, table], live[sh])
            jax.block_until_ready(out)
            _yield_slot()
            shards.append(out)
        outs.append((jnp.stack([o[0] for o in shards]),
                     jnp.stack([o[1] for o in shards])))
    return (jnp.stack([o[0] for o in outs], axis=1),
            jnp.stack([o[1] for o in outs], axis=1))


# ---------------------------------------------------------------------------
# Mutable store: base + deltas + tombstones
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StoreView:
    """One immutable, internally-consistent snapshot of a store's queryable
    state — the handle a query pins for its whole program.

    ``SegmentStore`` publishes a fresh view (one atomic attribute write) at
    the end of every mutation; readers grab ``store.view`` once and derive
    every program input (segment arrays, liveness/effective-id lookups,
    probe caps) from that single object, so a query dispatched concurrently
    with an ``insert``/``delete``/``compact`` swap sees either the whole
    pre-mutation state or the whole post-mutation state — never a torn mix
    of segments from one generation and lookups from another. ``generation``
    increments with every publish; the double-buffered swap machinery in
    ``repro.core.index`` uses it to refuse publishing a shadow store whose
    source mutated while the shadow was building.
    """

    segments: tuple          # base + deltas, slot-offset order
    luts: tuple              # per-segment (live, eff) device lookups
    wins: tuple              # per-segment live-window lookups (or None)
    generation: int

    @property
    def base(self):
        return self.segments[0]

    @property
    def n_deltas(self) -> int:
        return len(self.segments) - 1

    def seg_arrays(self, i: int):
        """(corpus, sorted_keys, perm, live, eff, win) of segment i."""
        seg = self.segments[i]
        live, eff = self.luts[i]
        return (seg.corpus, seg.sorted_keys, seg.perm, live, eff,
                self.wins[i])

    @property
    def all_arrays(self) -> tuple:
        return tuple(self.seg_arrays(i) for i in range(len(self.segments)))

    @property
    def delta_arrays(self) -> tuple:
        return tuple(self.seg_arrays(i)
                     for i in range(1, len(self.segments)))

    @property
    def all_caps(self) -> tuple[int, ...]:
        return tuple(seg.cap for seg in self.segments)

    @property
    def delta_caps(self) -> tuple[int, ...]:
        return tuple(seg.cap for seg in self.segments[1:])


class SegmentStore:
    """LSM-style mutable view over immutable segments.

    Holds one base segment (``TableSegment`` or ``ShardedSegment``), a
    bounded list of delta segments (``TableSegment``s on the single-device
    store, routed ``ShardedSegment`` slabs on the sharded store), and a
    host-side tombstone mask over every slot (shard-pad slots are born
    dead). Each segment also carries a host-side ``slot_pos`` map from slot
    to *sequence position* — the order items entered the store — because
    routed slabs interleave shards, so slot order no longer equals arrival
    order. After each mutation the store re-derives the per-segment device
    arrays the planner consumes:

      live  (m+1,) bool   per segment (sharded: (S, n_s+1)) — slot liveness
                          with the pad-sentinel entry always False
      eff   (m,) int32    per segment (sharded: (S, n_s)) — the slot's
                          effective id: its rank among live slots in
                          sequence order, i.e. its index in
                          ``effective_corpus()``
      win   optional      (live_rank, live_pos) live-window lookups, built
                          only for ``live_window=True`` stores (explicit
                          bucket_cap indexes) so truncated probe windows
                          skip tombstoned slots

    Deletes only flip mask bits (same array shapes -> no recompilation);
    inserts append a segment (bounded recompiles, the index compacts past
    ``max_deltas``). ``place`` keeps every sharded segment's derived
    arrays on the index's mesh; ``base_pos`` overrides the base slot ->
    sequence map (shard-local compaction produces bases whose shards hold
    non-contiguous sequence ranges).

    Every mutation ends by publishing a fresh immutable ``StoreView`` (one
    atomic attribute write); queries read ``store.view`` once and serve the
    whole program from it, so mutations racing a query from another thread
    can never tear the segment/lookup pairing mid-read.
    """

    def __init__(self, base, *, place: Callable | None = None,
                 base_pos: np.ndarray | None = None,
                 live_window: bool = False):
        self.base = base
        self.deltas: list[TableSegment | ShardedSegment] = []
        self.place = place or (lambda t: t)
        self.live_window = bool(live_window)
        self._generation = 0
        if base_pos is None:
            real = np.zeros(base.slots, bool)
            if isinstance(base, ShardedSegment):
                n_s = base.shard_size
                for s, c in enumerate(base.counts):
                    real[s * n_s:s * n_s + c] = True
            else:
                real[:] = True
            base_pos = np.where(real, np.cumsum(real) - 1, -1)
        self.slot_pos = [np.asarray(base_pos, np.int64)]
        self.live_host = self.slot_pos[0] >= 0  # shard pads are born dead
        self.seq_len = int(base.items)
        self._refresh()

    # -- derived state ------------------------------------------------------

    def _segments(self) -> list:
        return [self.base] + self.deltas

    def _seg_luts(self, seg, live: np.ndarray, eff: np.ndarray):
        if isinstance(seg, ShardedSegment):
            s, n_s = seg.shards, seg.shard_size
            lut = (jnp.asarray(np.pad(live.reshape(s, n_s),
                                      ((0, 0), (0, 1)))),
                   jnp.asarray(eff.reshape(s, n_s).astype(np.int32)))
            return self.place(lut)
        return (jnp.asarray(np.append(live, False)),
                jnp.asarray(eff.astype(np.int32)))

    def _seg_win(self, seg, live_lut):
        if not self.live_window:
            return None
        if isinstance(seg, ShardedSegment):
            return self.place(_live_window_tables_sharded(seg.perm, live_lut))
        return _live_window_tables(seg.perm, live_lut)

    def _refresh(self, touched: set[int] | None = None) -> None:
        """Rebuild the sequence-order views and the segment lookups.

        ``touched`` is the set of segment indices whose live mask changed
        (None = rebuild everything). Segments are ordered blocks in
        sequence space (each delta's positions follow every earlier
        segment's), so segments before the first touched one keep both
        lookups untouched; later segments rebuild ``eff`` (ranks shifted)
        but reuse their live-window tables unless their own mask changed —
        deletes stay cheap even on capped stores with a big base and many
        slabs."""
        live_seq = np.zeros(self.seq_len, bool)
        pos_to_slot = np.full(self.seq_len, -1, np.int64)
        off = 0
        for pos, seg in zip(self.slot_pos, self._segments()):
            valid = pos >= 0
            live_seq[pos[valid]] = self.live_host[off:off + seg.slots][valid]
            pos_to_slot[pos[valid]] = off + np.flatnonzero(valid)
            off += seg.slots
        self._live_seq = live_seq
        self._pos_to_slot = pos_to_slot
        self.n_live = int(live_seq.sum())
        self.n_dead = self.seq_len - self.n_live
        eff_seq = (np.cumsum(live_seq) - 1).astype(np.int64)
        first = 0 if touched is None else min(touched, default=0)
        luts, wins, off = [], [], 0
        for i, (pos, seg) in enumerate(zip(self.slot_pos,
                                           self._segments())):
            if touched is not None and i < first:
                luts.append(self._luts[i])
                wins.append(self._wins[i])
                off += seg.slots
                continue
            live = self.live_host[off:off + seg.slots]
            eff = (eff_seq[np.clip(pos, 0, None)] if self.seq_len
                   else np.zeros(seg.slots, np.int64))
            eff = np.where(pos >= 0, eff, 0)
            lut = self._seg_luts(seg, live, eff)
            luts.append(lut)
            if touched is None or i in touched:
                wins.append(self._seg_win(seg, lut[0]))
            else:
                wins.append(self._wins[i])
            off += seg.slots
        self._luts, self._wins = luts, wins
        self._publish()

    def _publish(self) -> None:
        """Assemble + install a fresh immutable view (one atomic write)."""
        self._generation += 1
        self.view = StoreView(segments=tuple(self._segments()),
                              luts=tuple(self._luts),
                              wins=tuple(self._wins),
                              generation=self._generation)

    @property
    def generation(self) -> int:
        """Monotone mutation clock: bumps whenever a new view publishes."""
        return self.view.generation

    def seg_arrays(self, i: int):
        """(corpus, sorted_keys, perm, live, eff, win) of segment i
        (0 = base; ``win`` is None unless the store keeps live windows).
        Served from the published view — for a multi-access read sequence
        that must stay consistent under concurrent mutation, pin
        ``store.view`` once instead."""
        return self.view.seg_arrays(i)

    @property
    def delta_arrays(self) -> tuple:
        return self.view.delta_arrays

    @property
    def delta_caps(self) -> tuple[int, ...]:
        return self.view.delta_caps

    @property
    def all_arrays(self) -> tuple:
        return self.view.all_arrays

    @property
    def all_caps(self) -> tuple[int, ...]:
        return self.view.all_caps

    @property
    def mutated(self) -> bool:
        return bool(self.deltas) or self.n_dead > 0

    @property
    def shard_live_counts(self) -> np.ndarray:
        """(S,) live items per shard over the base + every sharded delta —
        the occupancy the routing policy balances against."""
        counts = None
        off = 0
        for seg in self._segments():
            live = self.live_host[off:off + seg.slots]
            if isinstance(seg, ShardedSegment):
                c = live.reshape(seg.shards, seg.shard_size).sum(axis=1)
                counts = c.astype(np.int64) if counts is None else counts + c
            off += seg.slots
        return counts

    # -- durability hooks ----------------------------------------------------

    def host_state(self) -> dict:
        """The host-side bookkeeping a snapshot must persist next to the
        segment arrays: the slot -> sequence-position maps, the tombstone
        mask, and the sequence clock. Everything else the store serves
        (liveness/effective-id/live-window lookups, the published view) is
        re-derived deterministically by ``restore`` via ``_refresh``, so a
        snapshot never has to serialize device lookups."""
        return {
            "slot_pos": [np.asarray(p, np.int64) for p in self.slot_pos],
            "live_host": np.asarray(self.live_host, bool),
            "seq_len": int(self.seq_len),
            "live_window": bool(self.live_window),
        }

    @classmethod
    def restore(cls, segs, state: dict, *,
                place: Callable | None = None) -> "SegmentStore":
        """Rebuild a store from snapshotted segments + ``host_state()``.

        Installs the raw host state, then re-derives every lookup and
        publishes a fresh view through ``_refresh`` — the same code path
        every live mutation ends with — so a restored store answers
        queries bit-identically to the one that was snapshotted."""
        if len(segs) != len(state["slot_pos"]):
            raise ValueError(
                f"{len(segs)} segments but {len(state['slot_pos'])} "
                "slot_pos maps in the snapshot state")
        store = cls.__new__(cls)
        store.base = segs[0]
        store.deltas = list(segs[1:])
        store.place = place or (lambda t: t)
        store.live_window = bool(state["live_window"])
        store._generation = 0
        store.slot_pos = [np.asarray(p, np.int64) for p in state["slot_pos"]]
        store.live_host = np.asarray(state["live_host"], bool)
        store.seq_len = int(state["seq_len"])
        store._refresh()
        return store

    # -- mutations ----------------------------------------------------------

    def append_delta(self, seg, positions: np.ndarray | None = None) -> None:
        """O(batch) append: earlier segments' liveness and effective ids are
        untouched (new items rank after every live item), so only the new
        segment's lookups are built — no base-array re-upload per insert.
        ``positions`` maps the segment's slots to sequence positions (``-1``
        pads); defaults to the identity continuation for flat deltas."""
        if positions is None:
            positions = np.arange(self.seq_len, self.seq_len + seg.slots)
        positions = np.asarray(positions, np.int64)
        valid = positions >= 0
        n_new = int(valid.sum())
        start, seq0, slots0 = self.n_live, self.seq_len, self.live_host.size
        self.deltas.append(seg)
        self.slot_pos.append(positions)
        self.live_host = np.concatenate([self.live_host, valid])
        self._live_seq = np.concatenate([self._live_seq,
                                         np.ones(n_new, bool)])
        p2s = np.full(n_new, -1, np.int64)
        p2s[positions[valid] - seq0] = slots0 + np.flatnonzero(valid)
        self._pos_to_slot = np.concatenate([self._pos_to_slot, p2s])
        self.seq_len += n_new
        self.n_live += n_new
        eff = np.where(valid, start + (positions - seq0), 0)
        lut = self._seg_luts(seg, valid, eff)
        self._luts.append(lut)
        self._wins.append(self._seg_win(seg, lut[0]))
        self._publish()

    def delete_effective(self, ids: np.ndarray) -> int:
        """Tombstone items by their current *effective* ids (the numbering
        queries return). Returns the number of newly-dead items."""
        ids = np.unique(np.asarray(ids, np.int64))
        if ids.size == 0:
            return 0
        if ids.size and (ids[0] < 0 or ids[-1] >= self.n_live):
            raise IndexError(
                f"delete ids must be in [0, {self.n_live}), got "
                f"[{ids[0]}, {ids[-1]}]")
        seq_ids = np.flatnonzero(self._live_seq)[ids]
        slots = self._pos_to_slot[seq_ids]
        self.live_host[slots] = False
        bounds = np.cumsum([seg.slots for seg in self._segments()])
        touched = set(np.searchsorted(bounds, slots,
                                      side="right").tolist())
        self._refresh(touched)
        return int(ids.size)

    # -- effective (live) views --------------------------------------------

    def _flat_keys_and_corpus(self):
        flat_keys, flat_corpus = [], []
        for seg in self._segments():
            if isinstance(seg, ShardedSegment):
                flat_keys.append(seg.keys.reshape(-1, seg.keys.shape[-1]))
                flat_corpus.append(jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), seg.corpus))
            else:
                flat_keys.append(seg.keys)
                flat_corpus.append(seg.corpus)
        keys = jnp.concatenate(flat_keys, axis=0)
        corpus = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *flat_corpus)
        return keys, corpus

    def _live_slots_seq_order(self) -> np.ndarray:
        """Flat slot indices of the live items, in sequence order."""
        live_slots = np.flatnonzero(self.live_host)
        pos = np.concatenate(self.slot_pos)[live_slots]
        return live_slots[np.argsort(pos, kind="stable")]

    def effective_arrays(self):
        """-> ((n_live, L) keys, corpus pytree) of live items in sequence
        (= effective id) order — the rebalance/global-compaction input;
        keys come from storage, never from re-hashing."""
        keys, corpus = self._flat_keys_and_corpus()
        idx = jnp.asarray(self._live_slots_seq_order())
        return keys[idx], tree_index(corpus, idx)

    def effective_arrays_chunked(self, chunk: int):
        """``effective_arrays`` with the corpus assembled by bounded
        gather+scatter programs (``gather_rows_chunked``) instead of one
        store-sized concatenate + gather. Bit-identical output; the
        shadow-build (``prepare_compact``) path uses it so concurrent
        queries never queue behind a store-sized program. Keys stay on the
        one-program path — they are a few bytes per item."""
        idx = self._live_slots_seq_order()
        flat_keys = []
        srcs, src_idxs, dst_idxs = [], [], []
        off = 0
        for seg in self._segments():
            if isinstance(seg, ShardedSegment):
                flat_keys.append(seg.keys.reshape(-1, seg.keys.shape[-1]))
                flat = jax.tree.map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), seg.corpus)
            else:
                flat_keys.append(seg.keys)
                flat = seg.corpus
            w = seg.slots
            dst = np.flatnonzero((idx >= off) & (idx < off + w))
            srcs.append(flat)
            src_idxs.append(idx[dst] - off)
            dst_idxs.append(dst)
            off += w
        keys = jnp.concatenate(flat_keys, axis=0)[jnp.asarray(idx)]
        corpus = gather_rows_chunked(srcs[0], srcs, src_idxs, dst_idxs,
                                     idx.size, chunk=chunk)
        return keys, corpus

    def effective_corpus(self):
        """The live corpus in effective-id order. Zero-copy for a pristine
        flat base, a slice view when live slots are already a contiguous
        prefix in sequence order (pristine contiguous sharded base), and a
        corpus-only gather otherwise — the stored keys are never touched
        (``effective_arrays`` is the keys+corpus variant compaction needs).
        """
        if not self.mutated and isinstance(self.base, TableSegment):
            return self.base.corpus
        flats = [jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                              seg.corpus)
                 if isinstance(seg, ShardedSegment) else seg.corpus
                 for seg in self._segments()]
        corpus = flats[0] if len(flats) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *flats)
        idx = self._live_slots_seq_order()
        if np.array_equal(idx, np.arange(idx.size)):
            return tree_index(corpus, slice(0, idx.size))
        return tree_index(corpus, jnp.asarray(idx))
