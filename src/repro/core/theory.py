"""Closed-form collision probabilities and rank conditions from the paper.

Used by tests and benchmarks to validate the implementation against the
paper's own claims (Theorems 4, 6, 8, 10).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax.scipy.stats import norm as _norm


def e2lsh_collision_prob(r, w: float):
    """p(r) = Pr[h(x) = h(y)] for ||x-y|| = r (paper Eq. 3.4 / 4.17 / 4.33).

    Closed form of  int_0^w (1/r) f(t/r) (1 - t/w) dt  with f the folded
    standard normal density (Datar et al. 2004):

        p(r) = 1 - 2 Phi(-w/r) - (2 r / (sqrt(2 pi) w)) (1 - exp(-w^2 / 2r^2))
    """
    r = jnp.asarray(r, jnp.float64 if False else jnp.float32)
    t = w / r
    return (1.0 - 2.0 * _norm.cdf(-t)
            - (2.0 / (math.sqrt(2.0 * math.pi) * t)) * (1.0 - jnp.exp(-(t * t) / 2.0)))


def srp_collision_prob(cosine):
    """Pr[h(x) = h(y)] = 1 - theta/pi (paper Eq. 3.2 / 4.58 / 4.81)."""
    c = jnp.clip(jnp.asarray(cosine), -1.0, 1.0)
    return 1.0 - jnp.arccos(c) / math.pi


def cp_rank_condition(n_modes: int, dim: int, rank: int) -> float:
    """Ratio sqrt(R) N^(4/5) / d^((3N-8)/10) with d the per-mode dimension
    (Theorem 3/4 side condition, alpha = 5). The LSH guarantee needs this
    ratio -> 0 as the tensor grows; small values indicate the asymptotic
    regime. (Exponent on total size D = d^N is (3N-8)/(10N).)"""
    total = float(dim) ** n_modes
    return math.sqrt(rank) * n_modes ** 0.8 / total ** ((3 * n_modes - 8) / (10.0 * n_modes))


def tt_rank_condition(n_modes: int, dim: int, rank: int) -> float:
    """Ratio sqrt(R^(N-1)) N^(4/5) / D^((3N-8)/10N) (Theorem 5/6 condition)."""
    total = float(dim) ** n_modes
    return (math.sqrt(float(rank) ** (n_modes - 1)) * n_modes ** 0.8
            / total ** ((3 * n_modes - 8) / (10.0 * n_modes)))
