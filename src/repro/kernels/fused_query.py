"""Pallas kernel: one program from a query batch to (id, score) pairs.

The probe side of the query path — multi-probe key expansion, per-segment
binary search over sorted bucket keys, the bounded cap-wide gather with
bucket-boundary / duplicate / tombstone masking, exact in-format re-rank,
and top-k selection — used to be a chain of separate XLA dispatches with
HBM round-trips between every stage. This kernel runs the whole chain as
ONE ``pl.pallas_call``: a query block goes HBM->VMEM once and the program
emits the final (effective id, score) pairs plus the candidate count.

Stage map (all inside the kernel body, per B-block):

  raw projections -> discretize (E2LSH floor / SRP sign, the exact ops of
  ``lsh.LSHFamily.hash_batch_aux``) -> multi-probe expansion (the
  perturbation scores/deltas of ``core.probing.scores_and_deltas`` +
  stable top-T ranking, folded in front of the radix code-combine) ->
  uint32 combine -> per-segment probe windows -> dedup -> hoisted-norm
  re-rank -> packed top-k.

The probe-epilogue stages are the *shared* implementations in
``repro.kernels.epilogues`` and ``repro.core.segments.hoisted_scores`` —
the same functions the restructured XLA schedule calls on full arrays —
so the two probe backends are bit-identical by construction, and both are
pinned bit-identical to the reference planner by tests/test_fused_probe.py.

The only stage left outside is the batched projection contraction itself
(``project_batch``): the multi-probe expansion ranks floor residuals /
sign margins, and those are defined on the XLA projection path for every
``hash_backend`` (see ``hash_batch_aux``) — keeping that contract here
keeps kernel keys bit-identical to the planner's under any hash backend.

On this CPU container the kernel runs with interpret=True (the TPU
lowering is the target; segment arrays as whole-array VMEM refs bound the
corpus sizes a real TPU core can serve — the shard_map-vs-fused TPU
measurement is deferred, see ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import probing
from repro.core import projections as proj_lib
from repro.core import segments as _seg
from repro.kernels import epilogues as _epi
from repro.kernels.ops import _default_interpret, _pad_axis


def _expand_probe_keys(aux, base, mults, *, e2, probes, pa, pb):
    """(aux (bb, L, K), base (bb, L) uint32) -> (bb, L, T) ranked keys.

    The in-kernel body of ``probing.probe_keys``'s expansion: the same
    perturbation scores/deltas (``scores_and_deltas``), the same stable
    ascending top-(T-1), the same base-key slot-0 / base-key padding.
    ``pa``/``pb`` are the static pair indices as Python ints, so the pair
    sums unroll into static slices — no constant index arrays captured by
    the kernel trace.
    """
    if probes == 1:
        return base[..., None]
    if e2:
        r = aux
        s1 = jnp.concatenate([(1.0 - r) ** 2, r ** 2], axis=-1)
        d1 = jnp.concatenate([mults, jnp.uint32(0) - mults])
        d1 = jnp.broadcast_to(d1, s1.shape)
    else:
        s1 = jnp.abs(aux)
        d1 = jnp.where(aux > 0, jnp.uint32(0) - mults, mults)
    scores, deltas = s1, d1
    if len(pa):
        scores = jnp.concatenate(
            [s1, jnp.stack([s1[..., i] + s1[..., j]
                            for i, j in zip(pa, pb)], axis=-1)], axis=-1)
        deltas = jnp.concatenate(
            [d1, jnp.stack([d1[..., i] + d1[..., j]
                            for i, j in zip(pa, pb)], axis=-1)], axis=-1)
    n = min(probes - 1, scores.shape[-1])
    order = jnp.argsort(scores, axis=-1, stable=True)[..., :n]
    keys = base[..., None] + jnp.take_along_axis(deltas, order, axis=-1)
    keys = jnp.concatenate([base[..., None], keys], axis=-1)
    if 1 + n < probes:
        pad = jnp.broadcast_to(base[..., None],
                               base.shape + (probes - 1 - n,))
        keys = jnp.concatenate([keys, pad], axis=-1)
    return keys


def _fused_query_kernel(*refs, metric, topk, caps, probes, e2, w, num_tables,
                        num_codes, pa, pb, q_treedef, n_qleaves, seg_specs):
    """Kernel body: refs are (values, offsets, mults, *query leaves,
    *per-segment arrays..., ids_out, scores_out, ncand_out)."""
    refs = list(refs)
    values_ref, offs_ref, mults_ref = refs[:3]
    pos = 3
    qleaves = [refs[pos + i][...] for i in range(n_qleaves)]
    pos += n_qleaves
    queries = jax.tree.unflatten(q_treedef, qleaves)
    ids_ref, scores_ref, nc_ref = refs[-3:]

    # hash: discretize exactly as LSHFamily.hash_batch_aux
    v = values_ref[...]                                   # (bb, LK)
    bb = v.shape[0]
    mults = mults_ref[...][0]                             # (K,) uint32
    if e2:
        t = (v + offs_ref[...][0]) / w
        codes = jnp.floor(t).astype(jnp.int32)
        aux = (t - codes.astype(v.dtype)).reshape(bb, num_tables, num_codes)
        codes = codes.reshape(bb, num_tables, num_codes)
    else:
        codes = (v > 0).astype(jnp.int32).reshape(bb, num_tables, num_codes)
        aux = v.reshape(bb, num_tables, num_codes)
    # radix combine (lsh._combine_codes) + multi-probe expansion in front
    base = jnp.sum(codes.astype(jnp.uint32) * mults[None, None, :],
                   axis=-1, dtype=jnp.uint32)             # (bb, L)
    keys = _expand_probe_keys(aux, base, mults, e2=e2, probes=probes,
                              pa=pa, pb=pb)               # (bb, L, T)
    keys = jnp.moveaxis(keys, 0, -1)                      # (L, T, bb)

    # probe epilogue per segment: windows -> dedup -> re-rank -> pack
    his, los = [], []
    n_cand = jnp.zeros((bb,), jnp.int32)
    for spec, cap in zip(seg_specs, caps):
        n_leaves, treedef, has_win = spec
        corpus = jax.tree.unflatten(
            treedef, [refs[pos + i][...] for i in range(n_leaves)])
        pos += n_leaves
        sorted_keys = refs[pos][...]
        perm = refs[pos + 1][...]
        live = refs[pos + 2][...][0]
        eff = refs[pos + 3][...][0]
        pos += 4
        win = None
        if has_win:
            win = (refs[pos][...], refs[pos + 1][...])
            pos += 2
        m = sorted_keys.shape[1]
        ids, hit = _epi.probe_windows(sorted_keys, perm, keys, cap, live, win)
        cand, valid = _epi.dedup_windows(ids, hit, m)
        safe = jnp.where(valid, cand, 0)
        scores = _seg.hoisted_scores(metric, queries, corpus, safe)
        hi, lo = _epi.pack_candidates(metric, eff[safe], scores, valid)
        his.append(hi)
        los.append(lo)
        n_cand = n_cand + valid.sum(axis=1, dtype=jnp.int32)

    out_ids, out_scores = _epi.packed_select(
        metric, topk, jnp.concatenate(his, axis=1),
        jnp.concatenate(los, axis=1))
    ids_ref[...] = out_ids
    scores_ref[...] = out_scores
    nc_ref[...] = n_cand[:, None]


def fused_query(family, segs, mults, queries, *, metric, topk, caps,
                probes=1, block_b=None, interpret=None):
    """One fused kernel launch from a query batch to ((B, topk) effective
    ids, (B, topk) scores, (B,) candidate counts) over every segment.

    Drop-in for ``segments.segmented_query`` (the probe_backend='pallas'
    path): same arguments, bit-identical results. ``block_b`` tiles the
    query batch over the kernel grid (default: up to 256 queries per
    program); segment arrays are whole-array refs shared by every block.
    """
    e2 = family.offsets is not None
    k = family.num_codes
    values = proj_lib.project_batch(family.projection, queries)  # (B, L*K)
    b = values.shape[0]
    if block_b is None:
        block_b = min(b, 256)
    offs = family.offsets if e2 else jnp.zeros(values.shape[1:], jnp.float32)
    coord = np.concatenate([np.arange(k), np.arange(k)]) if e2 \
        else np.arange(k)
    pa, pb = probing._pair_indices(coord)
    pa = tuple(int(i) for i in np.asarray(pa))
    pb = tuple(int(i) for i in np.asarray(pb))

    q_leaves, q_treedef = jax.tree.flatten(queries)
    inputs = [_pad_axis(values.astype(jnp.float32), 0, block_b),
              offs.astype(jnp.float32)[None],               # (1, L*K)
              jnp.asarray(mults).astype(jnp.uint32)[None]]  # (1, K)
    in_specs = [
        pl.BlockSpec((block_b, values.shape[1]), lambda i: (i, 0)),
        pl.BlockSpec(inputs[1].shape, lambda i: (0, 0)),
        pl.BlockSpec(inputs[2].shape, lambda i: (0, 0)),
    ]

    def add_block_leaf(leaf):
        leaf = _pad_axis(leaf, 0, block_b)
        nd = leaf.ndim
        in_specs.append(pl.BlockSpec(
            (block_b,) + leaf.shape[1:],
            lambda i, nd=nd: (i,) + (0,) * (nd - 1)))
        inputs.append(leaf)

    def add_full(arr):
        arr = jnp.asarray(arr)
        if arr.ndim == 1:
            arr = arr[None]
        nd = arr.ndim
        in_specs.append(pl.BlockSpec(
            arr.shape, lambda i, nd=nd: (0,) * nd))
        inputs.append(arr)

    for leaf in q_leaves:
        add_block_leaf(leaf)

    seg_specs = []
    for seg_arrays in segs:
        corpus, sorted_keys, perm, live, eff, win = seg_arrays
        c_leaves, c_treedef = jax.tree.flatten(corpus)
        seg_specs.append((len(c_leaves), c_treedef, win is not None))
        for leaf in c_leaves:
            add_full(leaf)
        add_full(sorted_keys)
        add_full(perm)
        add_full(live)
        add_full(eff)
        if win is not None:
            add_full(win[0])
            add_full(win[1])

    b_pad = inputs[0].shape[0]
    grid = (b_pad // block_b,)
    kernel = functools.partial(
        _fused_query_kernel, metric=metric, topk=topk, caps=tuple(caps),
        probes=int(probes), e2=e2, w=float(family.bucket_width),
        num_tables=family.num_tables, num_codes=k, pa=pa, pb=pb,
        q_treedef=q_treedef, n_qleaves=len(q_leaves),
        seg_specs=tuple(seg_specs))
    out_ids, out_scores, out_nc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((block_b, topk), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, topk), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((b_pad, topk), jnp.int32),
                   jax.ShapeDtypeStruct((b_pad, topk), jnp.float32),
                   jax.ShapeDtypeStruct((b_pad, 1), jnp.int32)),
        interpret=_default_interpret(interpret),
    )(*inputs)
    return out_ids[:b], out_scores[:b], out_nc[:b, 0]


def fused_query_sharded(family, base, deltas, mults, queries, *, metric,
                        topk, cap, delta_caps, probes=1, block_b=None,
                        interpret=None):
    """Sharded-layout entry: ONE fused kernel launch over every (shard,
    segment) pair — each shard's base slice and delta slabs become
    independent segments of the same program, and the kernel's flat packed
    selection subsumes the S-way merge (effective ids are unique across
    shards, so the (validity, score, id) sort key is a strict total order;
    see ``segments.merge_topk``). Drop-in for
    ``segments.sharded_query_vmap`` on the pallas probe backend.

    Per-segment scoring runs unbatched inside the kernel, so results are
    bit-identical to the *per-shard* reference program (the shard_map
    body); the vmapped no-mesh fallback rounds some last bits differently
    — the same known divergence the seed's sharded parity test tolerates
    between its own two programs. The mesh shard_map dispatch of this
    kernel is the deferred TPU leg (see ROADMAP)."""
    s = jax.tree.leaves(base)[0].shape[0]
    segs, caps = [], []
    for i in range(s):
        segs.append(jax.tree.map(lambda a, i=i: a[i], base))
        caps.append(cap)
        for d, dcap in zip(deltas, delta_caps):
            segs.append(jax.tree.map(lambda a, i=i: a[i], d))
            caps.append(dcap)
    return fused_query(family, tuple(segs), mults, queries, metric=metric,
                       topk=topk, caps=tuple(caps), probes=probes,
                       block_b=block_b, interpret=interpret)
