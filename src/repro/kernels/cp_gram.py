"""Pallas TPU kernel: batch-native fused CP x CP hashing.

For a (B,)-batch of CP inputs X_z and L*K stacked CP projection tensors
P_{l,k} (equal mode dims, stacked factors) this computes, in one kernel,

    v[z, l, k] = scale * sum_{r,q}  prod_n  (X_{z,n}^T P_{(l,k),n})[r, q]

and (optionally, see kernels/epilogues.py) the discretization tail fused in
the same program — E2LSH floor-quantize, SRP sign, the uint32 radix
code-combine down to (B, L) bucket keys, or the SRP bit-pack — so the raw
projection values never round-trip through HBM. This is the build/insert/
query hash hot path of CP-E2LSH / CP-SRP (paper Definitions 10, 12):
O(B L K N d Rx Rp) FLOPs total.

TPU mapping
-----------
* Grid over (B-blocks, table-blocks): each program owns BBLK inputs and
  LBLK tables x K codes = T projection tensors.
* Per mode n the Gram X_n^T P_n is ONE (BBLK*Rx, d) x (d, T*Rp) MXU matmul
  (dot_general with d contracted, everything else free); the cross-mode
  Hadamard product accumulates in a VMEM scratch so the (BBLK, Rx, T, Rp)
  intermediates never leave the core — an XLA-naive lowering writes N Gram
  tensors to HBM.
* The epilogue (discretize / combine / pack) runs on the VPU on the final
  (BBLK, T) block before the single output store.
* ops.py pads d to a multiple of 8 (zero rows are exact: they add 0 to the
  Gram) and B to the B-block (zero inputs, outputs sliced off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogues import apply_epilogue, out_struct


def _cp_hash_kernel(x_ref, p_ref, b_ref, m_ref, o_ref, acc_ref, *,
                    n_modes: int, epilogue: str, w: float, scale: float):
    # x_ref: (BBLK, N, d, Rx); p_ref: (N, LBLK, K, d, Rp)
    # b_ref: (LBLK, K) f32; m_ref: (1, K) u32
    # acc_ref: VMEM scratch (BBLK, Rx, LBLK*K, Rp)
    _, lb, k, d, rp = p_ref.shape
    for m in range(n_modes):  # static unroll over modes
        x_m = x_ref[:, m]                   # (BBLK, d, Rx)
        p_m = p_ref[m].reshape(lb * k, d, rp)
        # Gram: contract d -> (BBLK, Rx, T, Rp), one batched MXU matmul
        g = jax.lax.dot_general(
            x_m, p_m,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if m == 0:
            acc_ref[...] = g
        else:
            acc_ref[...] = acc_ref[...] * g
    v = scale * jnp.sum(acc_ref[...], axis=(1, 3))        # (BBLK, T)
    v = v.reshape(v.shape[0], lb, k)
    o_ref[...] = apply_epilogue(v, b_ref[...], m_ref[...],
                                epilogue=epilogue, w=w)


@functools.partial(jax.jit, static_argnames=("epilogue", "w", "scale",
                                             "block_b", "block_l", "interpret"))
def cp_gram_pallas(x_factors: jax.Array, p_factors: jax.Array,
                   offsets: jax.Array | None = None,
                   mults: jax.Array | None = None, *,
                   epilogue: str = "raw", w: float = 1.0, scale: float = 1.0,
                   block_b: int = 8, block_l: int = 1,
                   interpret: bool = True) -> jax.Array:
    """x_factors (B, N, d, Rx), p_factors (N, L, K, d, Rp) ->
    (B, L, K) values/codes, (B, L) keys or (B, L, K/32) packed words,
    per ``epilogue`` (see kernels/epilogues.py).

    Requires B % block_b == 0 and L % block_l == 0 (ops.py pads; padded
    inputs are zeros, whose outputs are sliced off). ``offsets`` (L, K) and
    ``mults`` (1, K) default to zeros when the epilogue ignores them.
    """
    b, n, d, rx = x_factors.shape
    _, l, k, _, rp = p_factors.shape
    assert b % block_b == 0, (b, block_b)
    assert l % block_l == 0, (l, block_l)
    if offsets is None:
        offsets = jnp.zeros((l, k), jnp.float32)
    if mults is None:
        mults = jnp.zeros((1, k), jnp.uint32)
    out = out_struct(b, l, k, epilogue)
    if out.ndim == 3:
        out_spec = pl.BlockSpec((block_b, block_l, out.shape[-1]),
                                lambda i, j: (i, j, 0))
    else:  # (B, L) bucket keys
        out_spec = pl.BlockSpec((block_b, block_l), lambda i, j: (i, j))
    grid = (b // block_b, l // block_l)
    kernel = functools.partial(_cp_hash_kernel, n_modes=n, epilogue=epilogue,
                               w=w, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, d, rx), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((n, block_l, k, d, rp), lambda i, j: (0, j, 0, 0, 0)),
            pl.BlockSpec((block_l, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),
        ],
        out_specs=out_spec,
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((block_b, rx, block_l * k, rp),
                                   jnp.float32)],
        interpret=interpret,
    )(x_factors, p_factors, offsets, mults)
