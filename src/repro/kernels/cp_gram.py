"""Pallas TPU kernel: fused batched CP x CP inner products.

Computes, for K stacked CP projection tensors P_k and one CP input X
(equal mode dims, stacked factors):

    out[k] = sum_{r,q}  prod_n  (X_n^T P_{n,k})[r, q]

This is the compute hot-spot of CP-E2LSH / CP-SRP (paper Definitions 10, 12):
N Gram matmuls per hash, O(K N d Rx Rp) FLOPs total.

TPU mapping
-----------
* Grid over K-blocks; each program owns KBLK projection tensors.
* The input factor stack (N, d, Rx) is small (O(N d R)) and is broadcast
  into VMEM once (index_map pins it to block 0 for every program).
* Per mode n the Gram X_n^T P_{n,k} is a (d, Rx)^T x (d, Rp) MXU matmul,
  batched over KBLK; the cross-mode Hadamard product is accumulated in a
  VMEM scratch so the (KBLK, Rx, Rp) intermediates never round-trip to HBM —
  the fusion is the point of the kernel (an XLA-naive lowering writes N
  Gram tensors to HBM).
* ops.py pads d to a multiple of 8 (zero rows are exact: they add 0 to the
  Gram) and Rx/Rp to multiples of 128 only when they exceed MXU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cp_gram_kernel(x_ref, p_ref, o_ref, acc_ref, *, n_modes: int):
    # x_ref: (N, d, Rx); p_ref: (N, KBLK, d, Rp); o_ref: (KBLK,)
    # acc_ref: VMEM scratch (KBLK, Rx, Rp)
    for m in range(n_modes):  # static unroll over modes
        x_m = x_ref[m]                      # (d, Rx)
        p_m = p_ref[m]                      # (KBLK, d, Rp)
        # Gram: contract d -> (KBLK, Rx, Rp), batched MXU matmul
        g = jax.lax.dot_general(
            p_m, x_m,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                   # (KBLK, Rp, Rx)
        g = jnp.swapaxes(g, 1, 2)           # (KBLK, Rx, Rp)
        if m == 0:
            acc_ref[...] = g
        else:
            acc_ref[...] = acc_ref[...] * g
    o_ref[...] = jnp.sum(acc_ref[...], axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def cp_gram_pallas(x_factors: jax.Array, p_factors: jax.Array,
                   block_k: int = 8, interpret: bool = True) -> jax.Array:
    """x_factors (N, d, Rx), p_factors (N, K, d, Rp) -> (K,) float32.

    Requires K % block_k == 0 (ops.py pads; padded projections are zeros,
    whose Grams are zero, so padded outputs are zero and are sliced off).
    """
    n, d, rx = x_factors.shape
    _, k, _, rp = p_factors.shape
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k,)
    kernel = functools.partial(_cp_gram_kernel, n_modes=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d, rx), lambda i: (0, 0, 0)),           # broadcast X
            pl.BlockSpec((n, block_k, d, rp), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_k, rx, rp), jnp.float32)],
        interpret=interpret,
    )(x_factors, p_factors)
