"""Pure-jnp oracles for every Pallas kernel in this package.

Shapes use the *batched stacked-equal-mode* layout the kernels operate on
(leading batch axis B, all mode dimensions equal, boundary TT ranks
zero-padded to R):

  cp_inner_ref : x_factors (B, N, d, Rx), p_factors (N, K, d, Rp) -> (B, K)
  tt_inner_ref : x_cores (B, N, Rx, d, Rx), p_cores (N, K, Rp, d, Rp)
                 -> (B, K)  (mode 0 cores live in row 0; chain from e_00)
  combine_ref  : codes (B, L, K) int, mults (K,) uint32 -> (B, L) uint32
  srp_pack_ref : values (B, K) -> uint32 (B, ceil(K/32))
  e2lsh_quant_ref : values (B, K), offsets (K,), w -> int32 (B, K)

The fused-epilogue kernels compose these: e.g. the "e2lsh-keys" output of
``cp_gram_pallas`` equals
``combine_ref(e2lsh_quant_ref(scale * cp_inner_ref(...), offs, w).reshape(
B, L, K), mults)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cp_inner_ref(x_factors: jax.Array, p_factors: jax.Array) -> jax.Array:
    """Batched <P_k, X_z> for CP x CP (no scales): prod-of-Grams reduction."""
    n = x_factors.shape[1]
    h = None
    for m in range(n):
        g = jnp.einsum("zdr,kdq->zkrq", x_factors[:, m], p_factors[m])
        h = g if h is None else h * g
    return jnp.sum(h, axis=(2, 3))


def tt_inner_ref(x_cores: jax.Array, p_cores: jax.Array) -> jax.Array:
    """Batched <T_k, X_z> for TT x TT with zero-padded boundary ranks.

    State S_{z,k} in R^{Rx x Rp}, S0 = e_00 (only [0, 0] = 1); per mode:
    S' = sum_i Gx[:, i, :]^T S Gp[:, i, :].
    """
    b, n, rx = x_cores.shape[0], x_cores.shape[1], x_cores.shape[2]
    k, rp = p_cores.shape[1], p_cores.shape[2]
    s = jnp.zeros((b, k, rx, rp), x_cores.dtype).at[:, :, 0, 0].set(1.0)
    for m in range(n):
        s = jnp.einsum("zkab,zaic,kbie->zkce", s, x_cores[:, m], p_cores[m])
    return s[:, :, 0, 0]


def combine_ref(codes: jax.Array, mults: jax.Array) -> jax.Array:
    """(..., L, K) int codes -> (..., L) uint32 radix bucket keys."""
    prods = codes.astype(jnp.uint32) * jnp.asarray(mults).astype(jnp.uint32)
    return prods.sum(axis=-1, dtype=jnp.uint32)


def srp_pack_ref(values: jax.Array) -> jax.Array:
    """sign-bit (v > 0) packed little-endian into uint32 words."""
    bits = (values > 0).astype(jnp.uint32)
    kdim = bits.shape[-1]
    pad = (-kdim) % 32
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (-1, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


def e2lsh_quant_ref(values: jax.Array, offsets: jax.Array, w: float) -> jax.Array:
    """floor((v + b) / w) -> int32 (paper Eq. 4.1)."""
    return jnp.floor((values + offsets) / w).astype(jnp.int32)
