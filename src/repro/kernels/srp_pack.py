"""Pallas TPU kernel: SRP discretization — sign bits packed into uint32 words.

h(X) = sign(<P, X>) (paper Definitions 12-13). Given a (B, K) block of raw
projection values this kernel emits (B, K/32) packed signatures: bit j of
word w is 1 iff values[b, 32w + j] > 0 (little-endian within the word).

A pure VPU kernel: compare, shift, lane-reduce. Fused at the tail of the
projection matmuls so the (B, K) float values never reach HBM — only the
32x smaller signatures do. Grid over B-blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _srp_pack_kernel(v_ref, o_ref):
    # v_ref: (BBLK, K); o_ref: (BBLK, K // 32)
    v = v_ref[...]
    bblk, k = v.shape
    bits = (v > 0).astype(jnp.uint32)
    words = bits.reshape(bblk, k // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    o_ref[...] = jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def srp_pack_pallas(values: jax.Array, block_b: int = 8,
                    interpret: bool = True) -> jax.Array:
    """values (B, K) with K % 32 == 0, B % block_b == 0 -> uint32 (B, K/32).

    ops.py pads K to a multiple of 32 with -1.0 (sign bit 0) and B to a
    multiple of block_b, then slices the padding back off.
    """
    b, k = values.shape
    assert k % 32 == 0 and b % block_b == 0, (b, k)
    grid = (b // block_b,)
    return pl.pallas_call(
        _srp_pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, k // 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k // 32), jnp.uint32),
        interpret=interpret,
    )(values)
