"""Pallas TPU kernel: batched TT x TT inner products (transfer-matrix chain).

For K stacked TT projection tensors T_k and one TT input X, computes

    out[k] = e_0^T ( prod_n  sum_i  Gx^(n)[:,i,:] (x) Gp_k^(n)[:,i,:] ) e_0

via the standard chain: state S in R^{Rx x Rp}, S <- sum_i Gx[:,i,:]^T S
Gp[:,i,:] per mode — the hot loop of TT-E2LSH / TT-SRP (Definitions 11, 13),
O(K N d max{Rx,Rp}^3) FLOPs.

TPU mapping
-----------
* Boundary cores are zero-padded to rank R by ops.py and the chain starts
  from S0 = e_00, so every mode is a uniform (R, d, R) block — one BlockSpec,
  no boundary specialization inside the kernel.
* The running state S_k lives in a VMEM scratch across the whole mode loop;
  per mode the update is two MXU matmuls:
      tmp(b, i c) = S^T(b,a) @ Gx(a, i c)        # (Rx,Rx) x (Rx, d*Rx)
      S'(c, e)    = tmp^T(c, b i) @ Gp(b i, e)   # reshape + (Rx, d*Rp) matmul
  batched over the K-block. Nothing but the final (KBLK,) scalars leaves VMEM.
* Mode loop is a static unroll (N is small); K-blocks form the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tt_inner_kernel(x_ref, p_ref, o_ref, s_ref, *, n_modes: int):
    # x_ref: (N, Rx, d, Rx); p_ref: (N, KBLK, Rp, d, Rp); o_ref: (KBLK,)
    # s_ref: VMEM scratch (KBLK, Rx, Rp)
    kblk, rx, rp = s_ref.shape
    s0 = jnp.zeros((kblk, rx, rp), jnp.float32).at[:, 0, 0].set(1.0)
    s_ref[...] = s0
    for m in range(n_modes):  # static unroll
        gx = x_ref[m]                        # (Rx, d, Rx)
        gp = p_ref[m]                        # (KBLK, Rp, d, Rp)
        d = gx.shape[1]
        s = s_ref[...]                       # (KBLK, Rx, Rp)
        # tmp[k, b, i, c] = sum_a s[k, a, b] * gx[a, i, c]
        gx2 = gx.reshape(rx, d * rx)         # (a, i*c)
        tmp = jax.lax.dot_general(
            jnp.swapaxes(s, 1, 2),           # (KBLK, b=Rp, a=Rx)
            gx2,
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                    # (KBLK, Rp, d*Rx)
        tmp = tmp.reshape(kblk, rp, d, rx)
        # s'[k, c, e] = sum_{b, i} tmp[k, b, i, c] * gp[k, b, i, e]
        tmp2 = tmp.reshape(kblk, rp * d, rx)
        gp2 = gp.reshape(kblk, rp * d, rp)
        s_ref[...] = jax.lax.dot_general(
            tmp2, gp2,
            dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                    # (KBLK, Rx, Rp)
    o_ref[...] = s_ref[:, 0, 0]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tt_inner_pallas(x_cores: jax.Array, p_cores: jax.Array,
                    block_k: int = 8, interpret: bool = True) -> jax.Array:
    """x_cores (N, Rx, d, Rx), p_cores (N, K, Rp, d, Rp) -> (K,) float32.

    Mode-0 cores must be zero-padded into row 0 (ops.py does this); padded
    K entries are all-zero cores giving exactly 0 output.
    """
    n, rx, d, _ = x_cores.shape
    _, k, rp, _, _ = p_cores.shape
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k,)
    kernel = functools.partial(_tt_inner_kernel, n_modes=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, rx, d, rx), lambda i: (0, 0, 0, 0)),     # broadcast X
            pl.BlockSpec((n, block_k, rp, d, rp), lambda i: (0, i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_k, rx, rp), jnp.float32)],
        interpret=interpret,
    )(x_cores, p_cores)
