"""Pallas TPU kernel: batch-native fused TT x TT hashing (transfer-matrix
chain).

For a (B,)-batch of TT inputs X_z and L*K stacked TT projection tensors
T_{l,k}, computes in one kernel

    v[z, l, k] = scale * e_0^T ( prod_n sum_i Gx_z^(n)[:,i,:] (x)
                                 Gp_{l,k}^(n)[:,i,:] ) e_0

via the standard chain: state S in R^{Rx x Rp} per (input, hash) pair,
S <- sum_i Gx[:,i,:]^T S Gp[:,i,:] per mode — the hash hot loop of
TT-E2LSH / TT-SRP (Definitions 11, 13), O(B L K N d max{Rx,Rp}^3) FLOPs —
plus the fused discretization epilogue (floor-quantize / sign / uint32
radix combine / bit-pack, see kernels/epilogues.py) so raw projections
never round-trip through HBM.

TPU mapping
-----------
* Boundary cores are zero-padded to rank R by ops.py and the chain starts
  from S0 = e_00, so every mode is a uniform (R, d, R) block — one
  BlockSpec, no boundary specialization inside the kernel.
* The running states S_{z,t} live in one VMEM scratch (BBLK, T, Rx, Rp)
  across the whole mode loop; per mode the update is two MXU matmuls:
      tmp(z,t; b, i c) = S^T(z,t; b,a) @ Gx_z(a, i c)
      S'(t; z,c, e)    = tmp(z,t; (b i), c)^T @ Gp_t((b i), e)
  batched over the (B-block, table-block) pair. Nothing but the final
  (BBLK, T) values sees the epilogue; only its output leaves VMEM.
* Mode loop is a static unroll (N is small); (B-blocks, table-blocks) form
  the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogues import apply_epilogue, out_struct


def _tt_hash_kernel(x_ref, p_ref, b_ref, m_ref, o_ref, s_ref, *,
                    n_modes: int, epilogue: str, w: float, scale: float):
    # x_ref: (BBLK, N, Rx, d, Rx); p_ref: (N, LBLK, K, Rp, d, Rp)
    # b_ref: (LBLK, K) f32; m_ref: (1, K) u32
    # s_ref: VMEM scratch (BBLK, T, Rx, Rp), T = LBLK*K
    bb, t, rx, rp = s_ref.shape
    _, lb, k, _, d, _ = p_ref.shape
    s_ref[...] = jnp.zeros((bb, t, rx, rp), jnp.float32).at[:, :, 0, 0].set(1.0)
    for m in range(n_modes):  # static unroll
        gx = x_ref[:, m].reshape(bb, rx, d * rx)          # (z; a, i*c)
        gp = p_ref[m].reshape(t, rp * d, rp)              # (t; b*i, e)
        s = s_ref[...]                                    # (z, t, a, b)
        # tmp[z, t, b, i*c] = sum_a s[z, t, a, b] * gx[z, a, i*c]
        tmp = jax.lax.dot_general(
            jnp.swapaxes(s, 2, 3),                        # (z, t, b, a)
            gx,
            dimension_numbers=(((3,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                 # (z, t, b, i*c)
        tmp = tmp.reshape(bb, t, rp * d, rx)              # (z, t, b*i, c)
        # s'[z, t, c, e] = sum_{b,i} tmp[z, t, (b i), c] * gp[t, (b i), e]
        s_new = jax.lax.dot_general(
            tmp, gp,
            dimension_numbers=(((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        )                                                 # (t, z, c, e)
        s_ref[...] = jnp.swapaxes(s_new, 0, 1)
    v = scale * s_ref[:, :, 0, 0]                         # (BBLK, T)
    v = v.reshape(bb, lb, k)
    o_ref[...] = apply_epilogue(v, b_ref[...], m_ref[...],
                                epilogue=epilogue, w=w)


@functools.partial(jax.jit, static_argnames=("epilogue", "w", "scale",
                                             "block_b", "block_l", "interpret"))
def tt_inner_pallas(x_cores: jax.Array, p_cores: jax.Array,
                    offsets: jax.Array | None = None,
                    mults: jax.Array | None = None, *,
                    epilogue: str = "raw", w: float = 1.0, scale: float = 1.0,
                    block_b: int = 8, block_l: int = 1,
                    interpret: bool = True) -> jax.Array:
    """x_cores (B, N, Rx, d, Rx), p_cores (N, L, K, Rp, d, Rp) ->
    (B, L, K) values/codes, (B, L) keys or (B, L, K/32) packed words, per
    ``epilogue`` (see kernels/epilogues.py).

    Mode-0 cores must be zero-padded into row 0 (ops.py does this); padded
    B entries are all-zero cores giving exactly 0 raw values, and their
    outputs are sliced off. Requires B % block_b == 0, L % block_l == 0.
    """
    b, n, rx, d, _ = x_cores.shape
    _, l, k, rp, _, _ = p_cores.shape
    assert b % block_b == 0, (b, block_b)
    assert l % block_l == 0, (l, block_l)
    if offsets is None:
        offsets = jnp.zeros((l, k), jnp.float32)
    if mults is None:
        mults = jnp.zeros((1, k), jnp.uint32)
    out = out_struct(b, l, k, epilogue)
    if out.ndim == 3:
        out_spec = pl.BlockSpec((block_b, block_l, out.shape[-1]),
                                lambda i, j: (i, j, 0))
    else:
        out_spec = pl.BlockSpec((block_b, block_l), lambda i, j: (i, j))
    grid = (b // block_b, l // block_l)
    kernel = functools.partial(_tt_hash_kernel, n_modes=n, epilogue=epilogue,
                               w=w, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n, rx, d, rx), lambda i, j: (i, 0, 0, 0, 0)),
            pl.BlockSpec((n, block_l, k, rp, d, rp),
                         lambda i, j: (0, j, 0, 0, 0, 0)),
            pl.BlockSpec((block_l, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),
        ],
        out_specs=out_spec,
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((block_b, block_l * k, rx, rp),
                                   jnp.float32)],
        interpret=interpret,
    )(x_cores, p_cores, offsets, mults)
