"""Shared in-kernel epilogues for the fused hashing kernels.

Both projection kernels (cp_gram, tt_inner) end with the same (BBLK, LBLK*K)
block of scaled raw <P, X> values sitting in registers/VMEM; these helpers
turn it into the final output *inside the kernel* so the float values never
reach HBM:

  "raw"        (BBLK, LBLK, K) float32   the values themselves
  "e2lsh"      (BBLK, LBLK, K) int32     floor((v + b) / w)   (Defs 10-11)
  "srp"        (BBLK, LBLK, K) int32     1 iff v > 0          (Defs 12-13)
  "e2lsh-keys" (BBLK, LBLK)    uint32    radix combine of the e2lsh codes
  "srp-keys"   (BBLK, LBLK)    uint32    radix combine of the srp codes
  "srp-packed" (BBLK, LBLK, K/32) uint32 sign bits packed little-endian

The radix combine is sum_k codes[k] * mults[k] in uint32 arithmetic —
exactly ``repro.core.lsh._combine_codes`` (int32 -> uint32 casts wrap mod
2^32). The E2LSH quantize uses the same ``(v + b) / w`` division as
``lsh.e2lsh_discretize`` so codes stay bit-comparable with the XLA path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPILOGUES = ("raw", "e2lsh", "srp", "e2lsh-keys", "srp-keys", "srp-packed")


def out_struct(b: int, l: int, k: int, epilogue: str) -> jax.ShapeDtypeStruct:
    """Full-array output shape/dtype of a fused hash kernel."""
    if epilogue == "raw":
        return jax.ShapeDtypeStruct((b, l, k), jnp.float32)
    if epilogue in ("e2lsh", "srp"):
        return jax.ShapeDtypeStruct((b, l, k), jnp.int32)
    if epilogue in ("e2lsh-keys", "srp-keys"):
        return jax.ShapeDtypeStruct((b, l), jnp.uint32)
    if epilogue == "srp-packed":
        assert k % 32 == 0, k
        return jax.ShapeDtypeStruct((b, l, k // 32), jnp.uint32)
    raise ValueError(f"epilogue must be one of {EPILOGUES}, got {epilogue!r}")


def apply_epilogue(v: jax.Array, offs: jax.Array, mults: jax.Array, *,
                   epilogue: str, w: float) -> jax.Array:
    """(BBLK, LBLK, K) scaled raw values -> the kernel's output block.

    offs: (LBLK, K) float32 E2LSH offsets (ignored by srp/raw);
    mults: (1, K) uint32 radix multipliers (ignored unless *-keys).
    """
    if epilogue == "raw":
        return v
    if epilogue.startswith("e2lsh"):
        codes = jnp.floor((v + offs[None]) / w).astype(jnp.int32)
    else:
        codes = (v > 0).astype(jnp.int32)
    if epilogue in ("e2lsh", "srp"):
        return codes
    if epilogue.endswith("keys"):
        return jnp.sum(codes.astype(jnp.uint32) * mults[0][None, None, :],
                       axis=-1, dtype=jnp.uint32)
    # srp-packed: K % 32 == 0 (ops.py pads with zero projections -> bit 0)
    bb, lb, k = codes.shape
    words = codes.astype(jnp.uint32).reshape(bb, lb, k // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)
