"""Shared in-kernel epilogues for the fused hashing and query kernels.

Both projection kernels (cp_gram, tt_inner) end with the same (BBLK, LBLK*K)
block of scaled raw <P, X> values sitting in registers/VMEM; these helpers
turn it into the final output *inside the kernel* so the float values never
reach HBM:

  "raw"        (BBLK, LBLK, K) float32   the values themselves
  "e2lsh"      (BBLK, LBLK, K) int32     floor((v + b) / w)   (Defs 10-11)
  "srp"        (BBLK, LBLK, K) int32     1 iff v > 0          (Defs 12-13)
  "e2lsh-keys" (BBLK, LBLK)    uint32    radix combine of the e2lsh codes
  "srp-keys"   (BBLK, LBLK)    uint32    radix combine of the srp codes
  "srp-packed" (BBLK, LBLK, K/32) uint32 sign bits packed little-endian

The radix combine is sum_k codes[k] * mults[k] in uint32 arithmetic —
exactly ``repro.core.lsh._combine_codes`` (int32 -> uint32 casts wrap mod
2^32). The E2LSH quantize uses the same ``(v + b) / w`` division as
``lsh.e2lsh_discretize`` so codes stay bit-comparable with the XLA path.

The second half of this module is the *probe epilogue* — the stages that
take a block of hashed bucket keys the rest of the way to (id, score)
candidate pairs: binary search over per-segment sorted keys, the bounded
cap-wide gather with bucket-boundary / duplicate / tombstone masking, and
the packed top-k selection. They are written as plain jnp array functions
on purpose: ``repro.core.segments`` calls them on full (B, ...) arrays
(the restructured XLA query schedule) and ``repro.kernels.fused_query``
calls the very same functions on (BBLK, ...) blocks inside a Pallas kernel
body — one implementation, so the two probe backends are bit-identical by
construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

EPILOGUES = ("raw", "e2lsh", "srp", "e2lsh-keys", "srp-keys", "srp-packed")

# Packed-selection sentinels: an invalid candidate slot carries the largest
# uint32 order key (sorts after every real score — the only colliding real
# key would be a NaN with all-ones payload, which IEEE arithmetic never
# produces; hardware NaNs are canonical 0x7FC00000) and the largest int32
# id payload (sorts after every real effective id on key ties).
# numpy scalars on purpose: they inline as jaxpr literals, so the Pallas
# kernel body doesn't capture device-array constants
PROBE_PAD_KEY = np.uint32(0xFFFFFFFF)
PROBE_PAD_ID = np.int32(0x7FFFFFFF)


def out_struct(b: int, l: int, k: int, epilogue: str) -> jax.ShapeDtypeStruct:
    """Full-array output shape/dtype of a fused hash kernel."""
    if epilogue == "raw":
        return jax.ShapeDtypeStruct((b, l, k), jnp.float32)
    if epilogue in ("e2lsh", "srp"):
        return jax.ShapeDtypeStruct((b, l, k), jnp.int32)
    if epilogue in ("e2lsh-keys", "srp-keys"):
        return jax.ShapeDtypeStruct((b, l), jnp.uint32)
    if epilogue == "srp-packed":
        assert k % 32 == 0, k
        return jax.ShapeDtypeStruct((b, l, k // 32), jnp.uint32)
    raise ValueError(f"epilogue must be one of {EPILOGUES}, got {epilogue!r}")


def apply_epilogue(v: jax.Array, offs: jax.Array, mults: jax.Array, *,
                   epilogue: str, w: float) -> jax.Array:
    """(BBLK, LBLK, K) scaled raw values -> the kernel's output block.

    offs: (LBLK, K) float32 E2LSH offsets (ignored by srp/raw);
    mults: (1, K) uint32 radix multipliers (ignored unless *-keys).
    """
    if epilogue == "raw":
        return v
    if epilogue.startswith("e2lsh"):
        codes = jnp.floor((v + offs[None]) / w).astype(jnp.int32)
    else:
        codes = (v > 0).astype(jnp.int32)
    if epilogue in ("e2lsh", "srp"):
        return codes
    if epilogue.endswith("keys"):
        return jnp.sum(codes.astype(jnp.uint32) * mults[0][None, None, :],
                       axis=-1, dtype=jnp.uint32)
    # srp-packed: K % 32 == 0 (ops.py pads with zero projections -> bit 0)
    bb, lb, k = codes.shape
    words = codes.astype(jnp.uint32).reshape(bb, lb, k // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    return jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Probe epilogue: bucket windows -> dedup -> packed (id, score) selection
# ---------------------------------------------------------------------------


def probe_windows(sorted_keys, perm, keys, cap, live, win=None):
    """Raw probe windows, pre-dedup -> (ids (B, W) local ids, hit (B, W)).

    ``keys`` is (L, B) single-probe or (L, T, B) multi-probe; every op
    broadcasts over the optional probe axis, which is then folded into the
    flattened window axis W = L[*T]*cap (query-major, table-major, probe-
    major, window-minor). One (query, table, probe, window-slot) cell per
    output column: the same local id recurs once per probed bucket that
    holds it; callers sort + mask the recurrences away for the top-k path
    and count them for the weighted sampling mode.

    Dense stores (``win`` is None) gather the first ``cap`` sorted
    positions after the binary-search start and keep slots still inside
    the bucket (same key) whose slot is live. ``win`` stores (explicit
    ``bucket_cap``) instead gather through the (live_rank (L, m+1),
    live_pos (L, m)) live-window lookup: the window covers the first
    ``cap`` *live* members of the bucket, and because the bucket's live
    members occupy exactly the live ranks [live_rank[start], live_rank[end])
    — ``end`` from the side='right' binary search — the window bound is one
    rank compare. No per-slot key gather + equality scan and no tombstone
    mask re-check: every position the live window yields is live and
    in-bucket by construction.
    """
    m = sorted_keys.shape[1]
    starts = jax.vmap(
        lambda sk, q: jnp.searchsorted(sk, q, side="left"))(sorted_keys, keys)
    if win is None:
        pos = starts[..., None] + jnp.arange(cap, dtype=starts.dtype)
        in_range = pos < m                                # (L[, T], B, cap)
        posc = jnp.minimum(pos, max(m - 1, 0))
        key_at = jax.vmap(lambda sk, p: sk[p])(sorted_keys, posc)
        hit = in_range & (key_at == keys[..., None])
        ids = jax.vmap(lambda pm, p: pm[p])(perm, posc)   # (L[, T], B, cap)
        hit &= live[ids]                                  # tombstones + pads
    else:
        live_rank, live_pos = win
        ends = jax.vmap(
            lambda sk, q: jnp.searchsorted(sk, q, side="right"))(sorted_keys,
                                                                 keys)
        rank0 = jax.vmap(lambda lr, st: lr[st])(live_rank, starts)
        rank_end = jax.vmap(lambda lr, en: lr[en])(live_rank, ends)
        j = rank0[..., None] + jnp.arange(cap, dtype=rank0.dtype)
        hit = j < rank_end[..., None]                     # (L[, T], B, cap)
        pos = jax.vmap(lambda lp, p: lp[p])(
            live_pos, jnp.minimum(j, max(m - 1, 0)))
        ids = jax.vmap(lambda pm, p: pm[p])(perm, pos)
    b = keys.shape[-1]
    ids = jnp.moveaxis(ids, -2, 0).reshape(b, -1)
    hit = jnp.moveaxis(hit, -2, 0).reshape(b, -1)
    return ids, hit


def dedup_windows(ids, hit, m):
    """(ids, hit) raw windows -> (cand (B, W) sorted local ids, valid).

    Sort each row's hits ascending (misses carry the ``m`` sentinel, so
    they sink to the tail) and mask duplicates, so each local id appears at
    most once — including across the T probed buckets of one table, whose
    windows overlap whenever probes collide. ``cand`` keeps the sentinel on
    invalid slots; callers clamp before gathering.
    """
    b = ids.shape[0]
    cand = jnp.sort(jnp.where(hit, ids, m), axis=1)       # invalid (>=m) last
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), cand[:, 1:] == cand[:, :-1]], axis=1)
    valid = (cand < m) & ~dup
    return cand, valid


def order_key_bits(metric, scores):
    """f32 scores -> uint32 keys whose unsigned order is the metric's rank
    order (ascending distance / descending similarity), matching XLA's f32
    total order bit for bit: flip all bits of negatives, set the sign bit
    of non-negatives. Bijective, so the score is recoverable exactly."""
    order = scores if metric == "euclidean" else -scores
    bits = order.view(jnp.uint32)
    return jnp.where(bits >> 31 != 0, ~bits, bits | jnp.uint32(0x80000000))


def decode_order_key(metric, key32):
    """Inverse of ``order_key_bits`` (exact, including the cosine
    negation — a sign-bit flip is an involution on every f32 pattern)."""
    bits = jnp.where(key32 >> 31 != 0, key32 & jnp.uint32(0x7FFFFFFF), ~key32)
    order = bits.view(jnp.float32)
    return order if metric == "euclidean" else -order


def pack_candidates(metric, eid, scores, valid):
    """One segment's scored candidates -> (hi (B, W) uint32, lo (B, W)
    int32) packed selection operands: hi is the order key (pad-key on
    invalid slots), lo the effective id (pad-id on invalid slots)."""
    key32 = order_key_bits(metric, scores)
    hi = jnp.where(valid, key32, PROBE_PAD_KEY)
    lo = jnp.where(valid, eid.astype(jnp.int32), PROBE_PAD_ID)
    return hi, lo


def packed_select(metric, topk, hi, lo):
    """Packed top-k: one two-operand two-key sort on (order key, effective
    id) -> (ids (B, topk) with -1 fill, scores (B, topk) with bad fill).

    Equivalent to ``segments.select_topk`` on the same candidates, key for
    key: validity is folded into the order key (invalid slots carry the
    pad key, after every real score in XLA's f32 total order), and the
    effective id is the explicit tie-break — so the selection is
    independent of candidate position, which is what makes one flat sort
    over every segment's concatenated candidates bit-identical to the
    per-segment top-k + merge tree it replaces. ``is_stable=False`` is
    safe: effective ids are unique across a store's segments, so the key
    pair is already a strict total order on valid slots.
    """
    shi, slo = jax.lax.sort((hi, lo), dimension=1, is_stable=False,
                            num_keys=2)
    k = min(topk, hi.shape[1])
    shi, slo = shi[:, :k], slo[:, :k]
    sv = shi != PROBE_PAD_KEY
    bad = jnp.float32(jnp.inf if metric == "euclidean" else -jnp.inf)
    ids = jnp.where(sv, slo, -1)
    scores = jnp.where(sv, decode_order_key(metric, shi), bad)
    if k < topk:
        ids = jnp.pad(ids, ((0, 0), (0, topk - k)), constant_values=-1)
        scores = jnp.pad(scores, ((0, 0), (0, topk - k)),
                         constant_values=bad)
    return ids, scores
