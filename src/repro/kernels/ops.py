"""Public jit'd wrappers for the Pallas kernels.

These adapt the core-library formats (CPTensor/TTTensor/CPProjection/
TTProjection with per-mode tuples) to the stacked, padded, MXU-aligned
layouts the kernels want, and slice the padding back off:

  * mode dims padded to a multiple of 8 with zero rows (Grams unchanged);
  * K padded to the K-block with zero projections (outputs sliced off);
  * TT boundary ranks zero-padded to R, chain started from e_00;
  * SRP K padded to a multiple of 32 with -1 values (sign bit 0).

On this CPU container kernels always run with interpret=True (the TPU
lowering is the target; pass interpret=False on real hardware).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projections import CPProjection, TTProjection
from repro.core.tensor_formats import CPTensor, TTTensor
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.e2lsh_quant import e2lsh_quant_pallas
from repro.kernels.srp_pack import srp_pack_pallas
from repro.kernels.tt_inner import tt_inner_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _default_interpret(interpret):
    return (not on_tpu()) if interpret is None else interpret


def _pad_axis(a: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _check_equal_dims(dims):
    if len(set(dims)) != 1:
        raise ValueError(
            f"kernel path needs equal mode dims, got {dims}; use the "
            "repro.core.projections path for ragged modes")


# ---------------------------------------------------------------------------
# CP x CP inner products
# ---------------------------------------------------------------------------


def cp_inner_products(x: CPTensor, p: CPProjection, block_k: int = 8,
                      interpret: bool | None = None) -> jax.Array:
    """(K,) raw <P_k, X> values (scales applied) via the fused Gram kernel."""
    _check_equal_dims(x.dims)
    _check_equal_dims(p.dims)
    xf = jnp.stack([f.astype(jnp.float32) for f in x.factors])   # (N, d, Rx)
    pf = jnp.stack([f.astype(jnp.float32) for f in p.factors], 0)  # (N, K, d, Rp)
    xf = _pad_axis(xf, 1, 8)
    pf = _pad_axis(pf, 2, 8)
    k = pf.shape[1]
    pf = _pad_axis(pf, 1, block_k)
    out = cp_gram_pallas(xf, pf, block_k=block_k,
                         interpret=_default_interpret(interpret))
    return (x.scale * p.scale) * out[:k]


# ---------------------------------------------------------------------------
# TT x TT inner products
# ---------------------------------------------------------------------------


def _stack_tt_cores(cores, rank: int) -> jax.Array:
    """Zero-pad boundary cores to (rank, d, rank) and stack -> (N, R, d, R)."""
    out = []
    for c in cores:
        c = c.astype(jnp.float32)
        c = _pad_axis(_pad_axis(c, 0, rank) if c.shape[0] < rank else c,
                      2, rank) if (c.shape[0] < rank or c.shape[2] < rank) else c
        # _pad_axis pads to a multiple; boundary ranks are 1 so this yields rank
        out.append(c)
    return jnp.stack(out)


def tt_inner_products(x: TTTensor, p: TTProjection, block_k: int = 8,
                      interpret: bool | None = None) -> jax.Array:
    """(K,) raw <T_k, X> values (scales applied) via the chain kernel."""
    _check_equal_dims(x.dims)
    _check_equal_dims(p.dims)
    rx = max(max(c.shape[0], c.shape[2]) for c in x.cores)
    rp = max(max(c.shape[1], c.shape[3]) for c in p.cores)
    xc = _stack_tt_cores(x.cores, rx)                     # (N, Rx, d, Rx)
    pc = []
    for c in p.cores:  # (K, r, d, r)
        c = c.astype(jnp.float32)
        if c.shape[1] < rp:
            c = _pad_axis(c, 1, rp)
        if c.shape[3] < rp:
            c = _pad_axis(c, 3, rp)
        pc.append(c)
    pc = jnp.stack(pc)                                    # (N, K, Rp, d, Rp)
    xc = _pad_axis(xc, 2, 8)
    pc = _pad_axis(pc, 3, 8)
    k = pc.shape[1]
    pc = _pad_axis(pc, 1, block_k)
    out = tt_inner_pallas(xc, pc, block_k=block_k,
                          interpret=_default_interpret(interpret))
    return (x.scale * p.scale) * out[:k]


# ---------------------------------------------------------------------------
# Discretization tails
# ---------------------------------------------------------------------------


def srp_pack(values: jax.Array, block_b: int = 8,
             interpret: bool | None = None) -> jax.Array:
    """(B, K) raw values -> (B, ceil(K/32)) packed uint32 signatures."""
    b, k = values.shape
    v = _pad_axis(values.astype(jnp.float32), 1, 32, value=-1.0)
    v = _pad_axis(v, 0, block_b, value=-1.0)
    out = srp_pack_pallas(v, block_b=block_b,
                          interpret=_default_interpret(interpret))
    return out[:b]


def e2lsh_quantize(values: jax.Array, offsets: jax.Array, w: float,
                   block_b: int = 8, interpret: bool | None = None) -> jax.Array:
    """(B, K) values + (K,) offsets -> int32 (B, K) hashcodes."""
    b, k = values.shape
    v = _pad_axis(values.astype(jnp.float32), 0, block_b)
    out = e2lsh_quant_pallas(v, offsets.astype(jnp.float32), float(w),
                             block_b=block_b,
                             interpret=_default_interpret(interpret))
    return out[:b]
