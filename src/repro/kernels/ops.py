"""Public jit'd wrappers for the Pallas kernels.

These adapt the core-library formats (CPTensor/TTTensor/CPProjection/
TTProjection with per-mode tuples) to the stacked, padded, MXU-aligned
layouts the kernels want, and slice the padding back off:

  * mode dims padded to a multiple of 8 with zero rows (Grams unchanged);
  * the batch axis padded to the B-block with zero inputs (outputs sliced);
  * TT boundary ranks zero-padded to R, chain started from e_00;
  * SRP K padded to a multiple of 32 with zero projections (sign bit 0)
    for the packed epilogue; E2LSH quantize pads K to the lane width.

``fused_hash`` is the batch-native entry the LSH families dispatch to when
``hash_backend`` resolves to pallas: one kernel launch takes a (B, ...)
batch of CP/TT inputs straight to integer codes, combined uint32 bucket
keys, or packed SRP signatures (see kernels/epilogues.py) — the raw
projection values never round-trip through HBM.

On this CPU container kernels always run with interpret=True (the TPU
lowering is the target; pass interpret=False on real hardware).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projections import CPProjection, TTProjection
from repro.core.tensor_formats import CPTensor, TTTensor
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.e2lsh_quant import e2lsh_quant_pallas
from repro.kernels.srp_pack import srp_pack_pallas
from repro.kernels.tt_inner import tt_inner_pallas

_LANES = 128  # TPU VPU lane width (f32 tile is (8, 128))


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _default_interpret(interpret):
    return (not on_tpu()) if interpret is None else interpret


def _pad_axis(a: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _check_equal_dims(dims):
    if len(set(dims)) != 1:
        raise ValueError(
            f"kernel path needs equal mode dims, got {dims}; use the "
            "repro.core.projections path for ragged modes")


def _pick_block_l(l: int, cap: int = 8) -> int:
    """Largest power-of-two table-block (<= cap) dividing L."""
    return max(c for c in (64, 32, 16, 8, 4, 2, 1) if c <= cap and l % c == 0)


# Per-format-pair fused-hash block defaults: (block_b cap, block_t cap),
# clamped to the batch size / the largest power-of-two divisor of L.
# Measured by the ``make bench-kernels`` sweep (benchmarks/kernels.py,
# interpret mode on this CPU container, B=256 L=8 K=4 R=2 d=8; median of
# 5, noise ~10%):
#
#   CP x CP: grid-program count dominates — (32, 4) runs ~2.4x faster
#     than the old fixed (8, 1) tiling, with (16, 8) / (32, 8) / (64, 2)
#     all within noise of it (jit-wrapped, the same sweep reads 5-10x:
#     dispatch amortization compounds the grid shrink).  The VMEM
#     accumulator (BBLK, Rx, LBLK*K, Rp) f32 at 32*2*16*2 = 8 KiB stays
#     far under a core's VMEM; wider B-blocks are safe until
#     BBLK*Rx*L*K*Rp*4 nears ~4 MiB.
#   TT x TT: per-table work is R^3 per mode so the program body, not the
#     grid, dominates; gains come almost entirely from block_b.  (64, 8)
#     measured ~2.4x over (8, 1), with every (64, *) within ~6% of it.
#
# TPU re-measurement belongs with the deferred shard_map-vs-fused leg
# (ROADMAP); these caps only tile the grid — every (block_b, block_t)
# combination is bit-identical (pinned by tests/test_kernels.py).
_HASH_BLOCK_DEFAULTS = {"cp": (32, 4), "tt": (64, 8)}


# ---------------------------------------------------------------------------
# Format stacking (batched inputs, stacked projections)
# ---------------------------------------------------------------------------


def _stack_cp_batch(x: CPTensor) -> jax.Array:
    """Batched CP factors (each (B, d, R)) -> (B, N, d, Rx), d padded to 8."""
    xf = jnp.stack([f.astype(jnp.float32) for f in x.factors], axis=1)
    return _pad_axis(xf, 2, 8)


def _stack_cp_proj(p: CPProjection, num_tables: int) -> jax.Array:
    """Projection factors (each (L*K, d, R)) -> (N, L, K, d, Rp), d -> 8."""
    pf = jnp.stack([f.astype(jnp.float32) for f in p.factors], 0)
    pf = _pad_axis(pf, 2, 8)
    n, kt, d, rp = pf.shape
    return pf.reshape(n, num_tables, kt // num_tables, d, rp)


def _stack_tt_cores(cores, rank: int) -> jax.Array:
    """Zero-pad boundary cores to (rank, d, rank) and stack -> (N, R, d, R).

    ``_pad_axis`` pads to a multiple of ``rank``; every core rank is in
    [1, rank] (rank is the chain max), so both rank axes land exactly on
    ``rank`` — boundary cores (rank 1) and truncated interior ranks alike.
    """
    return jnp.stack([
        _pad_axis(_pad_axis(c.astype(jnp.float32), 0, rank), 2, rank)
        for c in cores])


def _stack_tt_batch(x: TTTensor, rank: int) -> jax.Array:
    """Batched TT cores (each (B, r, d, r)) -> (B, N, Rx, d, Rx), d -> 8."""
    cores = [_pad_axis(_pad_axis(c.astype(jnp.float32), 1, rank), 3, rank)
             for c in x.cores]
    return _pad_axis(jnp.stack(cores, axis=1), 3, 8)


def _stack_tt_proj(p: TTProjection, rank: int, num_tables: int) -> jax.Array:
    """Projection cores (each (L*K, r, d, r)) -> (N, L, K, Rp, d, Rp)."""
    cores = [_pad_axis(_pad_axis(c.astype(jnp.float32), 1, rank), 3, rank)
             for c in p.cores]
    pc = _pad_axis(jnp.stack(cores, axis=0), 3, 8)
    n, kt, rp, d, _ = pc.shape
    return pc.reshape(n, num_tables, kt // num_tables, rp, d, rp)


# ---------------------------------------------------------------------------
# Fused batch-native hashing (the hash_backend='pallas' entry point)
# ---------------------------------------------------------------------------


def hash_blocks(fmt: str, b: int, num_tables: int,
                block_b: int | None = None,
                block_t: int | None = None) -> tuple[int, int]:
    """Resolve the (block_b, block_t) grid tiling ``fused_hash`` runs with.

    ``fmt`` is the format pair ('cp' | 'tt'); ``None`` knobs take the
    documented per-format-pair default cap (``_HASH_BLOCK_DEFAULTS``).
    block_t is clamped to the largest power-of-two divisor of L so any
    requested cap stays a legal grid; block_b only tiles the padded batch,
    so it is used as-is (the batch axis is padded up to it).
    """
    db, dt = _HASH_BLOCK_DEFAULTS[fmt]
    block_b = db if block_b is None else int(block_b)
    if block_b < 1:
        raise ValueError(f"block_b must be >= 1, got {block_b}")
    block_t = dt if block_t is None else int(block_t)
    if block_t < 1:
        raise ValueError(f"block_t must be >= 1, got {block_t}")
    # never tile wider than the 8-aligned batch: a batch-of-1 hash must not
    # pay a 64-row zero-padded program
    block_b = min(block_b, max(8, -(-b // 8) * 8))
    return block_b, _pick_block_l(num_tables, cap=block_t)


def fused_hash(xs, p, *, epilogue: str, kind: str, num_tables: int,
               num_codes: int, offsets: jax.Array | None = None,
               w: float = 0.0, mults=None, block_b: int | None = None,
               block_t: int | None = None,
               interpret: bool | None = None) -> jax.Array:
    """One fused kernel launch from a (B, ...) batch to hash outputs.

    xs: batched CPTensor (under a CPProjection) or batched TTTensor (under
    a TTProjection), equal mode dims. epilogue:

      'codes'  -> (B, L, K) int32 hashcodes (E2LSH floor / SRP sign fused)
      'keys'   -> (B, L) uint32 bucket keys (discretize + radix combine
                  with the (K,) uint32 ``mults`` fused)
      'packed' -> (B, L, ceil(K/32)) uint32 SRP signatures (sign + pack)

    ``kind`` picks the discretizer ('*e2lsh' vs '*srp'); ``offsets``/``w``
    are the E2LSH quantizer parameters. ``block_b``/``block_t`` tile the
    kernel grid over the (padded) batch and the tables — tuning knobs only
    (see ``hash_blocks`` for the per-format-pair defaults); every setting
    is bit-identical to the XLA path of ``LSHFamily`` (pinned by
    tests/test_hash_backends.py).
    """
    e2 = kind.endswith("e2lsh")
    kernel_epilogue = {
        "codes": "e2lsh" if e2 else "srp",
        "keys": "e2lsh-keys" if e2 else "srp-keys",
        "packed": "srp-packed",
    }[epilogue]
    interpret = _default_interpret(interpret)

    b = jax.tree.leaves(xs)[0].shape[0]
    if isinstance(p, CPProjection) and isinstance(xs, CPTensor):
        block_b, block_l = hash_blocks("cp", b, num_tables, block_b, block_t)
        xf = _pad_axis(_stack_cp_batch(xs), 0, block_b)
        pf = _stack_cp_proj(p, num_tables)
        kernel = cp_gram_pallas
        k_axis = 2
    elif isinstance(p, TTProjection) and isinstance(xs, TTTensor):
        block_b, block_l = hash_blocks("tt", b, num_tables, block_b, block_t)
        rx = max(max(c.shape[1], c.shape[3]) for c in xs.cores)
        rp = max(max(c.shape[1], c.shape[3]) for c in p.cores)
        xf = _pad_axis(_stack_tt_batch(xs, rx), 0, block_b)
        pf = _stack_tt_proj(p, rp, num_tables)
        kernel = tt_inner_pallas
        k_axis = 2
    else:
        raise TypeError(
            f"fused_hash needs matching CP/TT formats, got {type(p).__name__}"
            f" projection on {type(xs).__name__} inputs")

    if epilogue == "packed":
        # zero projections give v = 0 -> sign bit 0, matching pack_bits' pad
        pf = _pad_axis(pf, k_axis, 32)

    offs = None
    if e2:
        offs = offsets.astype(jnp.float32).reshape(num_tables, num_codes)
    mults_arr = None
    if epilogue == "keys":
        mults_arr = jnp.asarray(mults).astype(jnp.uint32).reshape(1, num_codes)

    out = kernel(xf, pf, offs, mults_arr, epilogue=kernel_epilogue,
                 w=float(w) if e2 else 1.0,
                 scale=float(xs.scale * p.scale),
                 block_b=block_b, block_l=block_l,
                 interpret=interpret)
    return out[:b]


# ---------------------------------------------------------------------------
# CP x CP / TT x TT raw inner products (single input, test/benchmark API)
# ---------------------------------------------------------------------------


def cp_inner_products(x: CPTensor, p: CPProjection,
                      interpret: bool | None = None) -> jax.Array:
    """(K,) raw <P_k, X> values (scales applied) via the fused Gram kernel
    — the batch-of-1 case of the batch-native kernel."""
    _check_equal_dims(x.dims)
    _check_equal_dims(p.dims)
    xb = jax.tree.map(lambda a: a[None], x)
    xf = _pad_axis(_stack_cp_batch(xb), 0, 8)
    pf = _stack_cp_proj(p, 1)
    out = cp_gram_pallas(xf, pf, epilogue="raw",
                         interpret=_default_interpret(interpret))
    return (x.scale * p.scale) * out[0, 0]


def tt_inner_products(x: TTTensor, p: TTProjection,
                      interpret: bool | None = None) -> jax.Array:
    """(K,) raw <T_k, X> values (scales applied) via the chain kernel —
    the batch-of-1 case of the batch-native kernel."""
    _check_equal_dims(x.dims)
    _check_equal_dims(p.dims)
    rx = max(max(c.shape[0], c.shape[2]) for c in x.cores)
    rp = max(max(c.shape[1], c.shape[3]) for c in p.cores)
    xb = jax.tree.map(lambda a: a[None], x)
    xf = _pad_axis(_stack_tt_batch(xb, rx), 0, 8)
    pf = _stack_tt_proj(p, rp, 1)
    out = tt_inner_pallas(xf, pf, epilogue="raw",
                          interpret=_default_interpret(interpret))
    return (x.scale * p.scale) * out[0, 0]


# ---------------------------------------------------------------------------
# Discretization tails (standalone kernels; the fused path inlines these)
# ---------------------------------------------------------------------------


def srp_pack(values: jax.Array, block_b: int = 8,
             interpret: bool | None = None) -> jax.Array:
    """(B, K) raw values -> (B, ceil(K/32)) packed uint32 signatures."""
    b, k = values.shape
    v = _pad_axis(values.astype(jnp.float32), 1, 32, value=-1.0)
    v = _pad_axis(v, 0, block_b, value=-1.0)
    out = srp_pack_pallas(v, block_b=block_b,
                          interpret=_default_interpret(interpret))
    return out[:b]


def e2lsh_quantize(values: jax.Array, offsets: jax.Array, w: float,
                   block_b: int = 8, interpret: bool | None = None) -> jax.Array:
    """(B, K) values + (K,) offsets -> int32 (B, K) hashcodes.

    Both the batch axis and the K axis are padded — K to the f32 lane
    width with zero values/offsets (codes floor(0/w) = 0, sliced off), so
    non-lane-aligned K never reaches the kernel's (block_b, K) tiles.
    """
    b, k = values.shape
    v = _pad_axis(values.astype(jnp.float32), 0, block_b)
    v = _pad_axis(v, 1, _LANES)
    offs = _pad_axis(offsets.astype(jnp.float32), 0, _LANES)
    out = e2lsh_quant_pallas(v, offs, float(w), block_b=block_b,
                             interpret=_default_interpret(interpret))
    return out[:b, :k]
