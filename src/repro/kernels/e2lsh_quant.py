"""Pallas TPU kernel: E2LSH discretization — floor((v + b) / w) -> int32.

g(X) = floor((<P, X> + b) / w) (paper Definitions 10-11, Eq. 4.1/4.20).
A trivial VPU kernel fused at the tail of the projection so the float
values stay in VMEM; w is folded in as a compile-time reciprocal multiply
(no divide unit pressure). Grid over B-blocks; offsets broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _e2lsh_quant_kernel(v_ref, b_ref, o_ref, *, inv_w: float):
    o_ref[...] = jnp.floor((v_ref[...] + b_ref[...]) * inv_w).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("w", "block_b", "interpret"))
def e2lsh_quant_pallas(values: jax.Array, offsets: jax.Array, w: float,
                       block_b: int = 8, interpret: bool = True) -> jax.Array:
    """values (B, K), offsets (K,), bucket width w -> int32 (B, K)."""
    b, k = values.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    kernel = functools.partial(_e2lsh_quant_kernel, inv_w=1.0 / w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.int32),
        interpret=interpret,
    )(values, offsets[None, :])
