"""Pallas TPU kernels for the paper's compute hot-spots.

  cp_gram.py     batch-native fused CP x CP hashing (Gram + cross-mode
                 Hadamard + discretize/combine epilogues)
  tt_inner.py    batch-native fused TT x TT chain + the same epilogues
  epilogues.py   the shared in-kernel tails (E2LSH floor, SRP sign, uint32
                 radix code-combine, bit-pack)
  srp_pack.py    standalone sign + 32-lane bit pack (SRP tail)
  e2lsh_quant.py standalone floor((v + b)/w) quantization (E2LSH tail)
  ops.py         jit'd wrappers (padding/alignment, format adaptation) +
                 ``fused_hash``, the hash_backend='pallas' entry point of
                 the LSH families
  ref.py         pure-jnp oracles for allclose/bit-exact validation

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on this CPU container with interpret=True.
"""

from repro.kernels.ops import (cp_inner_products, tt_inner_products,
                               srp_pack, e2lsh_quantize, fused_hash, on_tpu)
from repro.kernels import ref
