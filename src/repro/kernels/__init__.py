"""Pallas TPU kernels for the paper's compute hot-spots.

  cp_gram.py     fused CP x CP inner products (Gram + cross-mode Hadamard)
  tt_inner.py    TT x TT transfer-matrix chain
  srp_pack.py    sign + 32-lane bit pack (SRP tail)
  e2lsh_quant.py floor((v + b)/w) quantization (E2LSH tail)
  ops.py         jit'd wrappers (padding/alignment, format adaptation)
  ref.py         pure-jnp oracles for allclose validation

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on this CPU container with interpret=True.
"""

from repro.kernels.ops import (cp_inner_products, tt_inner_products,
                               srp_pack, e2lsh_quantize, on_tpu)
from repro.kernels import ref
