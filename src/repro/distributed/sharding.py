"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates activations/params with *logical* dim names
("batch", "embed", "heads", ...). A rule set maps each logical name to mesh
axes. `shard()` resolves names against the active rule context and applies
`with_sharding_constraint`; outside a context it is a no-op, so the same
model code runs in single-device tests and in the 512-chip dry-run.

Divisibility fallback: a logical dim whose size does not divide the mapped
mesh-axis product is silently replicated (and recorded), never an error —
this is what keeps all 40 (arch x shape) dry-run cells compiling while the
perf pass tightens individual rules.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


# Default logical-name -> mesh-axes mapping for the production meshes
# ("pod", "data", "model"). Tuples mean the dim is sharded over several axes.
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,     # overridden to "model" (sequence parallelism) at scale
    "kv_seq": None,          # overridden to "model" for seq-sharded decode caches
    "embed": None,
    "fsdp_embed": ("data", "pod"),  # FSDP/ZeRO: param d_model dim; on the
                                    # multi-pod mesh optimizer state also
                                    # shards across pods (ZeRO over DP)
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv_embed": None,
    "vocab": "model",
    "expert": "model",
    "capacity": ("pod", "data"),   # MoE dispatch-buffer slot dim
    "dispatch": ("pod", "data"),   # MoE flat dispatch rows (T*k / E*C)
    "moe_d": "model",              # MoE dispatch feature dim (see moe.py)
    "chunks": "model",             # SSD chunk-index dim (heads fallback)
    "conv": None,
    "state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "frames": None,
    "layers": None,
    "lsh_hash": None,
    "lsh_rank": None,
    # corpus-shard axis of the sharded LSH index: the dedicated 1-D "shard"
    # mesh in tests, the data axis on the production meshes (one of the two
    # survives the missing-axis cleaning in axis_rules)
    "lsh_shard": ("shard", "data"),
}


@dataclasses.dataclass
class RuleContext:
    mesh: Mesh
    rules: dict[str, tuple[str, ...] | str | None]
    fallbacks: list[tuple[str, int, tuple[str, ...]]] = dataclasses.field(
        default_factory=list)

    def axis_size(self, axes: tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def current() -> RuleContext | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, overrides: Mapping[str, object] | None = None):
    """Activate sharding rules. Missing mesh axes in a rule are dropped
    (so the same rules work for (data, model) and (pod, data, model))."""
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    cleaned = {}
    for name, axes in rules.items():
        if axes is None:
            cleaned[name] = None
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        axes_t = tuple(a for a in axes_t if a in mesh.shape)
        cleaned[name] = axes_t or None
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = RuleContext(mesh=mesh, rules=cleaned)
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def resolve_spec(names: Sequence[str | None], shape: Sequence[int]) -> P:
    """Logical names -> PartitionSpec under the active context (with
    divisibility fallback). Returns P() outside a context."""
    ctx = current()
    if ctx is None:
        return P()
    entries = []
    used: set[str] = set()
    for name, size in zip(names, shape):
        axes = ctx.rules.get(name) if name else None
        if not axes:
            entries.append(None)
            continue
        if any(a in used for a in axes):
            entries.append(None)  # a mesh axis may appear once per spec
            continue
        ax_size = ctx.axis_size(axes)
        if size % ax_size != 0:
            ctx.fallbacks.append((str(name), int(size), axes))
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Constrain activation sharding by logical dim names (no-op w/o context)."""
    ctx = current()
    if ctx is None:
        return x
    spec = resolve_spec(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(names: Sequence[str | None], shape: Sequence[int]) -> NamedSharding:
    ctx = current()
    assert ctx is not None, "named_sharding requires an active axis_rules context"
    return NamedSharding(ctx.mesh, resolve_spec(names, shape))


def tree_shardings(axes_tree, shape_tree):
    """Map a tree of logical-axis tuples + a matching tree of
    ShapeDtypeStructs to NamedShardings."""
    return jax.tree.map(
        lambda axes, sds: named_sharding(axes, sds.shape),
        axes_tree, shape_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            isinstance(e, (str, type(None))) for e in a),
    )
