"""Mesh placement + shard_map query program for ``ShardedLSHIndex``.

The index math (per-segment probe, re-rank, global top-k merge) lives in
``repro.core.segments``; this module decides *where* the sharded base
segment runs and provides the ``shard_map`` variant of the query program:

- ``resolve_mesh``: map a shard count to (mesh, axis). An active
  ``distributed.sharding.axis_rules`` context wins — the ``lsh_shard``
  logical name resolves through the same rule machinery as every other
  logical dim, so the index shards over ``data`` on the production meshes
  and over the dedicated 1-D ``shard`` mesh in tests. Without a context, a
  1-D mesh over the first S local devices is built; with fewer devices than
  shards the caller falls back to the vmapped single-device program.
- ``place_sharded``: NamedSharding placement of any (S, ...)-leading index
  arrays — the base segment AND the routed delta slabs (sorted keys,
  permutations, liveness/effective-id/live-window lookups, corpus slices)
  follow the same rules, so the mutation plane shards exactly like the
  query plane. ``place_shadow`` is the blocking variant the double-buffered
  swap uses to land a fully-materialized shadow store before the pointer
  flip publishes it.
- ``shard_map_query``: one jit program — replicated hashing outside the
  shard_map; inside it each device probes its base block *and* its slab of
  every delta segment (searchsorted/gather/tombstone-filter/re-rank) and
  merges them into one per-shard top-k; then the single global S-way merge.
  Deltas are never a replicated post-merge appendix.
"""

from __future__ import annotations

import functools

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding

# Logical dim name of the corpus-shard axis (see sharding.DEFAULT_RULES) and
# the mesh axis name used when this module builds its own 1-D mesh.
SHARD_LOGICAL = "lsh_shard"
SHARD_AXIS = "shard"


def resolve_mesh(shards: int) -> tuple[Mesh, str] | tuple[None, None]:
    """-> (mesh, axis name) to lay the S-sharded index over, or (None, None).

    Inside an ``axis_rules`` context the ``lsh_shard`` rule must resolve to
    a single mesh axis whose size equals ``shards`` (the index's leading
    dim is exactly one slice per device along that axis); otherwise a
    dedicated 1-D mesh over the first ``shards`` local devices is built.
    """
    ctx = sharding.current()
    if ctx is not None:
        axes = ctx.rules.get(SHARD_LOGICAL)
        if axes and len(axes) == 1 and ctx.mesh.shape[axes[0]] == shards:
            return ctx.mesh, axes[0]
        return None, None  # context active but rule unusable -> vmap path
    devices = jax.devices()
    if shards <= len(devices):
        return Mesh(np.asarray(devices[:shards]), (SHARD_AXIS,)), SHARD_AXIS
    return None, None


def place_sharded(tree, mesh: Mesh, axis: str):
    """device_put every leaf with its leading dim sharded over ``axis``."""
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def place_shadow(tree, mesh: Mesh, axis: str):
    """``place_sharded`` for the double-buffered swap's shadow store: the
    transfers are issued AND waited on here, off the query path, so the
    later pointer flip publishes a store whose every array has already
    landed on its shard — the first post-swap query pays zero placement
    cost and the flip itself does no device work."""
    placed = place_sharded(tree, mesh, axis)
    jax.block_until_ready(jax.tree.leaves(placed))
    return placed


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps", "probes", "mesh",
                                             "axis", "probe_backend"))
def shard_map_query(family, base, deltas, mults, queries, *, metric, topk,
                    cap, delta_caps, mesh, axis, probes=1,
                    probe_backend="auto"):
    """One jit program: hash (replicated) -> per-shard fused probe/re-rank/
    top-k over the base block + every delta slab (shard_map) -> global S-way
    merge. Bit-identical to ``shard_map_query_reference`` and to
    ``core.segments.sharded_query_vmap``.

    ``base`` and each element of ``deltas`` is a (corpus, sorted_keys,
    perm, live, eff, win) tuple whose array leaves carry a leading shard
    dim laid over ``axis``; each device sees its (1, ...) blocks.
    ``probes`` = T > 1 replicates the (L, T, B) multi-probe key tensor
    instead of the (L, B) single-probe one — the shard body is
    shape-agnostic, so every device probes all T buckets of its blocks.

    ``probe_backend`` mirrors the knob on ``segments.segmented_query``.
    The 'xla' path runs the restructured packed schedule
    (``segments.shard_packed_topk_with_deltas``) inside the shard_map body;
    'pallas' currently falls back to the per-shard fused-kernel loop in
    ``segments.sharded_query_vmap`` — dispatching the Pallas program
    through shard_map itself is the deferred TPU measurement leg (ROADMAP).
    """
    from repro.core import segments

    if segments.resolved_probe_backend(probe_backend) == "pallas":
        return segments.sharded_query_vmap(
            family, base, deltas, mults, queries, metric=metric, topk=topk,
            cap=cap, delta_caps=delta_caps, probes=probes,
            probe_backend="pallas")

    # (L, B) / (L, T, B), replicated
    keys = segments.query_keys(family, mults, queries, probes)

    def body(base_blk, deltas_blk, keys_r, queries_r):
        # blocks carry a leading shard dim of 1 on the sharded operands
        take0 = lambda t: jax.tree.map(lambda a: a[0], t)
        ids, scores, n_cand = segments.shard_packed_topk_with_deltas(
            metric, topk, cap, delta_caps, queries_r,
            take0(base_blk), take0(deltas_blk), keys_r)
        return ids[None], scores[None], n_cand[None]

    sharded_spec, rep = P(axis), P()
    per_shard = shard_map(
        body, mesh,
        in_specs=(sharded_spec, sharded_spec, rep, rep),
        out_specs=(sharded_spec,) * 3,
        check_rep=False,
    )(base, deltas, keys, queries)
    return segments.merge_topk(metric, topk, *per_shard)


@functools.partial(jax.jit, static_argnames=("metric", "topk", "cap",
                                             "delta_caps", "probes", "mesh",
                                             "axis"))
def shard_map_query_reference(family, base, deltas, mults, queries, *, metric,
                              topk, cap, delta_caps, mesh, axis, probes=1):
    """The reference shard_map program: per-shard merge-tree top-k
    (``segments.shard_topk_with_deltas``) then the global S-way merge.
    The restructured ``shard_map_query`` above is pinned bit-identical to
    this program (tests/test_fused_probe.py)."""
    from repro.core import segments

    keys = segments.query_keys(family, mults, queries, probes)

    def body(base_blk, deltas_blk, keys_r, queries_r):
        take0 = lambda t: jax.tree.map(lambda a: a[0], t)
        ids, scores, n_cand = segments.shard_topk_with_deltas(
            metric, topk, cap, delta_caps, queries_r,
            take0(base_blk), take0(deltas_blk), keys_r)
        return ids[None], scores[None], n_cand[None]

    sharded_spec, rep = P(axis), P()
    per_shard = shard_map(
        body, mesh,
        in_specs=(sharded_spec, sharded_spec, rep, rep),
        out_specs=(sharded_spec,) * 3,
        check_rep=False,
    )(base, deltas, keys, queries)
    return segments.merge_topk(metric, topk, *per_shard)
