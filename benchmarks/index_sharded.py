"""Sharded vs single-device LSH index: build time, QPS at batch sizes
{1, 64, 1024}, and recall@10 parity at S in {1, 2, 4} simulated shards.

Run standalone (``python -m benchmarks.index_sharded``) the module forces a
4-device host platform (``--xla_force_host_platform_device_count``) so the
shard_map path is exercised; imported from ``benchmarks.run`` it uses
whatever devices exist (the vmapped fallback on one device — same math).

CSV rows (name,us_per_call,derived):

  index_sharded/build_s{S}          us = build wall time, derived = corpus n
  index_sharded/qps_s{S}_b{B}       us = per-query latency, derived = QPS
  index_sharded/recall10_s{S}       derived = recall@10 | mean candidates
  index_sharded/qps_ratio_s{S}      derived = sharded/single-device QPS
                                    at the largest batch (>= 0.5 target)

``run()`` also appends a trajectory entry to BENCH_index.json at the repo
root (build time, QPS, recall@10 per shard count) so later PRs can compare
against this baseline.
"""

from __future__ import annotations

import json
import os
import sys
import time

# standalone entrypoint only: force shards-many host devices (must happen
# before jax first initialises; a plain import never sets the flag)
if __name__ == "__main__" and "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import (DeviceLSHIndex, ShardedLSHIndex, make_family,
                        recall_at_k)

DIMS = (8, 8, 8)
N_CLUSTERS, PER_CLUSTER = 512, 8           # clustered corpus: real neighbors
N_CORPUS = N_CLUSTERS * PER_CLUSTER
NOISE = 0.15
N_RECALL_QUERIES = 64
BATCH_SIZES = (1, 64, 1024)
SHARD_COUNTS = (1, 2, 4)

_TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_index.json")


def _data():
    kc, kn, kq, kf = jax.random.split(jax.random.PRNGKey(11), 4)
    centers = jax.random.normal(kc, (N_CLUSTERS,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = (jnp.tile(centers, (max(BATCH_SIZES) // N_CLUSTERS + 1,)
                        + (1,) * len(DIMS))[:max(BATCH_SIZES)]
               + NOISE * jax.random.normal(kq, (max(BATCH_SIZES),) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    return corpus, queries, fam


def _append_trajectory(entry: dict) -> None:
    history = []
    if os.path.exists(_TRAJECTORY):
        try:
            with open(_TRAJECTORY) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    with open(_TRAJECTORY, "w") as f:
        json.dump(history, f, indent=1)


def run() -> list[str]:
    rows = []
    corpus, queries, fam = _data()

    # single-device reference
    single = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
    jax.block_until_ready(single.sorted_keys)
    b_max = max(BATCH_SIZES)
    us = time_fn(lambda qb: single.query_batch(qb, topk=10),
                 queries[:b_max], warmup=1, iters=5)
    single_qps = b_max / (us / 1e6)

    entry = {"n_devices": len(jax.devices()), "corpus_n": N_CORPUS,
             "single_device_qps_b1024": round(single_qps),
             "shards": {}}
    for s in SHARD_COUNTS:
        t0 = time.perf_counter()
        idx = ShardedLSHIndex(fam, metric="euclidean", shards=s).build(corpus)
        jax.block_until_ready(idx.sorted_keys)
        build_us = (time.perf_counter() - t0) * 1e6
        rows.append(emit(f"index_sharded/build_s{s}", build_us, N_CORPUS))
        cell = {"build_s": build_us / 1e6,
                "shard_map": idx.mesh is not None, "qps": {}}
        for b in BATCH_SIZES:
            us = time_fn(lambda qb: idx.query_batch(qb, topk=10),
                         queries[:b], warmup=1, iters=5)
            qps = b / (us / 1e6)
            rows.append(emit(f"index_sharded/qps_s{s}_b{b}", us / b,
                             f"{qps:.0f}"))
            cell["qps"][f"b{b}"] = round(qps)
        rows.append(emit(f"index_sharded/qps_ratio_s{s}", 0.0,
                         f"{cell['qps'][f'b{b_max}'] / single_qps:.2f}"))
        stats = recall_at_k(idx, queries[:N_RECALL_QUERIES], topk=10)
        rows.append(emit(
            f"index_sharded/recall10_s{s}", 0.0,
            f"{stats['recall']:.3f}|{stats['mean_candidates']:.0f}"))
        cell["recall10"] = round(stats["recall"], 4)
        entry["shards"][f"s{s}"] = cell

    _append_trajectory(entry)
    return rows


if __name__ == "__main__":
    run()
