"""Collision-probability validation (Theorems 4, 6, 8, 10).

For each family: empirical collision rate over M independent hash
functions vs the paper's closed forms — p(r) (Eq. 4.17/4.33) for the
E2LSH kinds, 1 - theta/pi (Eq. 4.58/4.81) for the SRP kinds.

CSV: name,us_per_call,derived (derived = max |empirical - theory| over the
distance/similarity grid; the paper's claim holds if this is at the
binomial-noise level ~ 3*sqrt(p(1-p)/M) ~ 0.03).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import make_family, theory

DIMS = (8, 8, 8)
M = 2000
W = 4.0


def run() -> list[str]:
    rows = []
    kx, kn, kf = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(kx, DIMS)
    noise = jax.random.normal(kn, DIMS)

    for kind in ("cp-e2lsh", "tt-e2lsh", "e2lsh"):
        fam = make_family(kf, kind, DIMS, num_codes=M, rank=2, bucket_width=W)
        hash_fn = jax.jit(fam.hash)
        cx = np.asarray(hash_fn(x)).ravel()
        devs = []
        for r in (0.5, 1.0, 2.0, 4.0, 8.0):
            y = x + noise * (r / jnp.linalg.norm(noise))
            cy = np.asarray(hash_fn(y)).ravel()
            emp = float((cx == cy).mean())
            want = float(theory.e2lsh_collision_prob(r, W))
            devs.append(abs(emp - want))
        us = time_fn(hash_fn, x)
        rows.append(emit(f"collision/{kind}", us, f"{max(devs):.4f}"))

    for kind in ("cp-srp", "tt-srp", "srp"):
        fam = make_family(kf, kind, DIMS, num_codes=M, rank=2)
        hash_fn = jax.jit(fam.hash)
        cx = np.asarray(hash_fn(x)).ravel()
        devs = []
        for mix in (0.05, 0.2, 0.5, 1.0, 2.0):
            y = x + mix * noise
            cos = float(jnp.vdot(x, y)
                        / (jnp.linalg.norm(x) * jnp.linalg.norm(y)))
            cy = np.asarray(hash_fn(y)).ravel()
            emp = float((cx == cy).mean())
            want = float(theory.srp_collision_prob(cos))
            devs.append(abs(emp - want))
        us = time_fn(hash_fn, x)
        rows.append(emit(f"collision/{kind}", us, f"{max(devs):.4f}"))
    return rows


if __name__ == "__main__":
    run()
