"""Hash-pipeline throughput: the PR 4 batch-native fused path vs the PR 3
vmap-of-``hash()`` path, per family kind, on corpus-hash (index build) and
insert-hash (one streaming-insert batch) workloads.

The legacy baseline is reconstructed exactly as PR 3 shipped it: a
jit(vmap(per-example projection chain -> discretize)) program followed by a
*separate* uint32 code-combine dispatch per batch. The fused path is
``segments.bucket_keys`` -> ``LSHFamily.hash_keys``: one jit program from
the input batch to the (B, L) bucket keys (explicit batched contractions;
for dense inputs the K projection tensors are densified once per batch and
hashing is a single (B, d^N) x (d^N, K) matmul — O(K d^N) per example vs
the chain's O(K R d^N)).

Corpora: dense tensors (what the index benchmarks and the PR 3 insert path
hash) for every kind, plus in-format CP/TT corpora for the tensorized
kinds. Backend is the XLA path on CPU; on TPU the same rows time the
Pallas kernel path (interpret-mode kernel timings on CPU are
Python-semantics only and are not emitted).

CSV rows (name,us_per_call,derived):

  hash/{kind}/{fmt}/corpus_legacy   us per corpus pass, derived = items/s
  hash/{kind}/{fmt}/corpus_fused    us per corpus pass, derived = items/s
  hash/{kind}/{fmt}/corpus_speedup  derived = legacy_us / fused_us (the
                                    acceptance bar: >= 2x for cp-e2lsh and
                                    tt-srp on the dense corpus)
  hash/{kind}/{fmt}/insert_b{B}     us per insert batch, derived =
                                    fused items/s | speedup vs legacy
  hash/{kind}/{fmt}/keys_equal     derived = fraction of bucket keys equal
                                    to the legacy path (float-reassociation
                                    can flip boundary codes; backends are
                                    pinned bit-identical by
                                    tests/test_hash_backends.py instead)

``run()`` appends a trajectory entry to BENCH_index.json (tagged
``"bench": "hash_throughput"``). BENCH_HASH_N shrinks the corpus for smoke
runs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core import cp_random_data, make_family, tt_random_data
from repro.core.lsh import (E2LSH_KINDS, LSHFamily, _combine_codes,
                            e2lsh_discretize, make_mults, srp_discretize)
from repro.core.projections import CPProjection, DenseProjection, TTProjection
from repro.core.segments import bucket_keys
from repro.core.tensor_formats import CPTensor, TTTensor

DIMS = (8, 8, 8)
N_CORPUS = int(os.environ.get("BENCH_HASH_N", 32_768))
INSERT_BATCH = 1024
HASH_BATCH = 1024
NUM_CODES, NUM_TABLES, RANK = 4, 8, 2

# ---------------------------------------------------------------------------
# The PR 3 hash path, reconstructed: per-example mode-by-mode projection
# chains under vmap (exactly the retired repro.core.projections single-input
# contractions), discretize inside the vmap, combine as a second dispatch.
# ---------------------------------------------------------------------------


def _legacy_project_one(p, x):
    if isinstance(p, CPProjection):
        if isinstance(x, CPTensor):
            h = None
            for a, f in zip(x.factors, p.factors):
                g = jnp.einsum("ir,kiq->krq", a, f)
                h = g if h is None else h * g
            return (x.scale * p.scale) * jnp.sum(h, axis=(1, 2))
        t = jnp.einsum("i...,kir->kr...", x, p.factors[0])
        for f in p.factors[1:]:
            t = jnp.einsum("kri...,kir->kr...", t, f)
        return p.scale * jnp.sum(t, axis=1)
    if isinstance(p, TTProjection):
        if isinstance(x, TTTensor):
            s = jnp.ones((p.num_hashes, 1, 1), x.cores[0].dtype)
            for gx, gp in zip(x.cores, p.cores):
                s = jnp.einsum("kab,aic,kbie->kce", s, gx, gp)
            return (x.scale * p.scale) * s.reshape(p.num_hashes)
        t = jnp.einsum("i...,kair->kr...", x, p.cores[0])
        for core in p.cores[1:]:
            t = jnp.einsum("kai...,kair->kr...", t, core)
        return p.scale * t.reshape(p.num_hashes)
    assert isinstance(p, DenseProjection)
    return p.scale * (p.matrix @ x.reshape(-1))


@jax.jit
def _legacy_hash_batch(family: LSHFamily, xs):
    def one(x):
        v = _legacy_project_one(family.projection, x)
        if family.kind in E2LSH_KINDS:
            codes = e2lsh_discretize(v, family.offsets, family.bucket_width)
        else:
            codes = srp_discretize(v)
        return codes.reshape(family.num_tables, family.num_codes)
    return jax.vmap(one)(xs)


@jax.jit
def _fused_keys(family, xs, mults):
    # one jit program, exactly as segments.bucket_keys runs it
    return family.hash_keys(xs, mults)


def _legacy_bucket_keys(family, mults, corpus, batch_size):
    n = jax.tree.leaves(corpus)[0].shape[0]
    keys = []
    for start in range(0, n, batch_size):
        chunk = jax.tree.map(
            lambda a: a[start:min(start + batch_size, n)], corpus)
        keys.append(_combine_codes(_legacy_hash_batch(family, chunk),
                                   jnp.asarray(mults)))
    return jnp.concatenate(keys, axis=0)


# ---------------------------------------------------------------------------


def _corpora(kind, key):
    out = {"dense": jax.random.normal(key, (N_CORPUS,) + DIMS)}
    if kind.startswith("cp"):
        out["cp"] = jax.vmap(lambda k: cp_random_data(k, DIMS, 3))(
            jax.random.split(key, N_CORPUS))
    elif kind.startswith("tt"):
        out["tt"] = jax.vmap(lambda k: tt_random_data(k, DIMS, 3))(
            jax.random.split(key, N_CORPUS))
    return out


def run() -> list[str]:
    rows = []
    summary = {}
    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    for i, kind in enumerate(("cp-e2lsh", "tt-e2lsh", "cp-srp", "tt-srp",
                              "e2lsh", "srp")):
        key = jax.random.PRNGKey(100 + i)
        fam = make_family(key, kind, DIMS, num_codes=NUM_CODES,
                          num_tables=NUM_TABLES, rank=RANK, bucket_width=16.0,
                          hash_backend=backend)
        mults = make_mults(0, NUM_CODES)
        for fmt, corpus in _corpora(kind, key).items():
            tag = f"hash/{kind}/{fmt}"
            legacy = lambda: _legacy_bucket_keys(fam, mults, corpus,
                                                 HASH_BATCH)
            fused = lambda: bucket_keys(fam, mults, corpus, HASH_BATCH)
            keys_eq = float((np.asarray(legacy()) ==
                             np.asarray(fused())).mean())
            us_legacy = time_fn(legacy, warmup=1, iters=3)
            us_fused = time_fn(fused, warmup=1, iters=3)
            rows.append(emit(f"{tag}/corpus_legacy", us_legacy,
                             f"{N_CORPUS / (us_legacy / 1e6):.0f}"))
            rows.append(emit(f"{tag}/corpus_fused", us_fused,
                             f"{N_CORPUS / (us_fused / 1e6):.0f}"))
            speedup = us_legacy / us_fused
            rows.append(emit(f"{tag}/corpus_speedup", 0.0, f"{speedup:.1f}x"))

            batch = jax.tree.map(lambda a: a[:INSERT_BATCH], corpus)
            ins_legacy = time_fn(
                lambda b: _combine_codes(_legacy_hash_batch(fam, b),
                                         jnp.asarray(mults)), batch)
            ins_fused = time_fn(
                lambda b: _fused_keys(fam, b, jnp.asarray(mults)), batch)
            rows.append(emit(
                f"{tag}/insert_b{INSERT_BATCH}", ins_fused,
                f"{INSERT_BATCH / (ins_fused / 1e6):.0f}"
                f"|{ins_legacy / ins_fused:.1f}x"))
            rows.append(emit(f"{tag}/keys_equal", 0.0, f"{keys_eq:.4f}"))
            summary[f"{kind}/{fmt}"] = {
                "corpus_legacy_items_per_s": round(N_CORPUS / (us_legacy / 1e6)),
                "corpus_fused_items_per_s": round(N_CORPUS / (us_fused / 1e6)),
                "corpus_speedup": round(speedup, 1),
                "insert_fused_items_per_s": round(
                    INSERT_BATCH / (ins_fused / 1e6)),
                "insert_speedup": round(ins_legacy / ins_fused, 1),
                "keys_equal_frac": keys_eq,
            }
    append_trajectory({
        "bench": "hash_throughput",
        "backend": backend,
        "n_devices": len(jax.devices()),
        "corpus_n": N_CORPUS,
        "hash_batch": HASH_BATCH,
        "insert_batch": INSERT_BATCH,
        "kinds": summary,
    })
    return rows


if __name__ == "__main__":
    run()
