"""p99 SLO harness for the serving plane: open-loop arrivals against the
micro-batch scheduler, with and without background compaction.

The question this bench answers is the PR's acceptance gate: does a
``compact()``/``rebalance()`` running behind the double-buffered swap stall
concurrently-arriving queries? An open-loop Poisson arrival process (the
offered load never waits for responses, so queueing delay is *measured*,
not hidden) drives single-query requests through ``ServingScheduler``;
each request's latency is completion minus its scheduled arrival. The
offered rate is calibrated per shard count to ``UTILIZATION`` of the
measured warmed dispatch capacity (capped at ``BENCH_SLO_RATE``): on CPU a
sharded vmap query program costs several times its single-shard
equivalent, and an offered load past saturation measures queueing
collapse, not swap stalls. ``max_batch`` shrinks with S for the same
reason — a 32-wide sharded batch is one multi-hundred-ms program, so
coalescing that deep *adds* latency at S > 1. Two phases per shard count
S in {1, 2, 4}:

  quiet      — queries only.
  compacting — the same arrival process while the ingest lane continuously
               inserts delta batches and runs prepare-compact/apply-swap
               cycles, so every query races a shadow-store build.

The phases run *interleaved* as ``N_BLOCKS`` alternating quiet/compacting
blocks (same per-block arrival seeds, latencies pooled per phase) rather
than as two long monolithic windows: single-core container environments
throw sporadic hundred-ms hiccups (host scheduling, page cache) that a
monolithic design lands entirely inside one phase, corrupting the ratio
in either direction — blocking spreads them evenly across both pools.

CSV rows (name,us_per_call,derived), per shard count S:

  serving_slo/build_s{S}        us = service build, derived = n
  serving_slo/quiet_s{S}        us = p50 latency, derived =
                                p99 ms | p99.9 ms | goodput req/s
  serving_slo/compacting_s{S}   same, measured against background swaps,
                                + swap builds completed
  serving_slo/p99_ratio_s{S}    derived = compacting p99 / quiet p99 on
                                the median-of-block-p99s estimator (the
                                acceptance gate is <= 1.5), + the pooled
                                single-distribution ratio
  serving_slo/stall_s{S}        us = worst compacting-phase latency,
                                derived = mean build-to-build interval ms
                                | ratio | within-budget flag (a query
                                stalling out a whole swap interval means
                                the build ran ON the query path, not
                                beside it)
  serving_slo/coalesce_s{S}     us = deadline, derived = mean coalesced
                                batch | lane batches

``run()`` appends one trajectory entry to BENCH_index.json (tagged
``"bench": "serving_slo"``). BENCH_SLO_N / BENCH_SLO_REQS / BENCH_SLO_RATE
shrink it for smoke runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core import make_family
from repro.serving.lsh_service import LSHService
from repro.serving.scheduler import ServingScheduler

DIMS = (8, 8, 8)
N_CORPUS = int(os.environ.get("BENCH_SLO_N", 20_000))
N_REQS = int(os.environ.get("BENCH_SLO_REQS", 400))     # per phase
RATE_QPS = float(os.environ.get("BENCH_SLO_RATE", 150.0))  # offered-rate cap
PER_CLUSTER = 8
NOISE = 0.15
SHARD_COUNTS = (1, 2, 4)
TOPK = 10
BUCKET_CAP = 64
MAX_BATCH = 32                # query-lane size flush at S=1 (shrinks with S)
DEADLINE_MS = 25.0            # query-lane coalescing window: sized to the
                              # per-program service time of the sharded CPU
                              # query (tens of ms), so the lane actually
                              # coalesces at the calibrated rates instead of
                              # dispatching singletons
INSERT_BATCH = 512            # ingest-lane churn per swap cycle
GATE_RATIO = 1.5              # acceptance: compacting p99 <= 1.5x quiet p99
N_BLOCKS = 6                  # alternating quiet/compacting blocks per phase
UTILIZATION = 0.2             # offered rate as a fraction of measured
                              # warmed dispatch capacity per shard count.
                              # Capacity is measured closed-loop through
                              # the scheduler, but the open loop coalesces
                              # shallower than the closed burst, so real
                              # sustainable capacity is below the measured
                              # cap; the rest is headroom for churn
                              # programs, which share the same CPU cores.
PAUSE_FRAC = 3.0              # churn duty cycle: sleep this fraction of
                              # each cycle's wall between swap cycles
                              # (compaction is periodic, not a busy loop)


def _data():
    kc, kn, kq, ki, kf = jax.random.split(jax.random.PRNGKey(41), 5)
    n_clusters = max(N_CORPUS // PER_CLUSTER, 1)
    centers = jax.random.normal(kc, (n_clusters,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)[:N_CORPUS]
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = np.asarray(
        jnp.tile(centers, (256 // n_clusters + 1,) + (1,) * len(DIMS))[:256]
        + NOISE * jax.random.normal(kq, (256,) + DIMS))
    inserts = np.asarray(
        jnp.tile(centers, (INSERT_BATCH // n_clusters + 1,)
                 + (1,) * len(DIMS))[:INSERT_BATCH]
        + NOISE * jax.random.normal(ki, (INSERT_BATCH,) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    return corpus, queries, inserts, fam


def _percentiles(lat_ms: np.ndarray) -> dict:
    return {"p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "p999_ms": float(np.percentile(lat_ms, 99.9)),
            "max_ms": float(lat_ms.max())}


def _open_loop(sched: ServingScheduler, queries: np.ndarray, *,
               n_reqs: int, rate_qps: float, seed: int) -> dict:
    """Drive ``n_reqs`` Poisson arrivals at ``rate_qps``; latency is
    completion minus *scheduled* arrival (open loop: a response that
    queues behind a stall keeps accruing latency)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_reqs))
    done = np.zeros(n_reqs)
    futures = []
    t0 = time.perf_counter()
    for i in range(n_reqs):
        wait = arrivals[i] - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        fut = sched.query(queries[i % len(queries)], topk=TOPK)
        fut.add_done_callback(
            lambda f, i=i: done.__setitem__(i, time.perf_counter() - t0))
        futures.append(fut)
    for fut in futures:
        fut.result(timeout=120)
    lat_ms = (done - arrivals) * 1e3
    wall = done.max() - arrivals[0]
    return {**_percentiles(lat_ms),
            "goodput_rps": float(n_reqs / max(wall, 1e-9)),
            "offered_rps": rate_qps, "n_reqs": n_reqs,
            "wall_s": float(wall), "lat_ms": lat_ms}


def _phase(blocks: list[dict], rate_qps: float) -> dict:
    """Pool the per-block latency samples of one phase into its summary.

    ``p99_ms`` (and the other pooled percentiles) describe the phase as
    one distribution; ``p99_med_ms`` — the *median of the per-block
    p99s* — is what the acceptance ratio uses. The pooled p99 of a few
    hundred samples is an extreme order statistic: one ~0.5 s host freeze
    (observed sporadically on single-core containers) lands in exactly
    one block and drags it arbitrarily, in whichever phase it happens to
    hit. The median across blocks ignores any minority of corrupted
    blocks while still being an honest per-block tail measurement."""
    lat_ms = np.concatenate([b["lat_ms"] for b in blocks])
    wall = float(sum(b["wall_s"] for b in blocks))
    block_p99s = [b["p99_ms"] for b in blocks]
    return {**_percentiles(lat_ms),
            "p99_med_ms": float(np.median(block_p99s)),
            "block_p99s_ms": [float(p) for p in block_p99s],
            "goodput_rps": float(len(lat_ms) / max(wall, 1e-9)),
            "offered_rps": rate_qps, "n_reqs": int(len(lat_ms)),
            "wall_s": wall}


class _Churn:
    """Ingest-lane churn: keep swap builds racing the query lane — insert
    a delta batch, tombstone it, then prepare+flip — while enabled. Each
    cycle returns the store to its pre-cycle size, so the swaps exercise
    the flip (not fresh jit compiles of ever-growing shapes: production
    stores cycle through warmed program shapes, and so does the bench).
    ``PAUSE_FRAC`` of each cycle's wall is slept between cycles —
    compaction is a periodic background job, not a busy loop.

    ``enable()``/``disable()`` gate the cycles so the interleaved block
    design can alternate quiet and compacting blocks on one churn thread;
    ``disable()`` blocks until the in-flight cycle (if any) completes, so
    a quiet block never overlaps a swap build."""

    def __init__(self, sched: ServingScheduler, svc: LSHService, inserts):
        self.sched, self.svc, self.inserts = sched, svc, inserts
        self.builds = 0
        self.build_ms: list[float] = []
        self._stop = False
        import threading
        self._go = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        n0 = self.svc.index.size
        n_ins = len(self.inserts)
        while not self._stop:
            if not self._go.wait(timeout=0.05):
                continue
            if self._stop:
                return
            self._idle.clear()
            try:
                t0 = time.perf_counter()
                self.sched.insert(self.inserts).result(timeout=120)
                self.sched.delete(
                    np.arange(n0, n0 + n_ins)).result(timeout=120)
                t1 = time.perf_counter()
                self.sched.compact().result(timeout=120)
                t2 = time.perf_counter()
                self.build_ms.append((t2 - t1) * 1e3)
                self.builds += 1
            finally:
                self._idle.set()
            pause_until = time.perf_counter() + PAUSE_FRAC * (t2 - t0)
            while time.perf_counter() < pause_until and not self._stop:
                time.sleep(0.01)

    def enable(self) -> None:
        self._go.set()

    def disable(self, timeout_s: float = 120.0) -> None:
        self._go.clear()
        self._idle.wait(timeout_s)

    def settle(self, timeout_s: float = 120.0) -> None:
        """Run exactly one unrecorded cycle, then zero the counters: the
        first build through a fresh scheduler pays one-time
        allocator/arena warm-up (measured ~1.5x the steady-state build),
        which is start-up transient, not swap behavior — the measured
        blocks see steady-state cycles only, matching the quiet settle
        pass."""
        self.enable()
        deadline = time.perf_counter() + timeout_s
        while self.builds < 1 and time.perf_counter() < deadline:
            time.sleep(0.01)
        self.disable(timeout_s)
        self.builds = 0
        self.build_ms = []

    def stop(self) -> float:
        self._stop = True
        self._go.set()
        self._thread.join()
        return float(np.mean(self.build_ms)) if self.build_ms else 0.0


def _capacity_rps(sched: ServingScheduler, queries: np.ndarray,
                  n: int = 96) -> float:
    """Closed-loop throughput ceiling THROUGH the scheduler: submit ``n``
    queries back-to-back and measure the drain rate. Unlike a direct
    warmed-dispatch estimate this includes every serving-path cost the
    open loop will pay — lane threads, GIL, stacking, future resolution,
    coalescing efficiency — so a fraction of it is a rate the scheduler
    can actually sustain (a direct estimate overshoots by 2-3x and the
    open loop then measures queueing collapse, not swap behavior)."""
    t0 = time.perf_counter()
    futures = [sched.query(queries[i % len(queries)], topk=TOPK)
               for i in range(n)]
    for fut in futures:
        fut.result(timeout=120)
    return n / (time.perf_counter() - t0)


def run() -> list[str]:
    corpus, queries, inserts, fam = _data()
    rows = []
    traj: dict = {"bench": "serving_slo", "n": N_CORPUS, "reqs": N_REQS,
                  "n_blocks": N_BLOCKS, "rate_cap_rps": RATE_QPS,
                  "utilization": UTILIZATION, "deadline_ms": DEADLINE_MS,
                  "gate_ratio": GATE_RATIO, "shards": {}}
    for s in SHARD_COUNTS:
        max_batch = max(MAX_BATCH // (s * s), 4)
        # every pow2 shape the lane can flush — all must be pre-warmed
        batch_grid = [1 << p for p in range(max_batch.bit_length())
                      if 1 << p <= max_batch]
        t0 = time.perf_counter()
        svc = LSHService(fam, metric="euclidean", shards=s,
                         bucket_cap=BUCKET_CAP, max_deltas=64).build(corpus)
        build_us = (time.perf_counter() - t0) * 1e6
        rows.append(emit(f"serving_slo/build_s{s}", build_us, N_CORPUS))
        with ServingScheduler(svc, max_batch=max_batch,
                              deadline_ms=DEADLINE_MS) as sched:
            # warm the jit cache across the pow2 batch shapes the lane
            # will dispatch — against the pristine store, the one-delta
            # store the churn cycles through, and the compacted store —
            # so neither phase pays first-compile cost
            n0 = svc.index.size
            for b in batch_grid:
                svc.query_arrays(queries[:b], topk=TOPK)
            svc.insert(inserts)
            for b in batch_grid:
                svc.query_arrays(queries[:b], topk=TOPK)
            svc.delete(np.arange(n0, n0 + len(inserts)))
            svc.compact()
            for b in batch_grid:
                svc.query_arrays(queries[:b], topk=TOPK)
            svc.stats.reset()
            # offered load: UTILIZATION of the scheduler's own measured
            # closed-loop capacity, capped at RATE_QPS — saturating a
            # slow sharded CPU program measures queueing collapse, not
            # swap stalls
            _capacity_rps(sched, queries)          # warm the burst path
            cap_rps = _capacity_rps(sched, queries)
            rate = min(RATE_QPS, UTILIZATION * cap_rps)

            # unrecorded settle pass: let the lane, allocator, and OS
            # scheduler reach steady state so the quiet blocks' tail
            # measures serving, not start-up transients
            _open_loop(sched, queries, n_reqs=max(N_REQS // 8, 16),
                       rate_qps=rate, seed=11)
            churn = _Churn(sched, svc, inserts)
            churn.settle()
            sched.stats.reset()   # coalesce row: measured blocks only
            # interleaved blocks: quiet block k and compacting block k
            # replay the SAME arrival process (seed) with churn as the
            # only difference, and alternating spreads environment hiccups
            # evenly across both latency pools
            block_reqs = max(N_REQS // N_BLOCKS, 16)
            quiet_blocks, comp_blocks = [], []
            for k in range(N_BLOCKS):
                quiet_blocks.append(_open_loop(
                    sched, queries, n_reqs=block_reqs, rate_qps=rate,
                    seed=3 + k))
                churn.enable()
                comp_blocks.append(_open_loop(
                    sched, queries, n_reqs=block_reqs, rate_qps=rate,
                    seed=3 + k))
                churn.disable()
            quiet = _phase(quiet_blocks, rate)
            rows.append(emit(
                f"serving_slo/quiet_s{s}", quiet["p50_ms"] * 1e3,
                f"p99={quiet['p99_ms']:.2f}ms|p99.9={quiet['p999_ms']:.2f}"
                f"ms|offered={rate:.0f}/s|goodput="
                f"{quiet['goodput_rps']:.0f}/s"))

            compacting = _phase(comp_blocks, rate)
            mean_build_ms = churn.stop()
            compacting["swap_builds"] = churn.builds
            rows.append(emit(
                f"serving_slo/compacting_s{s}", compacting["p50_ms"] * 1e3,
                f"p99={compacting['p99_ms']:.2f}ms|p99.9="
                f"{compacting['p999_ms']:.2f}ms|goodput="
                f"{compacting['goodput_rps']:.0f}/s|builds={churn.builds}"))

            # gate on the median of per-block p99s (see _phase): robust
            # to a container freeze corrupting one block of either phase
            ratio = (compacting["p99_med_ms"]
                     / max(quiet["p99_med_ms"], 1e-9))
            pooled = compacting["p99_ms"] / max(quiet["p99_ms"], 1e-9)
            rows.append(emit(f"serving_slo/p99_ratio_s{s}", 0.0,
                             f"{ratio:.2f}|pooled={pooled:.2f}"))
            # stall gate: no query may wait out a whole build-to-build
            # interval — if one did, a swap build blocked the query lane
            # instead of running beside it
            interval_ms = compacting["wall_s"] * 1e3 / max(churn.builds, 1)
            stall_ratio = compacting["max_ms"] / max(interval_ms, 1e-9)
            within = compacting["max_ms"] <= max(interval_ms, 1.0)
            rows.append(emit(
                f"serving_slo/stall_s{s}", compacting["max_ms"] * 1e3,
                f"interval={interval_ms:.1f}ms|build={mean_build_ms:.1f}ms|"
                f"ratio={stall_ratio:.2f}|{'ok' if within else 'STALL'}"))
            st = sched.stats
            rows.append(emit(
                f"serving_slo/coalesce_s{s}", DEADLINE_MS * 1e3,
                f"mean_batch={st.mean_batch:.1f}|batches={st.batches}"))
            traj["shards"][str(s)] = {
                "build_us": build_us, "max_batch": max_batch,
                "offered_rps": rate, "capacity_rps": cap_rps,
                "quiet": quiet, "compacting": compacting,
                "p99_ratio": ratio,
                "mean_swap_build_ms": mean_build_ms,
                "swap_interval_ms": interval_ms,
                "max_stall_ms": compacting["max_ms"],
                "stall_within_interval": bool(within),
                "coalesce_mean_batch": st.mean_batch,
            }
    append_trajectory(traj)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
