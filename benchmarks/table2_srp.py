"""Paper Table 2: LSH for cosine similarity on tensor data.

Same protocol as table1 for SRP / CP-SRP / TT-SRP.
CSV: name,us_per_call,derived (derived = projection storage in scalars).
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import cp_random_data, make_family, tt_random_data

K, RANK, RHAT = 16, 4, 4


def run(n_sweep=(2, 3, 4), d: int = 16) -> list[str]:
    rows = []
    key = jax.random.PRNGKey(1)
    for n in n_sweep:
        dims = (d,) * n
        kx, kf = jax.random.split(jax.random.fold_in(key, n))
        x_cp = cp_random_data(kx, dims, RHAT)
        x_tt = tt_random_data(kx, dims, RHAT)
        for kind, x in (("srp-naive", x_cp), ("cp-srp", x_cp),
                        ("tt-srp", x_cp), ("cp-srp-ttinput", x_tt),
                        ("tt-srp-ttinput", x_tt)):
            fam = make_family(kf, kind.split("-ttinput")[0].replace(
                "srp-naive", "srp"), dims, num_codes=K, rank=RANK)
            fn = jax.jit(fam.hash)
            us = time_fn(fn, x)
            rows.append(emit(f"table2/{kind}/N{n}d{d}", us,
                             fam.storage_size()))
    return rows


if __name__ == "__main__":
    run()
