"""Streaming-mutation benchmark: insert throughput vs full rebuild, delete
cost, post-insert serving (QPS at batch 1024 + recall@10 against the
effective corpus), and compaction, on an n=100k corpus (CPU-friendly).

The acceptance bar for the segment store is that absorbing a batch of
inserts costs >= 10x less than the O(n log n) full rebuild it replaces
(`index_mut/insert_speedup`): an insert hashes + sorts only the batch into
a delta segment, while a rebuild re-hashes and re-sorts the whole corpus.

CSV rows (name,us_per_call,derived):

  index_mut/build                us = full build wall time, derived = n
  index_mut/rebuild              us = warm full rebuild (the cost an insert
                                 avoids), derived = n
  index_mut/insert_b{B}          us = per insert batch (median), derived =
                                 items/s
  index_mut/insert_speedup       derived = rebuild_us / insert_us (>= 10)
  index_mut/delete_b{B}          us = per tombstone batch
  index_mut/qps_post_insert_b1024   us = per-query latency, derived = QPS
                                 with outstanding delta segments
  index_mut/recall10_post_insert derived = recall@10 | mean candidates vs
                                 the mutated (effective) corpus
  index_mut/compact              us = compaction wall time, derived = n_live
  index_mut/qps_post_compact_b1024  us = per-query latency, derived = QPS

``run()`` appends a trajectory entry to BENCH_index.json at the repo root
(tagged ``"bench": "index_mutation"``) so later PRs can compare. Set
BENCH_MUT_N to shrink the corpus for smoke runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core import DeviceLSHIndex, make_family, recall_at_k

DIMS = (8, 8, 8)
N_CORPUS = int(os.environ.get("BENCH_MUT_N", 100_000))
PER_CLUSTER = 8               # clustered corpus: real neighbors (see
NOISE = 0.15                  # benchmarks/index_qps.py)
INSERT_BATCH = 1024
N_INSERTS = 6                 # timed insert batches (after 1 warmup)
DELETE_BATCH = 1024
QUERY_BATCH = 1024
N_RECALL_QUERIES = 64
BUCKET_CAP = 64               # bound probe width at this corpus scale

def _data():
    kc, kn, kq, ki, kf = jax.random.split(jax.random.PRNGKey(23), 5)
    n_clusters = max(N_CORPUS // PER_CLUSTER, 1)
    centers = jax.random.normal(kc, (n_clusters,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)[:N_CORPUS]
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = (jnp.tile(centers, (QUERY_BATCH // n_clusters + 1,)
                        + (1,) * len(DIMS))[:QUERY_BATCH]
               + NOISE * jax.random.normal(kq, (QUERY_BATCH,) + DIMS))
    # inserts join existing clusters (streamed corpus churn, not outliers)
    n_ins = (N_INSERTS + 1) * INSERT_BATCH
    inserts = (jnp.tile(centers, (n_ins // n_clusters + 1,)
                        + (1,) * len(DIMS))[:n_ins]
               + NOISE * jax.random.normal(ki, (n_ins,) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    return corpus, queries, inserts, fam


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def run() -> list[str]:
    rows = []
    corpus, queries, inserts, fam = _data()
    make_index = lambda: DeviceLSHIndex(
        fam, metric="euclidean", bucket_cap=BUCKET_CAP,
        max_deltas=N_INSERTS + 2)   # no auto-compact inside the timed loop

    idx = make_index()
    build_us = _timed(lambda: jax.block_until_ready(
        idx.build(corpus).sorted_keys))
    rows.append(emit("index_mut/build", build_us, N_CORPUS))
    # warm rebuild: every jit program is compiled now, so this is the pure
    # hash + sort cost a streaming insert competes against
    rebuild_us = _timed(lambda: jax.block_until_ready(
        make_index().build(corpus).sorted_keys))
    rows.append(emit("index_mut/rebuild", rebuild_us, N_CORPUS))

    # streaming inserts: one warmup batch compiles the delta-build programs,
    # then each timed batch appends one more delta segment
    batches = [jax.lax.dynamic_slice_in_dim(inserts, i * INSERT_BATCH,
                                            INSERT_BATCH)
               for i in range(N_INSERTS + 1)]
    jax.block_until_ready(
        idx.insert(batches[0]).store.deltas[-1].sorted_keys)
    insert_times = []
    for b in batches[1:]:
        insert_times.append(_timed(lambda b=b: jax.block_until_ready(
            idx.insert(b).store.deltas[-1].sorted_keys)))
    insert_us = sorted(insert_times)[len(insert_times) // 2]
    rows.append(emit(f"index_mut/insert_b{INSERT_BATCH}", insert_us,
                     f"{INSERT_BATCH / (insert_us / 1e6):.0f}"))
    rows.append(emit("index_mut/insert_speedup", 0.0,
                     f"{rebuild_us / insert_us:.1f}x"))

    # streaming deletes: tombstone a spread of effective ids (mask flip +
    # effective-id recompute, no device rebuild)
    rng = np.random.default_rng(7)
    dead = rng.choice(idx.size, size=DELETE_BATCH, replace=False)
    delete_us = _timed(lambda: idx.delete(dead))
    rows.append(emit(f"index_mut/delete_b{DELETE_BATCH}", delete_us,
                     DELETE_BATCH))

    # serving with outstanding deltas + tombstones
    us = time_fn(lambda qb: idx.query_batch(qb, topk=10),
                 queries[:QUERY_BATCH], warmup=1, iters=5)
    rows.append(emit(f"index_mut/qps_post_insert_b{QUERY_BATCH}",
                     us / QUERY_BATCH,
                     f"{QUERY_BATCH / (us / 1e6):.0f}"))
    post_insert_qps = QUERY_BATCH / (us / 1e6)
    stats = recall_at_k(idx, queries[:N_RECALL_QUERIES], topk=10)
    rows.append(emit(
        "index_mut/recall10_post_insert", 0.0,
        f"{stats['recall']:.3f}|{stats['mean_candidates']:.0f}"))

    # compaction folds everything back into one base segment
    compact_us = _timed(lambda: jax.block_until_ready(
        idx.compact().sorted_keys))
    rows.append(emit("index_mut/compact", compact_us, idx.size))
    us = time_fn(lambda qb: idx.query_batch(qb, topk=10),
                 queries[:QUERY_BATCH], warmup=1, iters=5)
    rows.append(emit(f"index_mut/qps_post_compact_b{QUERY_BATCH}",
                     us / QUERY_BATCH,
                     f"{QUERY_BATCH / (us / 1e6):.0f}"))

    append_trajectory({
        "bench": "index_mutation",
        "n_devices": len(jax.devices()),
        "corpus_n": N_CORPUS,
        "insert_batch": INSERT_BATCH,
        "build_s": build_us / 1e6,
        "rebuild_s": rebuild_us / 1e6,
        "insert_batch_s": insert_us / 1e6,
        "insert_speedup_vs_rebuild": round(rebuild_us / insert_us, 1),
        "insert_items_per_s": round(INSERT_BATCH / (insert_us / 1e6)),
        "qps_post_insert_b1024": round(post_insert_qps),
        "recall10_post_insert": round(stats["recall"], 4),
        "compact_s": compact_us / 1e6,
        "qps_post_compact_b1024": round(QUERY_BATCH / (us / 1e6)),
    })
    return rows


if __name__ == "__main__":
    run()
