"""Sustained-ingest benchmark for the shard-native mutation plane:
interleaved insert / delete / query rounds at S in {1, 2, 4} shards on an
n=100k corpus (CPU-friendly), reporting

  - delta memory vs the replicated-delta baseline (PR 3 kept one flat copy
    of every delta segment per shard; routed slabs store each item exactly
    once, so the aggregate delta footprint should shrink ~S x),
  - shard-local ``compact()`` wall time vs the global-gather fold
    (``rebalance()`` is exactly PR 3's compact path: gather every survivor,
    re-partition contiguously — so the pair measures what going shard-local
    buys at steady state),
  - mid-ingest serving QPS and post-ingest recall@10 against the effective
    corpus.

CSV rows (name,us_per_call,derived), per shard count S:

  index_ingest/build_s{S}             us = full build, derived = n
  index_ingest/insert_b{B}_s{S}       us = per routed insert batch (median),
                                      derived = items/s
  index_ingest/delete_b{D}_s{S}       us = per tombstone batch (median)
  index_ingest/qps_mid_ingest_s{S}    us = per-query latency with
                                      outstanding slabs, derived = QPS
  index_ingest/delta_mem_s{S}         derived = slab MiB | replicated MiB
                                      (the S x baseline) | ratio
  index_ingest/compact_local_s{S}     us = shard-local fold, derived = n_live
  index_ingest/compact_global_s{S}    us = global gather + re-partition
                                      (the PR 3 compact), derived = n_live
  index_ingest/recall10_s{S}          derived = recall@10 | mean candidates

``run()`` appends one trajectory entry to BENCH_index.json (tagged
``"bench": "index_ingest"``). Set BENCH_INGEST_N to shrink for smoke runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core import ShardedLSHIndex, make_family, recall_at_k
from repro.core.segments import ShardedSegment

DIMS = (8, 8, 8)
N_CORPUS = int(os.environ.get("BENCH_INGEST_N", 100_000))
PER_CLUSTER = 8               # clustered corpus: real neighbors (see
NOISE = 0.15                  # benchmarks/index_qps.py)
SHARD_COUNTS = (1, 2, 4)
INSERT_BATCH = 1024
DELETE_BATCH = 256
QUERY_BATCH = 256
N_ROUNDS = 4                  # timed ingest rounds (after 1 warmup round)
N_RECALL_QUERIES = 64
BUCKET_CAP = 64               # bound probe width at this corpus scale


def _data():
    kc, kn, kq, ki, kf = jax.random.split(jax.random.PRNGKey(29), 5)
    n_clusters = max(N_CORPUS // PER_CLUSTER, 1)
    centers = jax.random.normal(kc, (n_clusters,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)[:N_CORPUS]
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = (jnp.tile(centers, (QUERY_BATCH // n_clusters + 1,)
                        + (1,) * len(DIMS))[:QUERY_BATCH]
               + NOISE * jax.random.normal(kq, (QUERY_BATCH,) + DIMS))
    n_ins = (N_ROUNDS + 1) * INSERT_BATCH
    inserts = (jnp.tile(centers, (n_ins // n_clusters + 1,)
                        + (1,) * len(DIMS))[:n_ins]
               + NOISE * jax.random.normal(ki, (n_ins,) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    return corpus, queries, inserts, fam


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def _tree_bytes(tree) -> int:
    return sum(a.nbytes for a in jax.tree.leaves(tree))


def _delta_bytes(store) -> tuple[int, int]:
    """-> (actual slab bytes, replicated-baseline bytes). Actual sums every
    delta's device arrays (keys + sorted keys + perm + corpus + lookups,
    padding included). The baseline is what PR 3's replicated layout would
    hold for the same items: one flat copy of each delta on every shard."""
    shards = store.base.shards if isinstance(store.base, ShardedSegment) \
        else 1
    actual = replicated = 0
    for i, seg in enumerate(store.deltas):
        live, eff = store._luts[1 + i]
        win = store._wins[1 + i]            # live-window luts (capped store)
        seg_bytes = (_tree_bytes((seg.keys, seg.sorted_keys, seg.perm))
                     + _tree_bytes(seg.corpus)
                     + live.nbytes + eff.nbytes
                     + (_tree_bytes(win) if win is not None else 0))
        actual += seg_bytes
        m = seg.items
        per_slot = seg_bytes // max(seg.slots, 1)   # same dtypes, no pads
        replicated += shards * m * per_slot
    return actual, replicated


def _ingest(idx, inserts, deletes_rng, queries=None, timings=None):
    """One warmup + N_ROUNDS timed rounds of insert -> delete [-> query].
    The warmup round pays the slab scatter+sort compile; quantized slab
    widths keep the later rounds on the cached program. Query timing uses
    one warmup call per round (the program changes as slabs accumulate),
    so it reports steady-state serving at that delta depth."""
    for r in range(N_ROUNDS + 1):
        batch = jax.lax.dynamic_slice_in_dim(
            inserts, r * INSERT_BATCH, INSERT_BATCH)
        t = _timed(lambda: jax.block_until_ready(
            idx.insert(batch).store.deltas[-1].sorted_keys))
        dead = deletes_rng.choice(idx.size, size=DELETE_BATCH, replace=False)
        td = _timed(lambda: idx.delete(dead))
        if queries is not None:
            tq = time_fn(lambda qb: idx.query_batch(qb, topk=10),
                         queries[:QUERY_BATCH], warmup=1, iters=2)
        if timings is not None and r > 0:   # round 0 pays the compiles
            timings["insert"].append(t)
            timings["delete"].append(td)
            timings["query"].append(tq)


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def run() -> list[str]:
    rows = []
    corpus, queries, inserts, fam = _data()
    traj = {"bench": "index_ingest", "n_devices": len(jax.devices()),
            "corpus_n": N_CORPUS, "insert_batch": INSERT_BATCH,
            "delete_batch": DELETE_BATCH, "rounds": N_ROUNDS, "shards": {}}
    for s in SHARD_COUNTS:
        make_index = lambda: ShardedLSHIndex(
            fam, metric="euclidean", shards=s, bucket_cap=BUCKET_CAP,
            max_deltas=2 * (N_ROUNDS + 2))  # no auto-compact mid-loop
        idx = make_index()
        build_us = _timed(lambda: jax.block_until_ready(
            idx.build(corpus).sorted_keys))
        rows.append(emit(f"index_ingest/build_s{s}", build_us, N_CORPUS))

        timings = {"insert": [], "delete": [], "query": []}
        _ingest(idx, inserts, np.random.default_rng(7), queries, timings)
        insert_us = _median(timings["insert"])
        rows.append(emit(f"index_ingest/insert_b{INSERT_BATCH}_s{s}",
                         insert_us,
                         f"{INSERT_BATCH / (insert_us / 1e6):.0f}"))
        rows.append(emit(f"index_ingest/delete_b{DELETE_BATCH}_s{s}",
                         _median(timings["delete"]), DELETE_BATCH))
        query_us = _median(timings["query"])
        rows.append(emit(f"index_ingest/qps_mid_ingest_s{s}",
                         query_us / QUERY_BATCH,
                         f"{QUERY_BATCH / (query_us / 1e6):.0f}"))

        actual_b, repl_b = _delta_bytes(idx.store)
        ratio = repl_b / max(actual_b, 1)
        rows.append(emit(
            f"index_ingest/delta_mem_s{s}", 0.0,
            f"{actual_b / 2**20:.1f}MiB|repl {repl_b / 2**20:.1f}MiB|"
            f"{ratio:.2f}x"))

        stats = recall_at_k(idx, queries[:N_RECALL_QUERIES], topk=10)
        rows.append(emit(
            f"index_ingest/recall10_s{s}", 0.0,
            f"{stats['recall']:.3f}|{stats['mean_candidates']:.0f}"))

        # shard-local compact vs the PR 3 global-gather fold (rebalance IS
        # that path: gather every survivor, re-partition contiguously).
        # Replaying the identical ingest on clones gives both folds the
        # same store; the first execution of each pays its compile, so the
        # reported numbers come from a second, warm clone.
        def _clone():
            c = make_index()
            jax.block_until_ready(c.build(corpus).sorted_keys)
            _ingest(c, inserts, np.random.default_rng(7))
            return c

        _timed(lambda: jax.block_until_ready(      # compile the local fold
            idx.compact().sorted_keys))
        n_live = idx.size
        del idx
        warm = _clone()
        local_us = _timed(lambda: jax.block_until_ready(
            warm.compact().sorted_keys))
        del warm
        cold = _clone()
        _timed(lambda: jax.block_until_ready(      # compile the global fold
            cold.rebalance().sorted_keys))
        del cold
        warm = _clone()
        global_us = _timed(lambda: jax.block_until_ready(
            warm.rebalance().sorted_keys))
        del warm
        rows.append(emit(f"index_ingest/compact_local_s{s}", local_us,
                         n_live))
        rows.append(emit(f"index_ingest/compact_global_s{s}", global_us,
                         n_live))

        traj["shards"][str(s)] = {
            "build_s": build_us / 1e6,
            "insert_batch_s": insert_us / 1e6,
            "insert_items_per_s": round(INSERT_BATCH / (insert_us / 1e6)),
            "qps_mid_ingest": round(QUERY_BATCH / (query_us / 1e6)),
            "delta_mem_mib": round(actual_b / 2**20, 2),
            "delta_mem_replicated_mib": round(repl_b / 2**20, 2),
            "delta_mem_ratio": round(ratio, 2),
            "compact_local_s": local_us / 1e6,
            "compact_global_s": global_us / 1e6,
            "compact_speedup": round(global_us / max(local_us, 1), 2),
            "recall10_post_ingest": round(stats["recall"], 4),
        }
    append_trajectory(traj)
    return rows


if __name__ == "__main__":
    run()
