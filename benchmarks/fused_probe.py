"""Fused query-to-candidates A/B: the restructured segment-major XLA
schedule (``probe_backend='xla'``) vs the reference planner, end to end at
batch 1024 on the bench corpus (the PR 8 / index_qps shape: clustered
n=4096, cp-e2lsh L=8 K=4).

CSV rows (name,us_per_call,derived):

  fused_probe/qps_reference_b1024   us = per-query latency, derived = QPS
  fused_probe/qps_xla_b1024         us = per-query latency, derived = QPS
  fused_probe/speedup_b1024         derived = xla QPS / reference QPS
  fused_probe/bit_identical         derived = 1 iff ids, score bit
                                    patterns, and candidate counts all
                                    match the reference planner
  fused_probe/qps_pallas_b64        us = per-query latency (interpret
                                    mode — a semantics row, not a perf
                                    row; the TPU lowering is the target)

The speedup row is the acceptance gate of the fused-probe work: the
restructured schedule (one fused scan over segments, keys kept between
searchsorted and gather, hoisted per-row norms, packed top-k selection)
must clear 1.3x over the reference planner on CPU. The Pallas fused
kernel runs interpret mode here, so its row only proves the program
composes at batch size; bit-identity for it is pinned by
tests/test_fused_probe.py across the full layout grid.

``run()`` appends a trajectory entry to BENCH_index.json (tagged
``"bench": "fused_probe"``); runnable standalone
(``make bench-fused-probe``) or via ``python -m benchmarks.run``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core import DeviceLSHIndex, make_family
from repro.core import segments

DIMS = (8, 8, 8)
N_CLUSTERS, PER_CLUSTER = 512, 8
N_CORPUS = N_CLUSTERS * PER_CLUSTER
NOISE = 0.15
B = 1024
B_PALLAS = 64          # interpret mode: keep the semantics row cheap
TOPK = 10
SPEEDUP_GATE = 1.3


def run() -> list[str]:
    rows = []
    kc, kn, kq, kf = jax.random.split(jax.random.PRNGKey(11), 4)
    centers = jax.random.normal(kc, (N_CLUSTERS,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = (jnp.tile(centers, (B // N_CLUSTERS + 1,) + (1,) * len(DIMS))
               [:B] + NOISE * jax.random.normal(kq, (B,) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    idx = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
    view = idx.store.view
    mults = jnp.asarray(idx._mults)

    ref = lambda q: segments.segmented_query_reference(
        fam, view.all_arrays, mults, q, metric="euclidean", topk=TOPK,
        caps=view.all_caps)
    xla = lambda q: segments.segmented_query(
        fam, view.all_arrays, mults, q, metric="euclidean", topk=TOPK,
        caps=view.all_caps, probe_backend="xla")

    r = jax.block_until_ready(ref(queries))
    n = jax.block_until_ready(xla(queries))
    identical = int(bool(jnp.all(r[0] == n[0]))
                    and bool(jnp.all(r[1].view(jnp.int32)
                                     == n[1].view(jnp.int32)))
                    and bool(jnp.all(r[2] == n[2])))

    us_ref = time_fn(ref, queries, iters=7)
    us_xla = time_fn(xla, queries, iters=7)
    qps_ref = B / (us_ref / 1e6)
    qps_xla = B / (us_xla / 1e6)
    speedup = qps_xla / qps_ref
    rows.append(emit("fused_probe/qps_reference_b1024", us_ref / B,
                     f"{qps_ref:.1f}"))
    rows.append(emit("fused_probe/qps_xla_b1024", us_xla / B,
                     f"{qps_xla:.1f}"))
    rows.append(emit("fused_probe/speedup_b1024", us_xla / B,
                     f"{speedup:.2f}"))
    rows.append(emit("fused_probe/bit_identical", us_xla / B,
                     f"{identical}"))
    if speedup < SPEEDUP_GATE:
        print(f"# WARNING fused_probe/speedup_b1024 {speedup:.2f} below "
              f"the {SPEEDUP_GATE}x gate", flush=True)

    qp = queries[:B_PALLAS]
    pal = lambda q: segments.segmented_query(
        fam, view.all_arrays, mults, q, metric="euclidean", topk=TOPK,
        caps=view.all_caps, probe_backend="pallas")
    p = jax.block_until_ready(pal(qp))
    rp = jax.block_until_ready(ref(qp))
    pal_ok = int(bool(jnp.all(p[0] == rp[0]))
                 and bool(jnp.all(p[1].view(jnp.int32)
                                  == rp[1].view(jnp.int32))))
    us_pal = time_fn(pal, qp, iters=3)
    rows.append(emit("fused_probe/qps_pallas_b64", us_pal / B_PALLAS,
                     f"{pal_ok}"))

    append_trajectory({
        "bench": "fused_probe",
        "n": N_CORPUS,
        "batch": B,
        "qps_reference": round(qps_ref, 1),
        "qps_xla": round(qps_xla, 1),
        "speedup": round(speedup, 3),
        "bit_identical": bool(identical),
        "pallas_bit_identical_b64": bool(pal_ok),
        "interpret": jax.default_backend() != "tpu",
    })
    return rows


if __name__ == "__main__":
    run()
