"""Device-resident vs host-index LSH A/B: build time, QPS at batch sizes
{1, 64, 1024}, and recall@10 parity (same family => same buckets).

CSV rows (name,us_per_call,derived):

  index/build_{host,device}        us = build wall time, derived = corpus n
  index/qps_device_b{1,64,1024}    us = per-query latency, derived = QPS
  index/qps_host_b1024             us = per-query latency, derived = QPS
  index/speedup_b1024              derived = device QPS / host QPS
  index/recall10_{host,device}     derived = recall@10 | mean candidates

The device index is built with the default exact bucket cap, so both
indexes probe identical candidate sets and recall@10 must match exactly.
Since the segment refactor HostLSHIndex serves queries through the same
shared planner (its dict tables remain the membership reference and
dominate its build row), so the host QPS rows measure the one-query-at-a-
time serving loop and speedup_b1024 is the batch-amortization win of the
single jit-compiled batched program.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import (DeviceLSHIndex, HostLSHIndex, make_family,
                        recall_at_k)

DIMS = (8, 8, 8)
N_CLUSTERS, PER_CLUSTER = 512, 8           # clustered corpus: real neighbors
N_CORPUS = N_CLUSTERS * PER_CLUSTER
NOISE = 0.15
N_RECALL_QUERIES = 128
BATCH_SIZES = (1, 64, 1024)


def _timed_build(cls, fam, corpus):
    t0 = time.perf_counter()
    idx = cls(fam, metric="euclidean").build(corpus)
    return idx, (time.perf_counter() - t0) * 1e6


def run() -> list[str]:
    rows = []
    kc, kn, kq, kf = jax.random.split(jax.random.PRNGKey(11), 4)
    centers = jax.random.normal(kc, (N_CLUSTERS,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    queries = (jnp.tile(centers, (max(BATCH_SIZES) // N_CLUSTERS + 1,)
                        + (1,) * len(DIMS))[:max(BATCH_SIZES)]
               + NOISE * jax.random.normal(kq, (max(BATCH_SIZES),) + DIMS))
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)

    host, host_build_us = _timed_build(HostLSHIndex, fam, corpus)
    device, dev_build_us = _timed_build(DeviceLSHIndex, fam, corpus)
    rows.append(emit("index/build_host", host_build_us, N_CORPUS))
    rows.append(emit("index/build_device", dev_build_us, N_CORPUS))

    # device QPS across batch sizes (jit warmup excluded, median timing)
    for b in BATCH_SIZES:
        us = time_fn(lambda qb: device.query_batch(qb, topk=10),
                     queries[:b], warmup=1, iters=5)
        dt = us / 1e6
        rows.append(emit(f"index/qps_device_b{b}", dt / b * 1e6,
                         f"{b / dt:.0f}"))
        if b == max(BATCH_SIZES):
            device_qps = b / dt

    # host QPS at the largest batch (one pass; the per-query loop is slow)
    b = max(BATCH_SIZES)
    host.query(queries[0], topk=10)  # warm the jitted hash
    t0 = time.perf_counter()
    for i in range(b):
        host.query(queries[i], topk=10)
    dt = time.perf_counter() - t0
    host_qps = b / dt
    rows.append(emit(f"index/qps_host_b{b}", dt / b * 1e6, f"{host_qps:.0f}"))
    rows.append(emit(f"index/speedup_b{b}", 0.0,
                     f"{device_qps / host_qps:.1f}x"))

    # recall@10 parity on the same seeds
    rq = queries[:N_RECALL_QUERIES]
    for name, idx in (("host", host), ("device", device)):
        t0 = time.perf_counter()
        stats = recall_at_k(idx, rq, topk=10)
        us = (time.perf_counter() - t0) / N_RECALL_QUERIES * 1e6
        rows.append(emit(f"index/recall10_{name}", us,
                         f"{stats['recall']:.3f}|{stats['mean_candidates']:.0f}"))
    return rows


if __name__ == "__main__":
    run()
