"""Paper Table 1: LSH for Euclidean distance on tensor data.

Measures, for K-sized hashcodes of N-order tensors (mode dim d):
  * storage of the projection parameters (paper's space complexity column)
  * time per hashcode batch for inputs given in CP / TT decomposition
    format (paper's time complexity column)
for the naive method (reshape + dense E2LSH), CP-E2LSH and TT-E2LSH.

CSV: name,us_per_call,derived  (derived = projection storage in scalars).
Scaling claims verified: naive storage grows as d^N (exponential in N),
tensorized storage linearly in N — see the N-sweep rows.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import (cp_random_data, make_family, tt_random_data)

K, RANK, RHAT, W = 16, 4, 4, 4.0


def run(n_sweep=(2, 3, 4), d: int = 16) -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    for n in n_sweep:
        dims = (d,) * n
        kx, kf = jax.random.split(jax.random.fold_in(key, n))
        x_cp = cp_random_data(kx, dims, RHAT)
        x_tt = tt_random_data(kx, dims, RHAT)

        for kind, x in (("e2lsh-naive", x_cp), ("cp-e2lsh", x_cp),
                        ("tt-e2lsh", x_cp), ("cp-e2lsh-ttinput", x_tt),
                        ("tt-e2lsh-ttinput", x_tt)):
            fam = make_family(kf, kind.split("-ttinput")[0].replace(
                "e2lsh-naive", "e2lsh"), dims, num_codes=K, rank=RANK,
                bucket_width=W)
            fn = jax.jit(fam.hash)
            us = time_fn(fn, x)
            rows.append(emit(f"table1/{kind}/N{n}d{d}", us,
                             fam.storage_size()))
    return rows


if __name__ == "__main__":
    run()
