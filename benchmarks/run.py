"""Benchmark harness: one module per paper table + validation benches.
Prints ``name,us_per_call,derived`` CSV rows (stdout)."""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (collision, durability, fused_probe,
                            hash_throughput, index_ingest,
                            index_multiprobe, index_mutation, index_qps,
                            index_sharded, kernels, recall, serving_slo,
                            table1_e2lsh, table2_srp)
    print("name,us_per_call,derived")
    rows = []
    rows += table1_e2lsh.run()
    rows += table2_srp.run()
    rows += collision.run()
    rows += recall.run()
    rows += index_qps.run()
    rows += index_multiprobe.run()
    rows += fused_probe.run()
    rows += index_sharded.run()
    rows += index_mutation.run()
    rows += index_ingest.run()
    rows += serving_slo.run()
    rows += durability.run()
    rows += hash_throughput.run()
    rows += kernels.run()
    print(f"# {len(rows)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
