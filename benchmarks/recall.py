"""End-to-end ANN quality: recall@1 and candidate-set pruning of the
multi-table index built on each of the paper's families, on a corpus with
planted near-duplicates.

CSV: name,us_per_call,derived (derived = recall@1|mean_candidate_fraction).
us_per_call is the per-query latency (hash + bucket + exact re-rank).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import LSHIndex, make_family, recall_at_k

DIMS = (8, 8, 8)
N_CORPUS, N_QUERIES = 2000, 25


def run() -> list[str]:
    rows = []
    kc, kq, kf = jax.random.split(jax.random.PRNGKey(3), 3)
    corpus = jax.random.normal(kc, (N_CORPUS,) + DIMS)
    queries = corpus[:N_QUERIES] + 0.05 * jax.random.normal(
        kq, (N_QUERIES,) + DIMS)

    for kind, metric in (("cp-e2lsh", "euclidean"), ("tt-e2lsh", "euclidean"),
                         ("cp-srp", "cosine"), ("tt-srp", "cosine"),
                         ("e2lsh", "euclidean"), ("srp", "cosine")):
        k, l = (6, 8) if "e2lsh" in kind else (10, 8)
        fam = make_family(kf, kind, DIMS, num_codes=k, num_tables=l, rank=2,
                          bucket_width=6.0)
        idx = LSHIndex(fam, metric=metric).build(corpus)
        t0 = time.perf_counter()
        stats = recall_at_k(idx, queries, topk=1)
        us = (time.perf_counter() - t0) / N_QUERIES * 1e6
        frac = stats["mean_candidates"] / N_CORPUS
        rows.append(emit(f"recall/{kind}", us,
                         f"{stats['recall']:.2f}|{frac:.4f}"))
    return rows


if __name__ == "__main__":
    run()
