"""End-to-end ANN quality: recall@1 and candidate-set pruning of the
multi-table index built on each of the paper's families, on a corpus with
planted near-duplicates. Each family runs A/B through the device-resident
batched index and the host-dict reference index (identical buckets).

CSV: name,us_per_call,derived (derived = recall@1|mean_candidate_fraction).
us_per_call is the per-query latency (hash + bucket + exact re-rank).
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import (DeviceLSHIndex, HostLSHIndex, brute_force_batch,
                        make_family)

DIMS = (8, 8, 8)
N_CORPUS, N_QUERIES = 2000, 25


def run() -> list[str]:
    rows = []
    kc, kq, kf = jax.random.split(jax.random.PRNGKey(3), 3)
    corpus = jax.random.normal(kc, (N_CORPUS,) + DIMS)
    queries = corpus[:N_QUERIES] + 0.05 * jax.random.normal(
        kq, (N_QUERIES,) + DIMS)

    for kind, metric in (("cp-e2lsh", "euclidean"), ("tt-e2lsh", "euclidean"),
                         ("cp-srp", "cosine"), ("tt-srp", "cosine"),
                         ("e2lsh", "euclidean"), ("srp", "cosine")):
        k, l = (6, 8) if "e2lsh" in kind else (10, 8)
        fam = make_family(kf, kind, DIMS, num_codes=k, num_tables=l, rank=2,
                          bucket_width=6.0)
        truth = brute_force_batch(metric, queries, corpus, topk=1)[0]
        # shared, untimed ground truth: one batched score matrix
        for label, cls in (("device", DeviceLSHIndex), ("host", HostLSHIndex)):
            idx = cls(fam, metric=metric).build(corpus)
            idx.query(queries[0], topk=1)  # warm the jit cache before timing
            t0 = time.perf_counter()
            results = [idx.query(queries[i], topk=1) for i in range(N_QUERIES)]
            us = (time.perf_counter() - t0) / N_QUERIES * 1e6
            hits = sum(len(set(t.tolist()) & set(ids.tolist()))
                       for t, (ids, _, _) in zip(truth, results))
            cand = sum(nc for _, _, nc in results)
            frac = cand / N_QUERIES / N_CORPUS
            rows.append(emit(f"recall/{kind}/{label}", us,
                             f"{hits / N_QUERIES:.2f}|{frac:.4f}"))
    return rows


if __name__ == "__main__":
    run()
