"""Benchmark helpers: jit + block_until_ready timing, CSV emission, and the
BENCH_index.json trajectory append shared by the index/hash/kernel benches."""

from __future__ import annotations

import json
import os
import time

import jax

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_index.json")


def append_trajectory(entry: dict) -> None:
    """Append one benchmark entry to the repo-root BENCH_index.json history
    (created if missing, reset if unreadable)."""
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    with open(TRAJECTORY, "w") as f:
        json.dump(history, f, indent=1)


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str | float) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
