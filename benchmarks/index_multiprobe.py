"""The (L, T) trade-off of query-directed multi-probe: recall@10 and QPS
over a num_tables x probes grid on a clustered corpus.

Multi-probe exists to shrink L — the dominant per-chip memory cost (each
table stores a full sorted key/permutation copy of the corpus) — by
probing the T most promising buckets per remaining table
(``repro.core.probing``). This bench sweeps L in {2, 4, 8} x T in
{1, 4, 8} with the same cp-e2lsh family seed and reports, per cell,
recall@10 against brute force, mean candidates per query, and batched
QPS, plus the headline comparison the tier-1 recall pin
(tests/test_multiprobe.py::TestRecallTradeoff) enforces: (L=2, T=8) vs
(L=8, T=1).

CSV rows (name,us_per_call,derived):

  index_mp/recall_L{l}_T{t}   derived = recall@10 | mean candidates
  index_mp/qps_L{l}_T{t}      us = per-query latency, derived = QPS
  index_mp/headline           derived = recall(L2,T8) - recall(L8,T1)

``run()`` appends a trajectory entry to BENCH_index.json (tagged
``"bench": "index_multiprobe"``). BENCH_MP_N shrinks the corpus for smoke
runs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core import DeviceLSHIndex, make_family, recall_at_k

DIMS = (8, 8, 8)
PER_CLUSTER = 8
N_CORPUS = int(os.environ.get("BENCH_MP_N", 4096))
N_CLUSTERS = max(N_CORPUS // PER_CLUSTER, 1)
NOISE = 0.15
N_RECALL_QUERIES = 128
QUERY_BATCH = 1024
TABLE_COUNTS = (2, 4, 8)
PROBE_COUNTS = (1, 4, 8)


def run() -> list[str]:
    rows = []
    kc, kn, kq, kf = jax.random.split(jax.random.PRNGKey(7), 4)
    centers = jax.random.normal(kc, (N_CLUSTERS,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)
              + NOISE * jax.random.normal(
                  kn, (N_CLUSTERS * PER_CLUSTER,) + DIMS))
    queries = (jnp.tile(centers, (QUERY_BATCH // N_CLUSTERS + 1,)
                        + (1,) * len(DIMS))[:QUERY_BATCH]
               + NOISE * jax.random.normal(kq, (QUERY_BATCH,) + DIMS))

    recall = {}
    for num_tables in TABLE_COUNTS:
        fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4,
                          num_tables=num_tables, rank=2, bucket_width=16.0)
        index = DeviceLSHIndex(fam, metric="euclidean").build(corpus)
        for probes in PROBE_COUNTS:
            stats = recall_at_k(index, queries[:N_RECALL_QUERIES],
                                topk=10, probes=probes)
            recall[num_tables, probes] = stats["recall"]
            rows.append(emit(
                f"index_mp/recall_L{num_tables}_T{probes}", 0.0,
                f"{stats['recall']:.3f}|{stats['mean_candidates']:.0f}"))
            us = time_fn(
                lambda qb, p=probes: index.query_batch(qb, topk=10,
                                                       probes=p),
                queries, warmup=1, iters=5)
            rows.append(emit(f"index_mp/qps_L{num_tables}_T{probes}",
                             us / QUERY_BATCH,
                             f"{QUERY_BATCH / (us / 1e6):.0f}"))

    headline = recall[2, 8] - recall[8, 1]
    rows.append(emit("index_mp/headline", 0.0, f"{headline:+.3f}"))

    append_trajectory({
        "bench": "index_multiprobe",
        "n_devices": len(jax.devices()),
        "corpus_n": N_CLUSTERS * PER_CLUSTER,
        "kind": "cp-e2lsh",
        "grid": {f"L{l}_T{t}": round(r, 4)
                 for (l, t), r in sorted(recall.items())},
        "headline_L2T8_minus_L8T1": round(headline, 4),
    })
    return rows


if __name__ == "__main__":
    run()
