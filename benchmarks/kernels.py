"""Kernel-level benchmark: the Pallas kernels (interpret mode on CPU; the
TPU lowering is the target) validated against ref.py and timed against the
equivalent XLA path. On CPU interpret mode measures Python-level kernel
semantics, so the number that matters here is the allclose check + the
arithmetic-intensity report used in the §Perf kernel discussion.

CSV: name,us_per_call,derived (derived = max|kernel - ref| ; 'flops/byte'
rows report the kernel's arithmetic intensity at benchmark shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ref
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.tt_inner import tt_inner_pallas
from repro.kernels.srp_pack import srp_pack_pallas


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # CP gram kernel: N=4, d=64, R=32, K=64
    n, d, rx, rp, k = 4, 64, 32, 32, 64
    kx, kp = jax.random.split(key)
    xf = jax.random.normal(kx, (n, d, rx))
    pf = jax.random.normal(kp, (n, k, d, rp))
    got = cp_gram_pallas(xf, pf, block_k=8, interpret=True)
    want = ref.cp_inner_ref(xf, pf)
    err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    us_ref = time_fn(jax.jit(ref.cp_inner_ref), xf, pf)
    rows.append(emit("kernels/cp_gram/allclose", us_ref, f"{err:.2e}"))
    flops = k * n * d * rx * rp * 2
    bytes_ = 4 * (xf.size + pf.size + k)
    rows.append(emit("kernels/cp_gram/intensity", us_ref,
                     f"{flops / bytes_:.2f}"))

    # TT inner kernel: N=4, d=32, R=16, K=32
    n, d, r, k = 4, 32, 16, 32
    xc = jax.random.normal(kx, (n, r, d, r))
    pc = jax.random.normal(kp, (n, k, r, d, r))
    got = tt_inner_pallas(xc, pc, block_k=8, interpret=True)
    want = ref.tt_inner_ref(xc, pc)
    err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    us_ref = time_fn(jax.jit(ref.tt_inner_ref), xc, pc)
    rows.append(emit("kernels/tt_inner/allclose", us_ref, f"{err:.2e}"))
    flops = k * n * d * (r ** 3) * 4
    bytes_ = 4 * (xc.size + pc.size + k)
    rows.append(emit("kernels/tt_inner/intensity", us_ref,
                     f"{flops / bytes_:.2f}"))

    # SRP pack kernel
    v = jax.random.normal(key, (256, 256))
    got = srp_pack_pallas(v, block_b=8, interpret=True)
    want = ref.srp_pack_ref(v)
    err = int(jnp.sum(got != want))
    us_ref = time_fn(jax.jit(ref.srp_pack_ref), v)
    rows.append(emit("kernels/srp_pack/exact", us_ref, f"{err}"))
    return rows


if __name__ == "__main__":
    run()
