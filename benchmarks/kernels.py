"""Kernel-level benchmark: the batch-native Pallas kernels (interpret mode
on CPU; the TPU lowering is the target) validated against ref.py and timed
against the equivalent XLA path. On CPU interpret mode measures Python-level
kernel semantics, so the numbers that matter here are the allclose/exact
checks + the arithmetic-intensity report used in the §Perf kernel
discussion.

CSV: name,us_per_call,derived (derived = max|kernel - ref| for allclose
rows, mismatch count for exact rows; 'flops/byte' rows report the kernel's
arithmetic intensity at benchmark shape — the fused '*_keys' variants also
show the HBM-traffic shrink from emitting (B, L) uint32 keys instead of
(B, L*K) float values).

``run()`` appends a trajectory entry to BENCH_index.json (tagged
``"bench": "kernels"``) so kernel-validation drift is tracked alongside the
index benchmarks; runnable standalone (``make bench-kernels``) or via
``python -m benchmarks.run``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit, time_fn
from repro.core.lsh import _combine_codes, make_mults
from repro.kernels import ref
from repro.kernels.cp_gram import cp_gram_pallas
from repro.kernels.tt_inner import tt_inner_pallas
from repro.kernels.srp_pack import srp_pack_pallas


def run() -> list[str]:
    rows = []
    errs = {}
    key = jax.random.PRNGKey(0)

    # CP gram kernel: B=64, N=4, d=64, R=32, L=8, K=8
    b, n, d, rx, rp, l, k = 64, 4, 64, 32, 32, 8, 8
    kx, kp = jax.random.split(key)
    xf = jax.random.normal(kx, (b, n, d, rx))
    pf = jax.random.normal(kp, (n, l, k, d, rp))
    got = cp_gram_pallas(xf, pf, epilogue="raw", block_l=2, interpret=True)
    want = ref.cp_inner_ref(xf, pf.reshape(n, l * k, d, rp)).reshape(b, l, k)
    err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    us_ref = time_fn(jax.jit(ref.cp_inner_ref), xf,
                     pf.reshape(n, l * k, d, rp))
    rows.append(emit("kernels/cp_gram/allclose", us_ref, f"{err:.2e}"))
    errs["cp_gram_rel_err"] = err
    flops = b * l * k * n * d * rx * rp * 2
    bytes_ = 4 * (xf.size + pf.size + b * l * k)
    rows.append(emit("kernels/cp_gram/intensity", us_ref,
                     f"{flops / bytes_:.2f}"))
    # fused keys epilogue: bit-exact vs the tail oracles composed on the
    # kernel's own raw values (raw accuracy is the allclose row above —
    # composing on the jnp raws would let ulp-level reassociation flip
    # boundary codes and pollute the epilogue check), 4*K fewer out bytes
    mults = make_mults(0, k)
    offs = jax.random.uniform(key, (l, k), minval=0.0, maxval=4.0)
    got_keys = cp_gram_pallas(xf, pf, offs, jnp.asarray(mults)[None],
                              epilogue="e2lsh-keys", w=4.0, block_l=2,
                              interpret=True)
    want_keys = _combine_codes(
        ref.e2lsh_quant_ref(got.reshape(b, l * k), offs.reshape(-1), 4.0)
        .reshape(b, l, k), mults)
    n_bad = int(jnp.sum(got_keys != want_keys))
    rows.append(emit("kernels/cp_gram/fused_keys_exact", us_ref, f"{n_bad}"))
    errs["cp_gram_keys_mismatch"] = n_bad
    bytes_keys = 4 * (xf.size + pf.size + b * l)
    rows.append(emit("kernels/cp_gram/fused_keys_intensity", us_ref,
                     f"{flops / bytes_keys:.2f}"))

    # TT inner kernel: B=32, N=4, d=32, R=16, L=4, K=8
    b, n, d, r, l, k = 32, 4, 32, 16, 4, 8
    xc = jax.random.normal(kx, (b, n, r, d, r))
    pc = jax.random.normal(kp, (n, l, k, r, d, r))
    got = tt_inner_pallas(xc, pc, epilogue="raw", block_l=2, interpret=True)
    want = ref.tt_inner_ref(xc, pc.reshape(n, l * k, r, d, r)).reshape(b, l, k)
    err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    us_ref = time_fn(jax.jit(ref.tt_inner_ref), xc,
                     pc.reshape(n, l * k, r, d, r))
    rows.append(emit("kernels/tt_inner/allclose", us_ref, f"{err:.2e}"))
    errs["tt_inner_rel_err"] = err
    flops = b * l * k * n * d * (r ** 3) * 4
    bytes_ = 4 * (xc.size + pc.size + b * l * k)
    rows.append(emit("kernels/tt_inner/intensity", us_ref,
                     f"{flops / bytes_:.2f}"))
    got_keys = tt_inner_pallas(xc, pc, None, jnp.asarray(make_mults(0, k))[None],
                               epilogue="srp-keys", block_l=2, interpret=True)
    want_keys = _combine_codes((got > 0).astype(jnp.int32), make_mults(0, k))
    n_bad = int(jnp.sum(got_keys != want_keys))
    rows.append(emit("kernels/tt_inner/fused_keys_exact", us_ref, f"{n_bad}"))
    errs["tt_inner_keys_mismatch"] = n_bad

    # Fused-hash block sweep: a few (block_b, block_t) tilings of the
    # in-format CP x CP keys kernel vs the (8, 1) untiled grid, at the
    # L/K/R/d shape the _HASH_BLOCK_DEFAULTS comment in kernels/ops.py
    # documents (B=64 here to keep the interpret-mode run short).
    # Interpret mode times Python-level grid overhead, which is exactly
    # what the tiling removes, so the ratios are meaningful on CPU.
    from repro.core import cp_random_data, make_family
    from repro.kernels import ops

    dims, bb, ll, kk, rr = (8, 8, 8), 64, 8, 4, 2
    fam = make_family(key, "cp-e2lsh", dims, num_codes=kk, num_tables=ll,
                      rank=rr, bucket_width=4.0)
    xs = jax.vmap(lambda s: cp_random_data(s, dims, rr))(
        jax.random.split(kx, bb))
    sweep_mults = jnp.asarray(make_mults(0, kk))
    base_us = None
    for blk_b, blk_t in ((8, 1), (32, 4), (64, 8)):
        f = jax.jit(lambda x, blk_b=blk_b, blk_t=blk_t: ops.fused_hash(
            x, fam.projection, epilogue="keys", kind="cp-e2lsh",
            num_tables=ll, num_codes=kk, offsets=fam.offsets,
            w=fam.bucket_width, mults=sweep_mults,
            block_b=blk_b, block_t=blk_t))
        us = time_fn(f, xs, iters=5)
        if base_us is None:
            base_us = us
        rows.append(emit(f"kernels/fused_hash_cp/blocks_{blk_b}x{blk_t}",
                         us, f"{base_us / us:.2f}x"))
    errs["fused_hash_block_speedup"] = round(base_us / us, 3)

    # SRP pack kernel
    v = jax.random.normal(key, (256, 256))
    got = srp_pack_pallas(v, block_b=8, interpret=True)
    want = ref.srp_pack_ref(v)
    n_bad = int(jnp.sum(got != want))
    us_ref = time_fn(jax.jit(ref.srp_pack_ref), v)
    rows.append(emit("kernels/srp_pack/exact", us_ref, f"{n_bad}"))
    errs["srp_pack_mismatch"] = n_bad

    append_trajectory({
        "bench": "kernels",
        "n_devices": len(jax.devices()),
        "interpret": jax.default_backend() != "tpu",
        **errs,
    })
    return rows


if __name__ == "__main__":
    run()
