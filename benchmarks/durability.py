"""Durability overhead + recovery-time benchmark for the mutable index.

Replays the bench-ingest workload (clustered n=100k corpus, 1024-item
insert batches, cp-e2lsh K=4 x 8 tables) through a plain ``LSHService``
and a ``DurableLSHService`` writing its WAL to a scratch directory, then
measures crash recovery (latest snapshot + log-suffix replay). The
acceptance gate this feeds: WAL-on insert throughput within 10% of
WAL-off.

CSV rows (name,us_per_call,derived):

  durability/insert_wal_off_b{B}   us = per insert batch (median),
                                   derived = items/s
  durability/insert_wal_on_b{B}    us = same batches, WAL fsync'd per
                                   append, derived = items/s|+X.X%
  durability/wal_append            us = caller-visible WAL commit time per
                                   record (the append + fsync overlap the
                                   in-memory apply; this is begin() plus
                                   the finish() wait), derived = records
  durability/snapshot              us = one atomic full-store snapshot,
                                   derived = n items
  durability/recover               us = snapshot load + replay of the
                                   log suffix, derived = records replayed

``run()`` appends one trajectory entry to BENCH_index.json (tagged
``"bench": "durability"``). Set BENCH_RECOVERY_N to shrink for smoke
runs.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, emit
from repro.core import make_family
from repro.serving.durability import DurableLSHService
from repro.serving.lsh_service import LSHService

DIMS = (8, 8, 8)
N_CORPUS = int(os.environ.get("BENCH_RECOVERY_N", 100_000))
PER_CLUSTER = 8
NOISE = 0.15
INSERT_BATCH = 1024
DELETE_BATCH = 256
N_ROUNDS = 8                  # timed rounds (after 1 compile-warmup round)
BUCKET_CAP = 64
NO_SNAP = 10 ** 9             # keep periodic snapshots out of insert timing


def _data():
    kc, kn, ki, kf = jax.random.split(jax.random.PRNGKey(29), 4)
    n_clusters = max(N_CORPUS // PER_CLUSTER, 1)
    centers = jax.random.normal(kc, (n_clusters,) + DIMS)
    corpus = (jnp.repeat(centers, PER_CLUSTER, axis=0)[:N_CORPUS]
              + NOISE * jax.random.normal(kn, (N_CORPUS,) + DIMS))
    n_ins = (N_ROUNDS + 1) * INSERT_BATCH
    inserts = np.asarray(
        jnp.tile(centers, (n_ins // n_clusters + 1,) + (1,) * len(DIMS))
        [:n_ins] + NOISE * jax.random.normal(ki, (n_ins,) + DIMS),
        np.float32)
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=4, num_tables=8,
                      rank=2, bucket_width=16.0)
    return corpus, inserts, fam


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def _ingest_rounds(svc, inserts) -> list[float]:
    """One warmup + N_ROUNDS timed insert/delete rounds (the bench-ingest
    cadence); -> per-insert-batch wall times in us."""
    rng = np.random.default_rng(7)
    times = []
    for r in range(N_ROUNDS + 1):
        batch = inserts[r * INSERT_BATCH:(r + 1) * INSERT_BATCH]
        t = _timed(lambda: jax.block_until_ready(
            svc.insert(batch).index.store.deltas[-1].sorted_keys))
        svc.delete(rng.choice(svc.index.size, size=DELETE_BATCH,
                              replace=False))
        if r > 0:                             # round 0 pays the compiles
            times.append(t)
        else:
            # Round 0 also pays the one-time WAL segment rotation (a 64MB
            # prezero); drop it from the per-append stats.
            svc.stats.wal_ms, svc.stats.wal_appends = 0.0, 0
    return times


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def run() -> list[str]:
    rows = []
    corpus, inserts, fam = _data()
    kw = dict(metric="euclidean", bucket_cap=BUCKET_CAP,
              max_deltas=2 * (N_ROUNDS + 2))

    plain = LSHService(fam, **kw).build(corpus)
    off_us = _median(_ingest_rounds(plain, inserts))
    off_ips = INSERT_BATCH / (off_us / 1e6)
    rows.append(emit(f"durability/insert_wal_off_b{INSERT_BATCH}", off_us,
                     f"{off_ips:.0f}"))
    del plain

    scratch = tempfile.mkdtemp(prefix="bench_durability_",
                               dir=os.path.dirname(os.path.abspath(__file__)))
    try:
        svc = DurableLSHService(fam, scratch, snapshot_every=NO_SNAP,
                                **kw).build(corpus)
        on_us = _median(_ingest_rounds(svc, inserts))
        on_ips = INSERT_BATCH / (on_us / 1e6)
        overhead = (on_us - off_us) / off_us * 100.0
        rows.append(emit(f"durability/insert_wal_on_b{INSERT_BATCH}", on_us,
                         f"{on_ips:.0f}|{overhead:+.1f}%"))
        rows.append(emit("durability/wal_append",
                         svc.stats.wal_ms * 1e3 / max(svc.stats.wal_appends,
                                                      1),
                         svc.stats.wal_appends))

        snap_us = _timed(svc.snapshot)        # rotates: replay starts here
        rows.append(emit("durability/snapshot", snap_us, svc.index.size))

        _ingest_rounds(svc, inserts)          # the log suffix to replay
        replayed = svc._log.next_lsn - svc._cover
        svc.close()

        fresh = DurableLSHService(fam, scratch, snapshot_every=NO_SNAP, **kw)
        rec_us = _timed(lambda: jax.block_until_ready(
            fresh.recover().index.store.base.sorted_keys))
        rows.append(emit("durability/recover", rec_us, replayed))

        append_trajectory({
            "bench": "durability", "n_devices": len(jax.devices()),
            "corpus_n": N_CORPUS, "insert_batch": INSERT_BATCH,
            "rounds": N_ROUNDS,
            "insert_items_per_s_wal_off": round(off_ips),
            "insert_items_per_s_wal_on": round(on_ips),
            "wal_overhead_pct": round(overhead, 2),
            "wal_append_ms": round(
                svc.stats.wal_ms / max(svc.stats.wal_appends, 1), 3),
            "snapshot_s": round(snap_us / 1e6, 3),
            "recovery_s": round(rec_us / 1e6, 3),
            "recovery_records_replayed": int(replayed),
        })
        fresh.close()
    finally:
        shutil.rmtree(scratch, ignore_errors=True)
    return rows


if __name__ == "__main__":
    run()
