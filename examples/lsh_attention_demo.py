"""LSH attention demo: the paper's CP-SRP hashing as sub-quadratic
attention (DESIGN.md integration point #2).

Compares exact causal attention with CP-SRP-bucketed attention on
sequences with planted long-range matches, reporting output error and the
fraction of attention mass the buckets recover, across hash counts.

    PYTHONPATH=src python examples/lsh_attention_demo.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.attention import chunked_attention
from repro.models.lsh_attention import lsh_attention_prefill

S, H, HD = 1024, 4, 64


def main():
    base = get_config("phi3-mini-3.8b", "smoke")
    key = jax.random.PRNGKey(0)
    kk, kv, kq, kp1, kp2 = jax.random.split(key, 5)
    k = jax.random.normal(kk, (1, S, H, HD))
    v = jax.random.normal(kv, (1, S, H, HD))
    # queries strongly aligned with the key 64 positions back
    q = jnp.roll(k, 64, axis=1) * 3.0 + 0.3 * jax.random.normal(
        kq, (1, S, H, HD))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    exact = chunked_attention(q, k, v, pos, pos, causal=True)

    print("hashes  chunk  rel.err   (vs exact attention)")
    for n_hashes in (2, 4, 8):
        for chunk in (64, 128, 256):
            cfg = dataclasses.replace(base, lsh_num_hashes=n_hashes,
                                      lsh_chunk=chunk, lsh_rank=2)
            proj = {
                "f1": jax.random.normal(kp1, (n_hashes, 8, cfg.lsh_rank)),
                "f2": jax.random.normal(kp2, (n_hashes, 8, cfg.lsh_rank)),
            }
            out = lsh_attention_prefill(cfg, proj, q, k, v, pos)
            err = float(jnp.linalg.norm(out[:, 128:] - exact[:, 128:])
                        / jnp.linalg.norm(exact[:, 128:]))
            cost = chunk * 2 / S
            print(f"{n_hashes:6d}  {chunk:5d}  {err:7.3f}   "
                  f"(attention cost {cost:.1%} of full)")

    print("\nInterpretation: larger chunks recover more of the exact softmax "
          "mass at proportionally higher cost. Note the hash-count trade-off: "
          "more bits give sharper buckets (Theorem 8) but, because queries "
          "and keys are sorted independently, many small buckets drift out "
          "of chunk alignment — with few bits the error is dominated by "
          "bucket collisions, with many bits by alignment, so bits and "
          "chunk size must scale together (the paper's K vs. w trade-off "
          "transposed to attention).")


if __name__ == "__main__":
    main()
