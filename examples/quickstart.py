"""Quickstart: the paper in ~60 lines.

Builds each of the four tensorized LSH families (CP-E2LSH, TT-E2LSH,
CP-SRP, TT-SRP), hashes tensors given in CP / TT / dense format, checks
the collision probabilities against the paper's closed forms, and runs a
tiny ANN query.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LSHIndex, cp_random_data, make_family,
                        naive_storage_size, theory)

DIMS = (8, 8, 8)   # a 3-mode tensor, 512 elements


def main():
    key = jax.random.PRNGKey(0)
    kx, kn, kf, kc = jax.random.split(key, 4)

    # --- 1. hash one tensor with every family -----------------------------
    x = jax.random.normal(kx, DIMS)
    for kind in ("cp-e2lsh", "tt-e2lsh", "cp-srp", "tt-srp"):
        fam = make_family(kf, kind, DIMS, num_codes=8, num_tables=2, rank=4,
                          bucket_width=4.0)
        codes = fam.hash(x)
        print(f"{kind:9s} codes {codes.shape} = {np.asarray(codes)[0][:6]}..."
              f"  storage {fam.storage_size():5d} scalars "
              f"(naive: {naive_storage_size(DIMS, 8, 2)})")

    # --- 2. collision probability vs the paper's theory -------------------
    m, w = 1500, 4.0
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=m, rank=2,
                      bucket_width=w)
    cx = np.asarray(fam.hash(x)).ravel()
    noise = jax.random.normal(kn, DIMS)
    print("\nr      empirical  p(r) theory   (Theorem 4 / Eq. 4.17)")
    for r in (1.0, 3.0, 6.0):
        y = x + noise * (r / jnp.linalg.norm(noise))
        cy = np.asarray(fam.hash(y)).ravel()
        emp = (cx == cy).mean()
        th = float(theory.e2lsh_collision_prob(r, w))
        print(f"{r:4.1f}   {emp:9.3f}  {th:10.3f}")

    # --- 3. ANN search over a CP-format corpus ----------------------------
    n = 500
    keys = jax.random.split(kc, n)
    from repro.core import CPTensor
    factors = [jnp.stack([cp_random_data(k, DIMS, 3).factors[m_] for k in keys])
               for m_ in range(3)]
    corpus = CPTensor(factors=tuple(factors), scale=1.0)
    fam = make_family(kf, "cp-e2lsh", DIMS, num_codes=8, num_tables=6,
                      rank=2, bucket_width=2.0)
    idx = LSHIndex(fam, metric="euclidean").build(corpus)
    q = jax.tree.map(lambda a: a[42], corpus)
    ids, dists, n_cand = idx.query(q, topk=3)
    print(f"\nANN query: nearest ids {ids.tolist()} (truth: 42), "
          f"{n_cand}/{n} candidates examined")


if __name__ == "__main__":
    main()
