"""End-to-end training driver example: train an LM for a few hundred steps
with checkpointing + auto-resume on the deterministic synthetic stream.

Quick CPU demo (reduced config, ~1 min):
    PYTHONPATH=src python examples/train_lm.py --steps 200

The ~100M-parameter run (mamba2-130m full config; slow on 1 CPU core,
native on TPU):
    PYTHONPATH=src python examples/train_lm.py --full --steps 300 --batch 4

This is a thin veneer over repro.launch.train (the real CLI); it exists so
the example is a single file with visible defaults.
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use the full mamba2-130m (130M params)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "mamba2-130m", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--lr", "3e-3"]
    if not args.full:
        argv.append("--smoke")
    history = train_main(argv)
    losses = [h["loss"] for h in history]
    k = max(len(losses) // 8, 1)
    print("loss curve:", " -> ".join(f"{l:.3f}" for l in losses[::k]))


if __name__ == "__main__":
    main()
