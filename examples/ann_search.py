"""End-to-end serving scenario: a batched LSH similarity-search service
over a corpus of tensors held in CP decomposition format — the paper's
efficient regime ("provided the input tensor is given in CP/TT format").

Builds the service with CP-E2LSH, serves query batches, and reports
recall@1 vs brute force, latency, candidate pruning, and the space the
naive method would have needed.

    PYTHONPATH=src python examples/ann_search.py [--corpus 5000]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CPTensor, brute_force, cp_random_data, naive_storage_size
from repro.serving.lsh_service import build_service

DIMS = (12, 12, 12)
RHAT = 4


def make_corpus(key, n):
    keys = jax.random.split(key, n)
    factors = [jnp.stack([cp_random_data(k, DIMS, RHAT).factors[m] for k in keys])
               for m in range(len(DIMS))]
    return CPTensor(factors=tuple(factors), scale=1.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=50)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kc, kq, kf = jax.random.split(key, 3)
    corpus = make_corpus(kc, args.corpus)

    # queries = perturbed corpus members (planted nearest neighbours)
    qid = np.arange(args.queries)
    queries = jax.tree.map(lambda a: a[qid], corpus)
    noise = 0.02
    queries = CPTensor(
        factors=tuple(f + noise * jax.random.normal(kq, f.shape)
                      for f in queries.factors),
        scale=1.0)

    t0 = time.perf_counter()
    svc = build_service(kf, "cp-e2lsh", DIMS, corpus, num_codes=8,
                        num_tables=10, rank=3, bucket_width=2.0)
    build_s = time.perf_counter() - t0
    print(f"built index over {args.corpus} CP tensors in {build_s:.2f}s")
    print(f"projection storage: {svc.index.family.storage_size()} scalars "
          f"(naive method: {naive_storage_size(DIMS, 6, 10)})")

    results = svc.query_batch(queries, topk=1)
    hits = sum(int(r["ids"].size and r["ids"][0] == i)
               for i, r in enumerate(results))
    print(f"recall@1 (planted NN): {hits}/{args.queries}")
    print(f"mean candidates: {svc.stats.mean_candidates:.1f} "
          f"({svc.stats.mean_candidates / args.corpus:.2%} of corpus)")
    print(f"mean latency: {svc.stats.mean_latency_ms:.2f} ms/query")

    # brute-force cross-check on a few queries
    ok = 0
    for i in range(5):
        q = jax.tree.map(lambda a: a[i], queries)
        truth, _ = brute_force("euclidean", q, corpus, topk=1)
        ok += int(truth[0] == i)
    print(f"brute-force sanity: planted NN is true NN for {ok}/5 queries")


if __name__ == "__main__":
    main()
