"""End-to-end serving scenario: a batched LSH similarity-search service
over a corpus of tensors held in CP decomposition format — the paper's
efficient regime ("provided the input tensor is given in CP/TT format").

Builds the service with CP-E2LSH on the device-resident batched index,
serves the whole query batch as one jit-compiled call, and reports
recall@1 vs brute force, batched latency/QPS, candidate pruning, the
host-index A/B latency, and the space the naive method would have needed.

    PYTHONPATH=src python examples/ann_search.py [--corpus 5000]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CPTensor, brute_force, cp_random_data, naive_storage_size
from repro.serving.lsh_service import build_service

DIMS = (12, 12, 12)
RHAT = 4


def make_corpus(key, n):
    keys = jax.random.split(key, n)
    factors = [jnp.stack([cp_random_data(k, DIMS, RHAT).factors[m] for k in keys])
               for m in range(len(DIMS))]
    return CPTensor(factors=tuple(factors), scale=1.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--host-ab", action="store_true",
                    help="also run the host-dict index for A/B timing")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kc, kq, kf = jax.random.split(key, 3)
    corpus = make_corpus(kc, args.corpus)

    # queries = perturbed corpus members (planted nearest neighbours)
    qid = np.arange(args.queries)
    queries = jax.tree.map(lambda a: a[qid], corpus)
    noise = 0.02
    queries = CPTensor(
        factors=tuple(f + noise * jax.random.normal(kq, f.shape)
                      for f in queries.factors),
        scale=1.0)

    svc = build_service(kf, "cp-e2lsh", DIMS, corpus, num_codes=8,
                        num_tables=10, rank=3, bucket_width=2.0)
    print(f"built device index over {args.corpus} CP tensors "
          f"in {svc.stats.build_s:.2f}s (bucket cap {svc.index.cap})")
    print(f"projection storage: {svc.index.family.storage_size()} scalars "
          f"(naive method: {naive_storage_size(DIMS, 6, 10)})")

    svc.query_batch(queries, topk=1)  # warm up the jit cache
    svc.stats.reset()
    results = svc.query_batch(queries, topk=1)
    hits = sum(int(r["ids"].size and r["ids"][0] == i)
               for i, r in enumerate(results))
    print(f"recall@1 (planted NN): {hits}/{args.queries}")
    print(f"mean candidates: {svc.stats.mean_candidates:.1f} "
          f"({svc.stats.mean_candidates / args.corpus:.2%} of corpus)")
    print(f"batched latency: {svc.stats.mean_latency_ms:.3f} ms/query "
          f"({svc.stats.qps:.0f} QPS, one jit call per batch)")

    if args.host_ab:
        hsvc = build_service(kf, "cp-e2lsh", DIMS, corpus, num_codes=8,
                             num_tables=10, rank=3, bucket_width=2.0,
                             device=False)
        hsvc.index.query(jax.tree.map(lambda a: a[0], queries), topk=1)  # warm jit
        hsvc.query_batch(queries, topk=1)
        dt = hsvc.stats.mean_latency_ms
        print(f"host-index A/B (dict build, shared planner): "
              f"{dt:.3f} ms/query "
              f"({dt / max(svc.stats.mean_latency_ms, 1e-9):.1f}x the "
              f"batched latency)")

    # brute-force cross-check on a few queries
    n_check = min(5, args.queries)
    ok = 0
    for i in range(n_check):
        q = jax.tree.map(lambda a: a[i], queries)
        truth, _ = brute_force("euclidean", q, corpus, topk=1)
        ok += int(truth[0] == i)
    print(f"brute-force sanity: planted NN is true NN for {ok}/{n_check} queries")


if __name__ == "__main__":
    main()
