"""Gradient compression with tensorized random projections (DESIGN.md
integration point #3): the paper's CP-Rademacher sketch on the DP
all-reduce path, with error feedback.

Trains the same model with and without compression and reports the loss
curves + the communicated-bytes ratio.

    PYTHONPATH=src python examples/gradient_compression.py [--steps 80]
"""

import argparse

import jax

from repro.configs import get_config
from repro.data.synthetic import DataConfig, batch_at
from repro.training import optimizer as opt_lib
from repro.training.compression import CompressionConfig
from repro.training.train_loop import TrainConfig, init_state, make_train_step


def train(cfg, tc, steps, seed=0):
    state, sketch = init_state(cfg, tc, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, tc, sketch=sketch))
    dc = DataConfig(batch_size=4, seq_len=64, seed=seed)
    losses, ratio = [], None
    for i in range(steps):
        state, m = step(state, batch_at(dc, cfg, i))
        losses.append(float(m["loss"]))
        ratio = float(m.get("comm_ratio", 0.0))
    return losses, ratio


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    cfg = get_config("stablelm-3b", "smoke")
    adamw = opt_lib.AdamWConfig(peak_lr=1e-3, warmup_steps=5,
                                decay_steps=max(args.steps, 10))

    base_tc = TrainConfig(adamw=adamw)
    comp_tc = TrainConfig(adamw=adamw, compression=CompressionConfig(
        num_projections=256, rank=2, min_size=4096))

    base_losses, _ = train(cfg, base_tc, args.steps)
    comp_losses, ratio = train(cfg, comp_tc, args.steps)

    k = max(args.steps // 8, 1)
    print("baseline  :", " -> ".join(f"{l:.3f}" for l in base_losses[::k]))
    print("compressed:", " -> ".join(f"{l:.3f}" for l in comp_losses[::k]))
    print(f"\nDP all-reduce volume with sketching: {ratio:.4f}x of raw "
          f"({1/max(ratio,1e-9):.0f}x reduction), via K CP-Rademacher "
          "projections per gradient matrix (paper Definition 8) + error "
          "feedback. Projection params are O(K (d1+d2) R) — the paper's "
          "space advantage — instead of O(K d1 d2) for a dense sketch.")


if __name__ == "__main__":
    main()
